"""Fig. 10: MiniLoader memory overhead + memory usage time (Mini vs
PISeL).

Paper claims: placeholder memory = 1/32 of fp32 (1-bit vs 4-byte);
memory usage *time* increases under Mini (~+27% avg) because faster
construction presses more concurrent placeholders into the weight-wait
interval.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run(args=None):
    args = args or common.std_parser(
        strategies=["pisel", "mini"]).parse_args([])
    store, _ = common.deployed_store(args)
    rows = []
    for name in common.model_list(args):
        rec = {}
        for strat in ("pisel", "mini"):
            res = common.load_with_strategy(store, name, strat, args.quick)
            tr = res.trace
            rec[strat] = (tr.memory_total_bytes(),
                          tr.memory_overhead_bytes(),
                          tr.memory_usage_time())
            rows.append([f"fig10/{name}/{strat}",
                         tr.memory_usage_time() * 1e6,
                         tr.memory_total_bytes() / 1e6])
        ratio = rec["pisel"][0] / max(rec["mini"][0], 1)
        dt = (rec["mini"][2] / max(rec["pisel"][2], 1e-9) - 1.0)
        print(f"# fig10 {name}: placeholder-bytes ratio pisel/mini = "
              f"{ratio:.1f}x (paper: 32x); usage-time delta = {dt:+.1%} "
              f"(paper: +27% avg)")
    common.print_csv(["name", "us_per_call", "mem_total_mb"], rows)
    return rows


if __name__ == "__main__":
    run(common.std_parser().parse_args())
