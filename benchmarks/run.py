"""Benchmark driver: one section per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--sweep]

Sections:
  fig9   end-to-end latency per model x strategy
  fig10  MiniLoader memory overhead + usage time
  fig11  per-unit work/wait breakdown
  fig12  pipeline utilization (+ fig13 active/total)
  fig14  Gantt timelines
  trace  Azure-like trace replay through the platform
  kernels micro-benches + VMEM budgets
  roofline  three-term analysis from dryrun_results.json (if present)
"""
from __future__ import annotations

import time

from benchmarks import (common, fig9_latency, fig10_memory, fig11_breakdown,
                        fig12_utilization, fig14_timeline, kernels_micro,
                        roofline, trace_bench)


def main() -> None:
    args = common.std_parser().parse_args()
    t0 = time.monotonic()
    sections = [
        ("fig9", lambda: fig9_latency.run(args)),
        ("fig10", lambda: fig10_memory.run(args)),
        ("fig11", lambda: fig11_breakdown.run(args)),
        ("fig12", lambda: fig12_utilization.run(args)),
        ("fig14", lambda: fig14_timeline.run(args)),
        ("trace", lambda: trace_bench.run(args)),
        ("kernels", lambda: kernels_micro.run(args)),
        ("roofline", lambda: roofline.run()),
    ]
    for name, fn in sections:
        print(f"\n=== {name} " + "=" * (68 - len(name)), flush=True)
        fn()
    print(f"\n# benchmarks completed in {time.monotonic() - t0:.1f}s")


if __name__ == "__main__":
    main()
