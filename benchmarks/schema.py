"""Schema for the BENCH_*.json artifacts — validated at emit time and
in CI's bench-smoke job, so a drive-by edit to a bench script can't
silently produce an artifact the gate (benchmarks/bench_gate.py) or a
downstream dashboard can no longer parse.

The shape every artifact shares:

    {"bench":  "<trace|generate|sharded|sharded_int8|slo|cluster|...>",
     "header": ["name", "<value-label>", "derived"],
     "rows":   [["<metric/path>", <number>, <number>], ...]}

Row names are slash-paths (``trace/cicada/mean``) and must be unique
within an artifact — the gate keys on them.  Values must be finite
(NaN/inf mean a bench mis-measured; failing here beats gating on them).

Usage:
    from benchmarks import schema
    schema.validate(obj)                      # raises SchemaError
    python benchmarks/schema.py BENCH_*.json  # CLI: exit 1 on invalid
"""
from __future__ import annotations

import json
import math
import sys
from typing import Any, Dict


class SchemaError(ValueError):
    """A BENCH artifact violates the schema."""


def _fail(msg: str):
    raise SchemaError(msg)


def validate(obj: Any, *, source: str = "<obj>") -> Dict[str, Any]:
    """Validate one parsed BENCH artifact; returns it for chaining."""
    if not isinstance(obj, dict):
        _fail(f"{source}: artifact must be a JSON object, "
              f"got {type(obj).__name__}")
    missing = [k for k in ("bench", "header", "rows") if k not in obj]
    if missing:
        _fail(f"{source}: missing keys {missing}")
    bench = obj["bench"]
    if not isinstance(bench, str) or not bench:
        _fail(f"{source}: 'bench' must be a non-empty string")
    header = obj["header"]
    if (not isinstance(header, list) or len(header) != 3
            or not all(isinstance(h, str) and h for h in header)):
        _fail(f"{source}: 'header' must be 3 non-empty strings, "
              f"got {header!r}")
    if header[0] != "name":
        _fail(f"{source}: header[0] must be 'name', got {header[0]!r}")
    rows = obj["rows"]
    if not isinstance(rows, list) or not rows:
        _fail(f"{source}: 'rows' must be a non-empty list")
    seen = set()
    for i, row in enumerate(rows):
        where = f"{source}: rows[{i}]"
        if not isinstance(row, list) or len(row) != 3:
            _fail(f"{where}: must be [name, value, derived], got {row!r}")
        name, value, derived = row
        if not isinstance(name, str) or not name:
            _fail(f"{where}: name must be a non-empty string")
        if name in seen:
            _fail(f"{where}: duplicate row name {name!r}")
        seen.add(name)
        for label, v in (("value", value), ("derived", derived)):
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                _fail(f"{where} ({name}): {label} must be a number, "
                      f"got {v!r}")
            if not math.isfinite(v):
                _fail(f"{where} ({name}): {label} is {v!r} — "
                      f"the bench mis-measured")
    return obj


def validate_file(path: str) -> Dict[str, Any]:
    with open(path) as f:
        try:
            obj = json.load(f)
        except json.JSONDecodeError as e:
            raise SchemaError(f"{path}: not valid JSON: {e}") from e
    return validate(obj, source=path)


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:])
    if not paths:
        print("usage: python benchmarks/schema.py BENCH_*.json",
              file=sys.stderr)
        return 2
    bad = 0
    for path in paths:
        try:
            obj = validate_file(path)
        except (SchemaError, OSError) as e:
            print(f"FAIL {e}")
            bad += 1
        else:
            print(f"ok   {path}: bench={obj['bench']} "
                  f"rows={len(obj['rows'])}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
