"""Fig. 11: per-pipeline-unit working/waiting time breakdown.

Paper claims: Mini cuts Layer Work ~63% on average vs PISeL; Preload
cuts Weight Work ~78% (retrieval moves into the overlapped Preload
row); waits (Weight Wait / Compute Wait) grow under both — acceptable
because E2E still drops.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run(args=None):
    args = args or common.std_parser().parse_args([])
    store, _ = common.deployed_store(args)
    rows = []
    per_strat = {}
    for name in common.model_list(args):
        for strat in args.strategies:
            res = common.load_with_strategy(store, name, strat, args.quick)
            s = res.trace.summary()
            per_strat.setdefault(strat, {})[name] = s
            for k in ("work_L", "work_R", "work_A", "work_E",
                      "wait_A", "wait_E"):
                rows.append([f"fig11/{name}/{strat}/{k}", s[k] * 1e6,
                             s[k] * 1e3])
    if "pisel" in per_strat and "mini" in per_strat:
        red = [1 - per_strat["mini"][n]["work_L"]
               / max(per_strat["pisel"][n]["work_L"], 1e-9)
               for n in per_strat["pisel"]]
        print(f"# fig11 Layer-Work reduction mini vs pisel: "
              f"{np.mean(red):.1%} (paper: 63.1% avg)")
    if "pisel" in per_strat and "preload" in per_strat:
        # PISeL's Weight unit does retrieval + apply (R+A); under the
        # WeightDecoupler retrieval moves to the overlapped Preload row
        # so the Weight unit's work is A alone.
        red = [1 - per_strat["preload"][n]["work_A"]
               / max(per_strat["pisel"][n]["work_A"]
                     + per_strat["pisel"][n]["work_R"], 1e-9)
               for n in per_strat["pisel"]]
        print(f"# fig11 Weight-Work reduction preload vs pisel: "
              f"{np.mean(red):.1%} (paper: 78.4% avg)")
    common.print_csv(["name", "us_per_call", "ms"], rows)
    return rows


if __name__ == "__main__":
    run(common.std_parser().parse_args())
