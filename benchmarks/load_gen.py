"""Open-loop load generator: Poisson/burst arrivals against a live Router.

Trace replay (``run_trace``) is *closed-loop* at low concurrency — a
slow response slows the arrival of the next request, which hides
overload (coordinated omission).  SLO numbers need the opposite: an
**open loop** that submits on a fixed wall-clock schedule no matter how
far behind the platform falls, so queueing delay under a burst shows up
in the measurements instead of silently stretching the workload.

Pieces:

  * :func:`poisson_arrivals` — piecewise-constant-rate Poisson arrival
    times (``phases = [(duration_s, rps), ...]``); a 10x burst is just
    a high-rate middle phase;
  * :class:`LoadClass` — one request class in the mix: its share of
    arrivals, whether it is one-shot or generation, and its SLO target
    (one-shot: end-to-end latency from submit; generation: TTFT from
    submit — both are what a *client* experiences, so router queueing
    and on-path cold starts count against the target);
  * :func:`run_open_loop` — submit every arrival at its scheduled wall
    time on the caller's thread (sleeping the gaps), collect every
    Future, and return per-request records;
  * :func:`slo_report` — per-class and overall attainment + latency
    percentiles from those records.

This module is driven by ``trace_bench --workload slo`` (the
BENCH_slo.json artifact) and is importable for ad-hoc load tests
against any Router-compatible ``submit``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.api import AdmissionError, GenerateSpec, Request


def poisson_arrivals(phases: Sequence[Tuple[float, float]],
                     rng: np.random.Generator) -> List[float]:
    """Arrival offsets (seconds from t=0) for piecewise-constant-rate
    Poisson traffic.  ``phases``: [(duration_s, rate_rps), ...]."""
    out: List[float] = []
    t0 = 0.0
    for dur, rate in phases:
        if rate > 0:
            t = t0 + float(rng.exponential(1.0 / rate))
            while t < t0 + dur:
                out.append(t)
                t += float(rng.exponential(1.0 / rate))
        t0 += dur
    return out


@dataclasses.dataclass
class LoadClass:
    """One request class in the mixed workload."""
    name: str
    weight: float                    # share of arrivals (normalized)
    gen: bool                        # generation vs one-shot
    slo_s: float                     # target: TTFT (gen) / latency (oneshot)
                                     # measured from *submit*


@dataclasses.dataclass
class RequestRecord:
    """One submitted request's outcome."""
    req_id: int
    cls_name: str
    gen: bool
    t_sched: float                   # scheduled arrival offset
    t_lag: float                     # submit lateness vs schedule
    ok: bool = False
    rejected: bool = False
    error: Optional[str] = None
    cold: Optional[bool] = None
    # client-perceived times, all measured from submit:
    latency_s: Optional[float] = None
    ttft_s: Optional[float] = None   # gen only (queue + service TTFT)

    def slo_time(self) -> Optional[float]:
        """The time the class SLO is judged on."""
        if not self.ok:
            return None
        return self.ttft_s if self.gen else self.latency_s


def run_open_loop(submit: Callable[[Request], "object"],
                  model: str,
                  arrivals: Sequence[float],
                  classes: Sequence[LoadClass],
                  make_spec: Callable[[int], GenerateSpec],
                  make_batch: Callable[[], dict],
                  rng: np.random.Generator,
                  time_scale: float = 1.0) -> List[RequestRecord]:
    """Submit one request per arrival at its scheduled wall time.

    Open loop: the schedule never waits for completions — if the
    platform falls behind, requests stack up in the router queue and
    their queue_s grows, exactly as a real overload would look.
    ``time_scale`` scales the schedule (0.5 = twice as fast).
    Rejected admissions (queue full) are recorded, not raised.
    """
    weights = np.array([c.weight for c in classes], float)
    weights /= weights.sum()
    picks = rng.choice(len(classes), size=len(arrivals), p=weights)
    t0 = time.monotonic()
    pending: List[Tuple[RequestRecord, "object"]] = []
    records: List[RequestRecord] = []
    for i, (t_arr, ci) in enumerate(zip(arrivals, picks)):
        cls = classes[ci]
        target = t0 + t_arr * time_scale
        lag = time.monotonic() - target
        if lag < 0:
            time.sleep(-lag)
            lag = 0.0
        rec = RequestRecord(req_id=i, cls_name=cls.name, gen=cls.gen,
                            t_sched=t_arr, t_lag=lag)
        records.append(rec)
        req = Request(req_id=i, model=model,
                      gen=make_spec(i) if cls.gen else None,
                      batch=None if cls.gen else make_batch(),
                      t_logical=t_arr)
        try:
            fut = submit(req)
        except AdmissionError:
            rec.rejected = True
            continue
        pending.append((rec, fut))
    for rec, fut in pending:
        try:
            resp = fut.result()
        except BaseException as e:            # record, don't abort the run
            rec.error = f"{type(e).__name__}: {e}"
            continue
        rec.ok = True
        rec.cold = resp.cold
        rec.latency_s = resp.queue_s + resp.latency_s
        if resp.ttft_s is not None:
            rec.ttft_s = resp.queue_s + resp.ttft_s
    return records


def slo_report(records: Sequence[RequestRecord],
               classes: Sequence[LoadClass]) -> Dict[str, object]:
    """Attainment + client-perceived percentiles.

    attainment = requests meeting their class SLO / all *scheduled*
    requests — a rejected or failed request counts as a miss (dropping
    it would let an overloaded platform shed its way to 100%).
    """
    by_name = {c.name: c for c in classes}
    met = 0
    per_class: Dict[str, List[float]] = {c.name: [] for c in classes}
    ttfts: List[float] = []
    n_cold = 0
    for r in records:
        t = r.slo_time()
        if t is not None:
            per_class[r.cls_name].append(t)
            if t <= by_name[r.cls_name].slo_s:
                met += 1
            if r.ttft_s is not None:
                ttfts.append(r.ttft_s)
            if r.cold:
                n_cold += 1
    out: Dict[str, object] = {
        "n": len(records),
        "n_ok": sum(1 for r in records if r.ok),
        "n_rejected": sum(1 for r in records if r.rejected),
        "n_errors": sum(1 for r in records if r.error),
        "n_cold": n_cold,
        "attainment": met / len(records) if records else 0.0,
        "ttft_p50_ms": float(np.percentile(ttfts, 50)) * 1e3
        if ttfts else None,
        "ttft_p99_ms": float(np.percentile(ttfts, 99)) * 1e3
        if ttfts else None,
    }
    for name, vals in per_class.items():
        out[f"{name}/n"] = len(vals)
        out[f"{name}/p99_ms"] = float(np.percentile(vals, 99)) * 1e3 \
            if vals else None
        out[f"{name}/attain"] = (
            sum(1 for v in vals if v <= by_name[name].slo_s) / len(vals)
            if vals else 0.0)
    return out
