"""CI bench gate: fail the build when a BENCH artifact regresses past
tolerance against its committed baseline.

Baselines live in ``benchmarks/baselines/BENCH_<bench>.json`` — the
same schema as the artifacts (benchmarks/schema.py), seeded from a CI
run and refreshed deliberately (commit a new baseline when a change
legitimately moves a number; the diff then *shows* the movement).

Only rows registered in :data:`GATES` are compared — most bench rows
are diagnostics whose run-to-run noise would make a 15% band flap.
Each gate is (direction, tolerance):

  ``lower``   value must not rise more than tol above baseline
              (latency-shaped metrics)
  ``higher``  value must not fall more than tol below baseline
              (throughput-shaped metrics)
  ``floor``   value must stay >= tol, baseline-independent (invariants
              like "the autoscaler beats the no-autoscaler run")

A gated row missing from the current artifact fails (a silently
dropped metric is a regression in coverage); a gated row missing from
the *baseline* is reported and skipped, so adding a gate and seeding
its baseline can land in one commit.  Artifacts with no registered
gates are schema-validated only.

Usage (CI's bench-gate job):
    python benchmarks/bench_gate.py --baseline-dir benchmarks/baselines \
        BENCH_trace.json BENCH_generate.json BENCH_slo.json
Exit: 0 ok, 1 regression/malformed, 2 usage.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

if __package__ in (None, ""):
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import schema

DEFAULT_TOL = 0.15

# bench -> {row name: (direction, tolerance)}
GATES: Dict[str, Dict[str, Tuple[str, float]]] = {
    "trace": {
        # cold/warm-mix load latency of the paper strategy
        "trace/cicada/mean": ("lower", DEFAULT_TOL),
        "trace/cicada/cold_mean": ("lower", DEFAULT_TOL),
    },
    "generate": {
        "generate/conc1/ttft_p50_ms": ("lower", DEFAULT_TOL),
        "generate/conc8/tok_s": ("higher", DEFAULT_TOL),
        # quantized-resident serving (--compute-quant), baseline-
        # independent: fused dequant must hold decode throughput, and
        # int8 residency must buy a real memory win (quant <= 0.6x f32
        # resident bytes, expressed as f32/quant >= 1.66)
        "generate/quant/tok_s_vs_f32": ("floor", 0.9),
        "generate/quant/resident_ratio": ("floor", 1.66),
        # block-paged KV serving (--compute-paged), baseline-
        # independent: the paged decode kernel + page bookkeeping must
        # hold decode throughput, a prefix-cache hit must skip enough
        # prefill to halve TTFT, and a prompt past the slotted per-slot
        # arena must admit under the same byte budget
        "generate/paged/tok_s_vs_slotted": ("floor", 0.9),
        "generate/paged/prefix_ttft_speedup": ("floor", 2.0),
        "generate/paged/long_prompt_admitted": ("floor", 1.0),
    },
    "slo": {
        "slo/autoscale/ttft_p50_ms": ("lower", DEFAULT_TOL),
        # the PR's headline invariant: pre-provisioning must beat the
        # bare platform's burst tail, whatever this runner's absolute
        # numbers are
        "slo/improvement/p99_ttft_ratio": ("floor", 1.0),
        "slo/autoscale/prewarms": ("floor", 1.0),
    },
    "sharded": {
        "sharded/mesh4_vs_mesh1/speedup": ("floor", 1.5),
    },
    "sharded_int8": {
        "sharded_int8/mesh4_vs_mesh1/speedup": ("floor", 1.5),
    },
    "cluster": {
        # the PR's headline invariants, baseline-independent: an N-node
        # scale-out burst over peer exchange must beat N independent
        # origin cold starts, and a second node cold-starting an
        # already-landed model must not touch the origin at all
        "cluster/peer_vs_origin/speedup": ("floor", 1.2),
        "cluster/second_node/zero_origin_reads": ("floor", 1.0),
    },
}


def _rows(obj) -> Dict[str, float]:
    return {name: float(value) for name, value, _ in obj["rows"]}


def gate_artifact(path: str, baseline_dir: str,
                  scale: float = 1.0) -> List[str]:
    """Returns failure messages (empty = pass); prints a verdict line
    per gated row.  ``scale`` multiplies relative tolerances (noisy
    shared runners can widen the band without editing the registry)."""
    obj = schema.validate_file(path)
    bench = obj["bench"]
    gates = GATES.get(bench, {})
    if not gates:
        print(f"-- {path}: bench={bench!r} has no registered gates "
              f"(schema-validated only)")
        return []
    cur = _rows(obj)
    base_path = os.path.join(baseline_dir, f"BENCH_{bench}.json")
    base: Dict[str, float] = {}
    if os.path.exists(base_path):
        base = _rows(schema.validate_file(base_path))
    else:
        print(f"-- {path}: no baseline at {base_path} "
              f"(floor gates still apply)")
    fails: List[str] = []
    for name, (direction, tol) in sorted(gates.items()):
        if name not in cur:
            fails.append(f"{path}: gated row {name!r} missing from "
                         f"artifact")
            continue
        v = cur[name]
        if direction == "floor":
            ok = v >= tol
            print(f"{'ok  ' if ok else 'FAIL'} {name}: {v:.4g} "
                  f"(floor {tol:g})")
            if not ok:
                fails.append(f"{path}: {name} = {v:.4g} below floor "
                             f"{tol:g}")
            continue
        if name not in base:
            print(f"--   {name}: {v:.4g} (no baseline row — seed it)")
            continue
        b = base[name]
        band = tol * scale
        if b == 0:
            ok = v == 0 if direction == "lower" else v >= 0
            delta = 0.0
        elif direction == "lower":
            delta = (v - b) / b
            ok = delta <= band
        else:
            delta = (b - v) / b
            ok = delta <= band
        print(f"{'ok  ' if ok else 'FAIL'} {name}: {v:.4g} vs "
              f"baseline {b:.4g} ({direction}, "
              f"regression {delta:+.1%}, band {band:.0%})")
        if not ok:
            fails.append(f"{path}: {name} regressed {delta:+.1%} "
                         f"(> {band:.0%} {direction}-band vs "
                         f"baseline {b:.4g})")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifacts", nargs="+", metavar="BENCH_*.json")
    ap.add_argument("--baseline-dir", default="benchmarks/baselines")
    ap.add_argument("--tolerance-scale", type=float,
                    default=float(os.environ.get(
                        "BENCH_GATE_TOLERANCE_SCALE", "1.0")),
                    help="multiply every relative tolerance band "
                         "(env: BENCH_GATE_TOLERANCE_SCALE)")
    args = ap.parse_args(argv)
    fails: List[str] = []
    for path in args.artifacts:
        try:
            fails.extend(gate_artifact(path, args.baseline_dir,
                                       args.tolerance_scale))
        except (schema.SchemaError, OSError, KeyError) as e:
            fails.append(f"{path}: {e}")
    if fails:
        print("\nbench-gate FAILED:")
        for f in fails:
            print(f"  {f}")
        return 1
    print("\nbench-gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
