"""Fig. 9: end-to-end inference latency per model x strategy.

Paper claims to validate: Preload/Mini/Cicada reduce latency vs PISeL by
~6% / ~53% / ~62% on average; MiniLoader dominates the win; the VGG
family benefits most from MiniLoader.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run(args=None):
    args = args or common.std_parser().parse_args([])
    store, _ = common.deployed_store(args)
    rows = []
    summary = {}
    for name in common.model_list(args):
        lat = {}
        for strat in args.strategies:
            ts = []
            for _ in range(args.repeats):
                res = common.load_with_strategy(store, name, strat,
                                                args.quick)
                ts.append(res.trace.total_time())
            lat[strat] = float(np.median(ts))
            rows.append([f"fig9/{name}/{strat}", lat[strat] * 1e6,
                         lat[strat] * 1e3])
        if "pisel" in lat:
            for s in lat:
                if s != "pisel":
                    summary.setdefault(s, []).append(
                        1.0 - lat[s] / lat["pisel"])
    common.print_csv(["name", "us_per_call", "latency_ms"], rows)
    for s, reds in sorted(summary.items()):
        print(f"# fig9 mean latency reduction vs PISeL [{s}]: "
              f"{np.mean(reds):+.1%}  (paper: mini 53.4%, cicada 61.6%, "
              f"preload 6.2%)")
    return rows


if __name__ == "__main__":
    run(common.std_parser(repeats=3).parse_args())
