"""Roofline analysis (deliverable g): three terms per (arch x shape)
from the dry-run's compiled artifacts.

    compute term    = HLO_FLOPs_dev / peak_FLOP/s          (197 TF bf16)
    memory term     = HLO_bytes_dev / HBM_bw               (819 GB/s)
    collective term = collective_bytes_dev / link_bw       (50 GB/s ICI)

(`*_dev` are per-device numbers from the SPMD-partitioned module, so
dividing by per-chip peaks is the same as global/chips x peak.)

Also reported per cell: the dominant term, MODEL_FLOPS = 6*N*D (dense;
N_active for MoE; D = tokens processed), the usefulness ratio
MODEL_FLOPS / HLO_FLOPS_global (catches remat/redundancy waste), and a
one-line lever on the dominant term.

Input: the JSON written by ``python -m repro.launch.dryrun --all
--both-meshes --out dryrun_results.json``.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES
from repro.models.api import get_config

PEAK_FLOPS = 197e12          # v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

LEVERS = {
    "compute": "raise arithmetic efficiency: fuse ops/skip masked work "
               "(causal flash), drop remat recompute on cheap layers",
    "memory": "cut HBM traffic: larger fused blocks, bf16 activations, "
              "keep weights resident across microbatches",
    "collective": "reshard: move the gather/reduce off the critical "
                  "axis, overlap collectives with compute, int8 "
                  "compress the DP reduce",
}


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.seq * cell.batch
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.seq * cell.batch
        return 2.0 * n * tokens
    return 2.0 * n * cell.batch          # decode: one token per row


def analyze(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok" or "cost_per_device" not in rec:
        return None
    c = rec["cost_per_device"]
    devices = rec.get("devices", 256)
    t_compute = c["flops"] / PEAK_FLOPS
    t_memory = c["bytes"] / HBM_BW
    t_coll = c["collectives"]["total"] / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = c["flops"] * devices
    useful = mf / hlo_global if hlo_global else 0.0
    # roofline fraction: useful model FLOPs per second achievable at the
    # bound, over the chip's peak
    ach = mf / devices / max(bound, 1e-12)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dom,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": useful,
        "roofline_frac": ach / PEAK_FLOPS,
        "fits_hbm": rec["memory"]["fits_hbm_16g"],
        "live_gib": rec["memory"]["live_bytes_per_device"] / 2 ** 30,
        "lever": LEVERS[dom],
    }


def run(path: str = "dryrun_results.json", mesh: str = "16x16"):
    if not os.path.exists(path):
        print(f"# roofline: {path} not found — run "
              f"`python -m repro.launch.dryrun --all --both-meshes --out "
              f"{path}` first")
        return []
    with open(path) as f:
        records = json.load(f)
    rows: List[List] = []
    print("arch,shape,t_compute_s,t_memory_s,t_collective_s,dominant,"
          "roofline_frac,useful_ratio,live_gib,fits_hbm")
    for rec in records:
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skip":
            print(f"{rec['arch']},{rec['shape']},,,,"
                  f"skip({rec['skip_reason'][:40]}),,,,")
            continue
        a = analyze(rec)
        if a is None:
            continue
        print(f"{a['arch']},{a['shape']},{a['t_compute_s']:.4e},"
              f"{a['t_memory_s']:.4e},{a['t_collective_s']:.4e},"
              f"{a['dominant']},{a['roofline_frac']:.3f},"
              f"{a['useful_ratio']:.3f},{a['live_gib']:.2f},"
              f"{int(a['fits_hbm'])}")
        rows.append(a)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="path", default="dryrun_results.json")
    ap.add_argument("--mesh", default="16x16")
    a = ap.parse_args()
    run(a.path, a.mesh)
