"""Kernel micro-benchmarks: XLA-fallback wall time on CPU (structural —
the Pallas kernels target TPU; interpret mode is a correctness harness,
not a performance surface) + analytic VMEM footprints of the chosen
BlockSpecs, which is the number that matters for the TPU target.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops


def timeit(f, *a, n=5):
    f(*a)[0].block_until_ready() if isinstance(f(*a), tuple) else \
        jax.block_until_ready(f(*a))
    t0 = time.monotonic()
    for _ in range(n):
        jax.block_until_ready(f(*a))
    return (time.monotonic() - t0) / n


def vmem_bytes_flash(bq=256, bk=256, dh=128):
    # q + k + v + acc(f32) + m/l scratch
    return (bq * dh * 2 + 2 * bk * dh * 2 + bq * dh * 4
            + 2 * bq * 128 * 4)


def run(args=None):
    r = np.random.default_rng(0)
    rows = []

    B, H, K, S, dh = 1, 8, 2, 1024, 128
    q = jnp.asarray(r.standard_normal((B, S, H, dh)), jnp.bfloat16)
    k = jnp.asarray(r.standard_normal((B, S, K, dh)), jnp.bfloat16)
    v = jnp.asarray(r.standard_normal((B, S, K, dh)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True))
    t = timeit(f, q, k, v)
    rows.append(["kernel/flash_attention_xla_1k", t * 1e6,
                 2 * 2 * B * H * S * S * dh / t / 1e9])

    kc = jnp.asarray(r.standard_normal((4, K, 2048, dh)), jnp.bfloat16)
    vc = kc
    q1 = jnp.asarray(r.standard_normal((4, H, dh)), jnp.bfloat16)
    pos = jnp.full((4,), 2047, jnp.int32)
    f2 = jax.jit(lambda q, a, b, p: ops.decode_attention(q, a, b, p))
    t = timeit(f2, q1, kc, vc, pos)
    rows.append(["kernel/decode_attention_xla_2k", t * 1e6,
                 kc.nbytes * 2 / t / 1e9])

    x = jnp.asarray(r.standard_normal((2, 8, 512, 64)), jnp.float32)
    dt = jnp.abs(jnp.asarray(r.standard_normal((2, 8, 512)),
                             jnp.float32)) * 0.1
    A = -jnp.ones((8,))
    Bm = jnp.asarray(r.standard_normal((2, 512, 64)), jnp.float32)
    f3 = jax.jit(lambda *a: ops.ssd_scan(*a, bc=128))
    t = timeit(f3, x, dt, A, Bm, Bm)
    rows.append(["kernel/ssd_scan_xla_512", t * 1e6, 0.0])

    a = jnp.abs(jnp.asarray(r.standard_normal((2, 1024, 256)),
                            jnp.float32)) * 0.3
    b = jnp.asarray(r.standard_normal((2, 1024, 256)), jnp.float32)
    f4 = jax.jit(ops.rglru_scan)
    t = timeit(f4, a, b)
    rows.append(["kernel/rglru_scan_xla_1k", t * 1e6, 0.0])

    w8 = jnp.asarray(r.integers(-127, 128, (4096, 4096)), jnp.int8)
    sc = jnp.abs(jnp.asarray(r.standard_normal(4096), jnp.float32))
    f5 = jax.jit(lambda w, s: ops.weight_transform(w, s))
    t = timeit(f5, w8, sc)
    rows.append(["kernel/weight_transform_16M", t * 1e6,
                 w8.nbytes / t / 1e9])

    # TPU-target VMEM budgets (static analysis of BlockSpecs)
    rows.append(["kernel/flash_vmem_kb", vmem_bytes_flash() / 1024, 0.0])
    common.print_csv(["name", "us_per_call", "derived_gbps"], rows)
    return rows


if __name__ == "__main__":
    run()
