"""Kernel micro-benchmarks: sweep the dispatch registry.

Every kernel is timed under each *available* mode — ``ref`` (the jnp
fallback serving CPU hot paths), ``interpret`` (the Pallas kernel body
executed by the interpreter: a correctness harness, timed here so its
cost trend is visible), and ``pallas`` when the backend probes as
capable (TPU).  ``weight_transform`` additionally sweeps the per-shard
extent sizes the decoupler's placement lanes feed it (full leaf down to
a 4-way shard slice), with the tile sizes
:func:`repro.configs.shapes.wt_shard_tiles` assigns each size.

``quant_matmul`` is timed at a decode shape (m=8) and a prefill shape
(m=1024) — the two regimes the fused-dequant kernel serves under
``compute_quant``.

``--autotune`` additionally sweeps the tunable block sizes of
``quant_matmul`` and ``weight_transform`` on this backend and persists
the per-kernel winner into the JSON artifact (``"autotune"`` key,
keyed by backend + sweep shape); a later run — or the serving process —
re-applies it with :func:`repro.configs.shapes.load_autotuned`.

``--json-out BENCH_kernels.json`` emits the rows plus the registry's
capability report and per-mode dispatch counts — the CI bench-smoke
artifact.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import shapes
from repro.configs.shapes import kernel_blocks, wt_shard_tiles
from repro.kernels import ops


def timeit(f, *a, n=3):
    jax.block_until_ready(f(*a))
    t0 = time.monotonic()
    for _ in range(n):
        jax.block_until_ready(f(*a))
    return (time.monotonic() - t0) / n


def vmem_bytes_flash(bq=None, bk=None, dh=128):
    kb = kernel_blocks()
    bq = bq or kb.flash_bq
    bk = bk or kb.flash_bk
    # q + k + v + acc(f32) + m/l scratch
    return (bq * dh * 2 + 2 * bk * dh * 2 + bq * dh * 4
            + 2 * bq * 128 * 4)


def _available_modes(requested=None):
    modes = ["ref", "interpret"]
    if all(ops.registry.pallas_supported(n)
           for n in ("flash_attention", "decode_attention")):
        modes.append("pallas")
    if requested:
        missing = [m for m in requested if m not in modes]
        if missing:
            raise SystemExit(
                f"requested mode(s) {missing} unavailable on this "
                f"backend (capable of: {modes}); see "
                f"ops.registry.describe() for probe verdicts")
        modes = [m for m in modes if m in requested]
    return modes


def _sweep(rows, name, build, modes, ref_bytes=0.0):
    """Time one kernel closure under each dispatch mode.  ``build()``
    returns (fn, args): rebuilt per mode so the fresh jit traces under
    the newly-forced dispatch."""
    for mode in modes:
        ops.set_mode(mode)
        try:
            f, args = build()
            t = timeit(f, *args)
            rows.append([f"kernel/{name}/{mode}", t * 1e6,
                         ref_bytes / t / 1e9 if ref_bytes else 0.0])
        finally:
            ops.set_mode(None)


def run(args=None):
    r = np.random.default_rng(0)
    rows = []
    modes = _available_modes(getattr(args, "modes", None))

    B, H, K, S, dh = 1, 4, 2, 256, 64
    q = jnp.asarray(r.standard_normal((B, S, H, dh)), jnp.bfloat16)
    k = jnp.asarray(r.standard_normal((B, S, K, dh)), jnp.bfloat16)
    v = jnp.asarray(r.standard_normal((B, S, K, dh)), jnp.bfloat16)
    _sweep(rows, "flash_attention_256",
           lambda: (jax.jit(lambda q, k, v: ops.flash_attention(
               q, k, v, causal=True)), (q, k, v)), modes,
           ref_bytes=2 * 2 * B * H * S * S * dh)

    kc = jnp.asarray(r.standard_normal((2, K, 512, dh)), jnp.bfloat16)
    q1 = jnp.asarray(r.standard_normal((2, H, dh)), jnp.bfloat16)
    pos = jnp.full((2,), 511, jnp.int32)
    _sweep(rows, "decode_attention_512",
           lambda: (jax.jit(lambda q, a, b, p: ops.decode_attention(
               q, a, b, p)), (q1, kc, kc, pos)), modes,
           ref_bytes=kc.nbytes * 2)

    # paged twin of the 512-token decode: same logical extent gathered
    # through per-sequence page tables over a shuffled physical pool
    pt = 64
    npg = 512 // pt
    P = 2 * npg + 2                       # + 2 unreferenced pages
    perm = r.permutation(P)[:2 * npg]
    tbl = jnp.asarray(perm.reshape(2, npg).astype(np.int32))
    kp = jnp.asarray(r.standard_normal((P, K, pt, dh)), jnp.bfloat16)
    paged_modes = [m for m in modes if m != "pallas"
                   or ops.registry.pallas_supported("decode_attention_paged")]
    _sweep(rows, f"decode_attention_paged_512_pt{pt}",
           lambda: (jax.jit(lambda q, a, b, t, p:
                            ops.decode_attention_paged(q, a, b, t, p)),
                    (q1, kp, kp, tbl, pos)), paged_modes,
           ref_bytes=2 * 2 * npg * pt * K * dh * kp.dtype.itemsize)

    x = jnp.asarray(r.standard_normal((1, 4, 256, 64)), jnp.float32)
    dt = jnp.abs(jnp.asarray(r.standard_normal((1, 4, 256)),
                             jnp.float32)) * 0.1
    A = -jnp.ones((4,))
    Bm = jnp.asarray(r.standard_normal((1, 256, 64)), jnp.float32)
    _sweep(rows, "ssd_scan_256",
           lambda: (jax.jit(lambda *a: ops.ssd_scan(*a, bc=64)),
                    (x, dt, A, Bm, Bm)), modes)

    a = jnp.abs(jnp.asarray(r.standard_normal((1, 256, 128)),
                            jnp.float32)) * 0.3
    b = jnp.asarray(r.standard_normal((1, 256, 128)), jnp.float32)
    _sweep(rows, "rglru_scan_256",
           lambda: (jax.jit(ops.rglru_scan), (a, b)), modes)

    # weight transform at the shard-slice sizes the placement lanes see:
    # a 4M-element leaf whole, then its 2-way and 4-way column shards
    n_full, m_full = 2048, 2048
    w8_full = np.asarray(r.integers(-127, 128, (n_full, m_full)), np.int8)
    sc_full = np.abs(r.standard_normal(m_full).astype(np.float32)) + 1e-3
    for div in (1, 2, 4):
        m = m_full // div
        w8 = jnp.asarray(w8_full[:, :m])
        sc = jnp.asarray(sc_full[:m])
        bn, bm = wt_shard_tiles(w8.nbytes)
        _sweep(rows, f"weight_transform_shard{div}_bn{bn}",
               lambda w8=w8, sc=sc, bn=bn, bm=bm: (
                   jax.jit(lambda w, s: ops.weight_transform(
                       w, s, bn=bn, bm=bm)), (w8, sc)), modes,
               ref_bytes=w8.nbytes)

    # fused-dequant matmul at the two compute_quant regimes: decode
    # (a few resident generations' activations against one weight) and
    # prefill (prompt-length activation blocks)
    K_qm, N_qm = 1024, 1024
    w8 = jnp.asarray(r.integers(-127, 128, (K_qm, N_qm)), np.int8)
    sc = jnp.asarray(np.abs(r.standard_normal(N_qm).astype(np.float32))
                     + 1e-3)
    for m, tag in ((8, "decode"), (1024, "prefill")):
        xq = jnp.asarray(r.standard_normal((m, K_qm)), jnp.bfloat16)
        _sweep(rows, f"quant_matmul_{tag}_m{m}",
               lambda xq=xq: (jax.jit(lambda x, w, s: ops.quant_matmul(
                   x, w, s)), (xq, w8, sc)), modes,
               ref_bytes=w8.nbytes)

    autotune = None
    if getattr(args, "autotune", False):
        autotune = autotune_blocks(rows)

    # TPU-target VMEM budgets (static analysis of the configured blocks)
    rows.append(["kernel/flash_vmem_kb", vmem_bytes_flash() / 1024, 0.0])
    common.print_csv(["name", "us_per_call", "derived_gbps"], rows)

    json_out = getattr(args, "json_out", None)
    if json_out:
        obj = {"bench": "kernels",
               "header": ["name", "us_per_call", "derived_gbps"],
               "registry": ops.registry.describe(),
               "dispatch_counts": {
                   f"{k}/{m}": n for (k, m), n
                   in ops.registry.dispatch_snapshot().items()},
               "rows": rows}
        if autotune is not None:
            obj["autotune"] = autotune
        with open(json_out, "w") as f:
            json.dump(obj, f, indent=2)
        print(f"# wrote {json_out}")
    return rows


# ---------------------------------------------------------------------------
# per-backend block autotuning
# ---------------------------------------------------------------------------

# candidate grids per kernel: KernelBlocks field -> values.  Every value
# divides the sweep shapes below, so interpret-mode timing exercises the
# exact tiling (no padding) and a pallas-capable backend lowers each
# candidate unchanged.
_TUNE_GRID = {
    "quant_matmul": {"qm_bm": (128, 256), "qm_bk": (256, 512),
                     "qm_bn": (128, 256)},
    "weight_transform": {"wt_bn": (256, 512), "wt_bm": (256, 512)},
}
_TUNE_SHAPES = {"quant_matmul": (256, 1024, 1024),     # (m, k, n)
                "weight_transform": (2048, 1024)}      # (n, m)


def autotune_blocks(rows, grid=None):
    """Sweep the tunable block sizes on this backend; returns the
    ``"autotune"`` artifact section (and appends a best-time row per
    kernel).  Timed under the best *executing* mode — ``pallas`` when
    the backend probes capable, else ``interpret`` (the interpreter
    walks the real grid, so tile-count effects are visible even where
    the pallas path cannot lower)."""
    import itertools

    backend = jax.default_backend()
    grid = grid or _TUNE_GRID
    out = {}
    r = np.random.default_rng(1)
    for kern, fields in grid.items():
        mode = "pallas" if ops.registry.pallas_supported(kern) \
            else "interpret"
        if kern == "quant_matmul":
            m, k, n = _TUNE_SHAPES[kern]
            x = jnp.asarray(r.standard_normal((m, k)), jnp.bfloat16)
            w = jnp.asarray(r.integers(-127, 128, (k, n)), np.int8)
            s = jnp.asarray(np.abs(r.standard_normal(n)
                                   .astype(np.float32)) + 1e-3)

            def build(cand):
                return (jax.jit(lambda x, w, s: ops.quant_matmul(
                    x, w, s, bm=cand["qm_bm"], bk=cand["qm_bk"],
                    bn=cand["qm_bn"])), (x, w, s))
        else:
            n, m = _TUNE_SHAPES[kern]
            w = jnp.asarray(r.integers(-127, 128, (n, m)), np.int8)
            s = jnp.asarray(np.abs(r.standard_normal(m)
                                   .astype(np.float32)) + 1e-3)

            def build(cand):
                return (jax.jit(lambda w, s: ops.weight_transform(
                    w, s, bn=cand["wt_bn"], bm=cand["wt_bm"])), (w, s))

        names = list(fields)
        best = None
        ops.set_mode(mode)
        try:
            for combo in itertools.product(*(fields[f] for f in names)):
                cand = dict(zip(names, combo))
                f, fargs = build(cand)
                t = timeit(f, *fargs)
                if best is None or t < best[1]:
                    best = (cand, t)
        finally:
            ops.set_mode(None)
        out[kern] = {"backend": backend, "mode": mode,
                     "shape": list(_TUNE_SHAPES[kern]),
                     "winner": best[0], "us_per_call": best[1] * 1e6}
        rows.append([f"kernel/autotune/{kern}_best_us", best[1] * 1e6,
                     0.0])
        print(f"# autotune {kern} [{backend}/{mode}]: {best[0]} "
              f"({best[1] * 1e6:.1f}us)")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-out", default=None,
                    help="also write rows + registry capability report "
                         "as JSON (CI artifact)")
    ap.add_argument("--modes", nargs="+", default=None,
                    choices=["ref", "interpret", "pallas"],
                    help="restrict the dispatch-mode sweep")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep quant_matmul / weight_transform block "
                         "sizes on this backend and persist the winner "
                         "into the JSON artifact (reload with "
                         "repro.configs.shapes.load_autotuned)")
    return run(ap.parse_args(argv))


if __name__ == "__main__":
    main()
