"""Fig. 8 analogue: trace-driven platform replay — cold/warm mix and
per-strategy mean latency under the bursty Azure-like workload, plus

  * a concurrency sweep (serial seed-style replay vs ≥4 in-flight
    requests through the Router's worker pool),
  * a scale-out sweep for the node-local WeightCache: cold-baseline vs
    warm-cache cold-start latency, and single-flight reads under
    concurrent scale-out of one model, and
  * ``--workload generate``: the generation-first serving path —
    TTFT p50/p99, TPOT and aggregate tokens/s at concurrency {1, 4, 8}
    through one instance's continuous-batching DecodeScheduler, against
    a serial per-request prefill+decode baseline; plus a cold
    generation request whose first token must land inside the loading
    pipeline (before the final E event completes).

``--mesh`` sweeps shard-granular cold starts over simulated device
meshes of 1 / 2 / 4 (λScale-style: every device brings its own
``--bandwidth-mbps`` store channel) and reports the critical-path load
time per mesh size — the BENCH_sharded.json artifact.  ``--quant
int8`` runs the same sweep from an int8-quantized deployment: the
shard streams carry value+scale slices and the placement lanes run the
per-shard ``weight_transform`` dequant before each commit (the
BENCH_sharded_int8.json artifact).

``--workload slo`` runs the open-loop SLO bench (benchmarks/load_gen):
a mixed one-shot + generation Poisson workload with a 10x burst phase,
replayed twice from the same arrival schedule — once against a bare
platform (``slo/noscale/*`` rows) and once with the Autoscaler
pre-provisioning warm instances off the arrival-rate slope
(``slo/autoscale/*`` rows) — reporting client-perceived p99 TTFT and
per-class SLO attainment, plus the noscale/autoscale improvement ratio
(the BENCH_slo.json artifact).

``--workload cluster`` runs the multi-node bench (repro.cluster): an
all-nodes simultaneous cold-start burst at ``--nodes`` {1, 2, 4} with
peer-to-peer shard exchange over the fast intra-cluster link
(``--cluster-bw-mbps``), against the same burst with cluster-blind
nodes that each re-read the slow shared origin
(``--cluster-origin-mbps``) — plus a two-node phase proving the second
node's cold start is served entirely by its peer (zero origin reads).
The BENCH_cluster.json artifact.

``--pallas {auto,pallas,interpret,ref}`` forces the kernel dispatch
registry (default: auto — capability-probed per kernel).

Every ``--json-out`` artifact is validated against benchmarks/schema.py
before it is written (CI re-validates the files in bench-smoke).

Run directly for CI's bench-smoke job:

    PYTHONPATH=src:. python benchmarks/trace_bench.py --quick \
        --invocations 8 --json-out BENCH_trace.json
    PYTHONPATH=src:. python benchmarks/trace_bench.py --quick \
        --workload generate --models smollm-360m \
        --json-out BENCH_generate.json
    PYTHONPATH=src:. python benchmarks/trace_bench.py --quick --mesh \
        --bandwidth-mbps 200 --json-out BENCH_sharded.json
    PYTHONPATH=src:. python benchmarks/trace_bench.py --quick \
        --workload slo --models smollm-360m --json-out BENCH_slo.json
    PYTHONPATH=src:. python benchmarks/trace_bench.py --quick \
        --workload cluster --nodes 1 2 4 --json-out BENCH_cluster.json
"""
from __future__ import annotations

import json
import os
import sys
import time

# Must precede the jax import (jax locks the device count on first
# init): the --mesh sweep simulates a 4-device host mesh on CPU.
if "--mesh" in sys.argv and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import numpy as np

from benchmarks import common, schema
from repro.serving.api import GenerateSpec, Request
from repro.serving.decode import reference_generate
from repro.serving.engine import ServerlessPlatform
from repro.serving.trace import Invocation, azure_like_trace, summarize


def _replay(store, models, args, trace, strat, *, concurrency=1,
            max_instances=1, keep_alive_s=45.0, cache_budget_bytes=None):
    builders = {}
    for name in models:
        cfg, model = common.get_model(name, args.quick)
        builders[name] = (lambda m=model, c=cfg:
                          (m, common.make_batch(c)))
    platform = ServerlessPlatform(store, builders, strategy=strat,
                                  keep_alive_s=keep_alive_s,
                                  max_instances=max_instances,
                                  cache_budget_bytes=cache_budget_bytes)
    rs = platform.run_trace(trace,
                            lambda n: common.make_batch(
                                common.get_model(n, args.quick)[0]),
                            concurrency=concurrency)
    return rs, platform


def scaleout_sweep(store, models, args, *, n_instances=2):
    """Cold vs warm-cache cold starts under the shared WeightCache.

    Phase rows (keep-alive expires between two invocations, so both
    are cold starts; with the cache the second one's retrieval is
    all hits):
      recold_nocache — second cold start, no cache (baseline: full re-read)
      recold_cache   — second cold start, warm cache (~zero retrieval)
    Concurrency rows (n_instances simultaneous cold starts of one
    model single-flight each unit's read):
      scaleout{N}_cold_mean + the cache's deduped-read count.
    """
    rows = []
    name = models[0]
    recold = {}
    for label, budget in (("nocache", None), ("cache", 0)):
        # 0 -> unbounded budget; None -> cache disabled
        tr = [Invocation(0.0, name, 0), Invocation(1000.0, name, 1)]
        rs, platform = _replay(store, [name], args, tr, "cicada",
                               keep_alive_s=10.0,
                               cache_budget_bytes=budget)
        assert [r.cold for r in rs] == [True, True]
        recold[label] = rs[1].latency_s
        rows.append([f"trace/cicada/recold_{label}", rs[1].latency_s * 1e6,
                     rs[0].latency_s * 1e6])
    if recold["cache"] > 0:
        rows.append(["trace/cicada/recold_speedup",
                     recold["nocache"] / recold["cache"], 0.0])
    # concurrent scale-out: n_instances cold starts at once, one store
    # read per unit node-wide
    tr = [Invocation(0.0, name, i) for i in range(n_instances)]
    rs, platform = _replay(store, [name], args, tr, "cicada",
                           concurrency=n_instances,
                           max_instances=n_instances,
                           cache_budget_bytes=0)
    lat = np.array([r.latency_s for r in rs])
    cs = platform.cache_stats()
    rows.append([f"trace/cicada/scaleout{n_instances}_cold_mean",
                 lat.mean() * 1e6, float(sum(r.cold for r in rs))])
    # every hit is a store read avoided (waits are the subset of hits
    # that blocked on a concurrent leader's in-flight read)
    rows.append([f"trace/cicada/scaleout{n_instances}_deduped_reads",
                 float(cs.hits), float(cs.misses)])
    return rows


def generate_run(args):
    """--workload generate: TTFT / TPOT / tokens-per-second rows.

    Rows (name, value, derived):
      generate/cold/ttft_ms            TTFT of a cold generation request;
                                       derived = load_s (ms) — TTFT must
                                       be smaller: first token produced
                                       inside the pipeline
      generate/cold/ttft_before_final_E 1.0 when the first-token
                                       timestamp precedes the final E
                                       event's completion in the trace
      generate/serial/tok_s            per-request serial prefill+decode
                                       baseline (reference_generate)
      generate/conc{N}/tok_s           aggregate through the Router at
                                       concurrency N, one instance
                                       (continuous batching); derived =
                                       max slot occupancy reached
      generate/conc{N}/ttft_p50_ms, ttft_p99_ms, tpot_ms
      generate/conc8/speedup_vs_serial aggregate tokens/s ratio
    """
    rows = []
    name = args.models[0]
    cfg, model = common.get_model(name, args.quick)
    if not hasattr(model, "decode_step"):
        raise SystemExit(
            f"--workload generate needs a decoder LM, got {name!r} "
            f"({cfg.family.value}); try --models smollm-360m")
    store, _ = common.deployed_store(args)
    common.ensure_deployed(store, name, args.quick)
    # enough decode steps for batching to amortize per-request
    # prefill/join overhead (short runs understate the steady state)
    n_new = args.n_new or (16 if args.quick else 32)
    prompt_len = args.prompt_len
    cache_len = max(64, prompt_len + n_new)
    rng = np.random.default_rng(0)

    def spec(i=0):
        return GenerateSpec(
            prompt=rng.integers(0, cfg.vocab_size,
                                (prompt_len,)).astype(np.int32),
            n_new=n_new, seed=i)

    def build_platform():
        return ServerlessPlatform(
            store, {name: (lambda: (model, common.make_batch(cfg)))},
            strategy="cicada", keep_alive_s=1e9, max_instances=1,
            gen_slots=8, gen_cache_len=cache_len)

    # ---- cold generation: TTFT inside the loading pipeline ----------------
    platform = build_platform()
    router = platform.router(workers=1)
    try:
        cold = router.submit(Request(req_id=0, model=name,
                                     gen=spec())).result()
    finally:
        router.shutdown()
    assert cold.cold
    inst = platform.pools[name]._instances[0]
    trace = inst.last_load.trace
    final_e_end = max(e.t_end for e in trace.events if e.stage == "E")
    # first-token absolute time = service start + ttft
    t_first_abs = cold.t_arrival + cold.ttft_s
    rows.append(["generate/cold/ttft_ms", cold.ttft_s * 1e3,
                 cold.load_s * 1e3])
    rows.append(["generate/cold/ttft_before_final_E",
                 float(t_first_abs <= final_e_end), 0.0])
    params = inst.params

    # ---- serial per-request baseline (B=1 prefill + decode loop) ----------
    n_req = args.gen_requests or (8 if args.quick else 16)
    reference_generate(model, params, spec(0).prompt, n_new=n_new,
                       cache_len=cache_len)          # jit warm
    t0 = time.monotonic()
    for i in range(n_req):
        reference_generate(model, params, spec(i).prompt, n_new=n_new,
                           cache_len=cache_len)
    serial_tok_s = n_req * n_new / (time.monotonic() - t0)
    rows.append(["generate/serial/tok_s", serial_tok_s, float(n_req)])

    # ---- continuous batching through the Router at concurrency {1,4,8} ----
    conc_tok_s = {}
    for conc in (1, 4, 8):
        router = platform.router(workers=conc)
        try:
            # warm the step/prefill compiles outside the timed window
            router.submit(Request(req_id=-1, model=name,
                                  gen=spec())).result()
            # report THIS level's peak occupancy, not the lifetime max
            inst.scheduler.reset_peaks()
            t0 = time.monotonic()
            futs = [router.submit(Request(req_id=i, model=name,
                                          gen=spec(i)))
                    for i in range(n_req)]
            rs = [f.result() for f in futs]
            wall = time.monotonic() - t0
        finally:
            router.shutdown()
        n_tok = sum(r.n_generated for r in rs)
        ttft = np.array([r.ttft_s for r in rs])
        tpot = np.concatenate([r.tpot_s for r in rs])
        occ = inst.scheduler.stats()["max_occupancy"]
        conc_tok_s[conc] = n_tok / wall
        rows.append([f"generate/conc{conc}/tok_s", n_tok / wall,
                     float(occ)])
        rows.append([f"generate/conc{conc}/ttft_p50_ms",
                     np.percentile(ttft, 50) * 1e3, 0.0])
        rows.append([f"generate/conc{conc}/ttft_p99_ms",
                     np.percentile(ttft, 99) * 1e3, 0.0])
        rows.append([f"generate/conc{conc}/tpot_ms",
                     tpot.mean() * 1e3, 0.0])
    rows.append(["generate/conc8/speedup_vs_serial",
                 conc_tok_s[8] / serial_tok_s, 0.0])
    if getattr(args, "compute_quant", False):
        rows += _quant_generate_rows(args, cfg, model, name, store, spec,
                                     n_req, cache_len, conc_tok_s[8])
    if getattr(args, "compute_paged", False):
        rows += _paged_generate_rows(args, cfg, model, name, store, spec,
                                     n_req, cache_len)
    return rows


def _quant_generate_rows(args, cfg, model, name, store, spec, n_req,
                         cache_len, f32_tok_s):
    """--compute-quant rows: quantized-resident serving vs the f32 path.

    Rows (name, value, derived):
      generate/quant/tok_s           conc8 aggregate tokens/s from the
                                     quantized-resident instance;
                                     derived = max slot occupancy
      generate/quant/tok_s_vs_f32    ratio vs the f32 conc8 run
                                     (gated >= 0.9: fused dequant must
                                     not tank decode throughput)
      generate/quant/resident_ratio  f32 / quant WeightCache resident
                                     bytes after one cold start each
                                     (gated >= 1.66, i.e. quant <= 0.6x
                                     f32); derived = quant bytes
      generate/quant/params_ratio    f32 / quant instance param bytes
                                     (QuantLeaf residency on-device);
                                     derived = quant param bytes
    """
    import jax
    from repro.quant import QuantLeaf
    from repro.store.store import deploy_model

    qname = f"{name}-int8"
    if not store.has_model(qname):
        deploy_model(store, model, qname, jax.random.key(0), quant="int8")

    def build(nm, cq):
        # unbounded caches so resident bytes reflect the full artifact
        return ServerlessPlatform(
            store, {nm: (lambda: (model, common.make_batch(cfg)))},
            strategy="cicada", keep_alive_s=1e9, max_instances=1,
            gen_slots=8, gen_cache_len=cache_len,
            cache_budget_bytes=0, compute_quant=cq)

    def param_bytes(tree):
        return sum(l.nbytes for l in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda l: isinstance(l, QuantLeaf)))

    rows = []
    qp = build(qname, True)
    router = qp.router(workers=8)
    q_tok_s = 0.0
    try:
        router.submit(Request(req_id=-1, model=qname,
                              gen=spec())).result()     # cold + jit warm
        inst = qp.pools[qname]._instances[0]
        inst.scheduler.reset_peaks()
        # best of two rounds: the f32 conc8 number this compares against
        # ran after two earlier concurrency levels (fully warm), so a
        # single round here would eat the remaining warmup noise
        for rnd in range(2):
            t0 = time.monotonic()
            futs = [router.submit(Request(req_id=rnd * n_req + i,
                                          model=qname, gen=spec(i)))
                    for i in range(n_req)]
            rs = [f.result() for f in futs]
            wall = time.monotonic() - t0
            q_tok_s = max(q_tok_s, sum(r.n_generated for r in rs) / wall)
    finally:
        router.shutdown()
    occ = inst.scheduler.stats()["max_occupancy"]
    q_cache = qp.cache_stats().bytes_cached
    q_params = param_bytes(inst.params)

    fp = build(name, False)
    router = fp.router(workers=1)
    try:
        router.submit(Request(req_id=0, model=name, gen=spec())).result()
    finally:
        router.shutdown()
    f_cache = fp.cache_stats().bytes_cached
    f_params = param_bytes(fp.pools[name]._instances[0].params)

    rows.append(["generate/quant/tok_s", q_tok_s, float(occ)])
    rows.append(["generate/quant/tok_s_vs_f32", q_tok_s / f32_tok_s, 0.0])
    rows.append(["generate/quant/resident_ratio", f_cache / q_cache,
                 float(q_cache)])
    rows.append(["generate/quant/params_ratio", f_params / q_params,
                 float(q_params)])
    return rows


def _paged_generate_rows(args, cfg, model, name, store, spec, n_req,
                         cache_len):
    """--compute-paged rows: block-paged KV serving vs the slotted path.

    Rows (name, value, derived):
      generate/paged/tok_s             conc8 aggregate tokens/s through
                                       a paged-KV instance (16-token
                                       pages, default byte budget ==
                                       the slotted arena's capacity);
                                       derived = max slot occupancy
      generate/paged/tok_s_vs_slotted  ratio vs a *contemporaneous*
                                       slotted twin — rounds interleave
                                       slotted/paged so host-load drift
                                       cancels (the earlier conc8
                                       number ran minutes before);
                                       derived = the twin's tokens/s.
                                       Gated >= 0.9: the paged decode
                                       kernel + page bookkeeping must
                                       not tank decode throughput
      generate/paged/prefix_ttft_ms    TTFT of a request whose 960-token
                                       prefix is already resident in
                                       the prefix cache (prefill covers
                                       only the 64-token suffix);
                                       derived = its cold twin's TTFT
      generate/paged/prefix_ttft_speedup
                                       cold-twin TTFT / prefix-hit TTFT
                                       (gated >= 2.0: the paper-regime
                                       win of skipping shared-prefix
                                       prefill); derived = cumulative
                                       prefix-hit pages
      generate/paged/long_prompt_admitted
                                       1.0 when a prompt longer than the
                                       slotted per-slot arena admits and
                                       completes under the *same* byte
                                       budget (pages flex across mixed
                                       lengths); derived = pages needed
    """
    rows = []

    def build(cl, pt, *, slots=8, budget=None):
        return ServerlessPlatform(
            store, {name: (lambda: (model, common.make_batch(cfg)))},
            strategy="cicada", keep_alive_s=1e9, max_instances=1,
            gen_slots=slots, gen_cache_len=cl,
            kv_page_tokens=pt, kv_budget_bytes=budget)

    # ---- conc8 tokens/s: paged decode vs a contemporaneous slotted twin ---
    sp = ServerlessPlatform(
        store, {name: (lambda: (model, common.make_batch(cfg)))},
        strategy="cicada", keep_alive_s=1e9, max_instances=1,
        gen_slots=8, gen_cache_len=cache_len)
    pp = build(cache_len, 16)
    s_router = sp.router(workers=8)
    p_router = pp.router(workers=8)
    s_tok_s = p_tok_s = 0.0
    try:
        for router in (s_router, p_router):             # cold + jit warm
            router.submit(Request(req_id=-1, model=name,
                                  gen=spec())).result()
        inst = pp.pools[name]._instances[0]
        inst.scheduler.reset_peaks()

        def round_(router, rnd):
            t0 = time.monotonic()
            futs = [router.submit(Request(req_id=rnd * n_req + i,
                                          model=name, gen=spec(i)))
                    for i in range(n_req)]
            rs = [f.result() for f in futs]
            return sum(r.n_generated for r in rs) / \
                (time.monotonic() - t0)

        # interleave slotted/paged rounds so host-load drift hits both
        # sides of the ratio equally; best-of-two each
        for rnd in range(2):
            s_tok_s = max(s_tok_s, round_(s_router, rnd))
            p_tok_s = max(p_tok_s, round_(p_router, rnd))
    finally:
        s_router.shutdown()
        p_router.shutdown()
    occ = inst.scheduler.stats()["max_occupancy"]
    rows.append(["generate/paged/tok_s", p_tok_s, float(occ)])
    rows.append(["generate/paged/tok_s_vs_slotted",
                 p_tok_s / s_tok_s, s_tok_s])

    # ---- prefix-cache TTFT: shared 960-token prefix, 64-token suffix ------
    pt2, n_pfx, n_sfx = 64, 960, 64
    rngp = np.random.default_rng(7)

    def pspec(prefix, seed):
        sfx = rngp.integers(0, cfg.vocab_size, (n_sfx,)).astype(np.int32)
        return GenerateSpec(prompt=np.concatenate([prefix, sfx]),
                            n_new=4, seed=seed)

    def pfx():
        return rngp.integers(0, cfg.vocab_size,
                             (n_pfx,)).astype(np.int32)

    fp = build(n_pfx + n_sfx + 16, pt2, slots=2)
    router = fp.router(workers=1)
    try:
        # warm both compile paths outside the timed pairs: full prefill
        # (cold miss) and gather + prefill-continue at off == n_pfx
        w = pfx()
        router.submit(Request(req_id=-1, model=name,
                              gen=pspec(w, 0))).result()
        router.submit(Request(req_id=-2, model=name,
                              gen=pspec(w, 0))).result()
        colds, warms = [], []
        for k in range(2):          # best of two cold/warm pairs
            prefix = pfx()
            rc = router.submit(Request(req_id=2 * k, model=name,
                                       gen=pspec(prefix, k))).result()
            rw = router.submit(Request(req_id=2 * k + 1, model=name,
                                       gen=pspec(prefix, k))).result()
            colds.append(rc.ttft_s)
            warms.append(rw.ttft_s)
        sched = fp.pools[name]._instances[0].scheduler
        hits = sched.kvpool.stats().prefix_hits
    finally:
        router.shutdown()
    cold_ms, warm_ms = min(colds) * 1e3, min(warms) * 1e3
    rows.append(["generate/paged/prefix_ttft_ms", warm_ms, cold_ms])
    rows.append(["generate/paged/prefix_ttft_speedup", cold_ms / warm_ms,
                 float(hits)])

    # ---- mixed admission: beyond the per-slot arena, same byte budget -----
    pt3 = 16
    budget = 8 * (-(-cache_len // pt3)) * model.kv_page_bytes(pt3)
    mp = build(2 * cache_len, pt3, budget=budget)
    long_prompt = np.random.default_rng(11).integers(
        0, cfg.vocab_size, (cache_len + cache_len // 2,)).astype(np.int32)
    router = mp.router(workers=1)
    try:
        r = router.submit(Request(req_id=0, model=name,
                                  gen=GenerateSpec(prompt=long_prompt,
                                                   n_new=8))).result()
    finally:
        router.shutdown()
    need = -(-(long_prompt.shape[0] + 8) // pt3)
    rows.append(["generate/paged/long_prompt_admitted",
                 1.0 if r.n_generated == 8 else 0.0, float(need)])
    return rows


def slo_run(args):
    """--workload slo: open-loop SLO attainment under a 10x burst.

    The same Poisson arrival schedule (trickle phase, then a 10x burst)
    is replayed twice through benchmarks/load_gen's open loop — mixed
    generation + one-shot classes, each with a client-perceived SLO
    target measured from submit:

      noscale    bare platform; the burst's scale-out cold starts run
                 on the request path and land in p99 TTFT
      autoscale  the Autoscaler observes the trickle, pre-provisions
                 warm instances off the arrival-rate slope, and the
                 burst finds them ready

    Jit compilation is warmed (and the pool scaled back to cold)
    before each measured run, and the store is re-wrapped at
    ``--slo-bandwidth-mbps`` so a cold start costs what it costs in
    the paper's regime instead of vanishing into this host's page
    cache with smoke-size weights.

    Rows (name, value, derived):
      slo/{v}/attainment        fraction of *scheduled* requests meeting
                                their class SLO (rejected/failed = miss);
                                derived = n scheduled
      slo/{v}/ttft_p50_ms       client TTFT (queue + service first token)
      slo/{v}/ttft_p99_ms       derived = cold-served request count
      slo/{v}/oneshot_p99_ms    one-shot client latency p99; derived = n
      slo/autoscale/prewarms    off-path provisioning runs; derived =
                                live instances at drain
      slo/improvement/p99_ttft_ratio
                                noscale p99 TTFT / autoscale p99 TTFT
                                (>1: the autoscaler moved the tail);
                                derived = attainment delta
    """
    from benchmarks import load_gen as lg
    from repro.store.store import BandwidthModel, WeightStore

    rows = []
    name = args.models[0]
    cfg, model = common.get_model(name, args.quick)
    if not hasattr(model, "decode_step"):
        raise SystemExit(
            f"--workload slo needs a decoder LM, got {name!r} "
            f"({cfg.family.value}); try --models smollm-360m")
    store, root = common.deployed_store(args)
    common.ensure_deployed(store, name, args.quick)
    slow = WeightStore(root, BandwidthModel(args.slo_bandwidth_mbps, 0.2))

    n_new = args.n_new or 8
    prompt_len = args.prompt_len
    cache_len = max(64, prompt_len + n_new)
    max_inst = 4
    base_rps = 1.5
    phases = [(3.0, base_rps), (2.0, 10.0 * base_rps)]
    # between warm service (~15ms TTFT / ~1ms one-shot) and an on-path
    # cold start (~150ms+ at the slo bandwidth): warm requests pass,
    # requests that pay a cold start or deep queueing miss
    classes = [lg.LoadClass("gen", weight=0.75, gen=True, slo_s=0.075),
               lg.LoadClass("oneshot", weight=0.25, gen=False,
                            slo_s=0.075)]

    def spec(i):
        rng = np.random.default_rng(max(i, 0) + 1)
        return GenerateSpec(
            prompt=rng.integers(0, cfg.vocab_size,
                                (prompt_len,)).astype(np.int32),
            n_new=n_new, seed=max(i, 0))

    def make_batch():
        return common.make_batch(cfg)

    reports = {}
    for tag, autoscale in (
            ("noscale", None),
            # budget 0.4 rps/instance so the trickle alone justifies a
            # full pool; horizon ~ a few cold starts ahead; scale-in
            # disabled (the run is shorter than any idle window)
            ("autoscale", dict(rps_per_instance=0.4, window_s=4.0,
                               horizon_s=2.0, queue_per_instance=4,
                               idle_scale_in_s=1e9, interval_s=0.1,
                               max_prewarm_workers=3))):
        rng = np.random.default_rng(0)      # same schedule both runs
        arrivals = lg.poisson_arrivals(phases, rng)
        platform = ServerlessPlatform(
            slow, {name: (lambda: (model, common.make_batch(cfg)))},
            strategy="cicada", keep_alive_s=1e9, max_instances=max_inst,
            gen_slots=2, gen_cache_len=cache_len, autoscale=autoscale)
        router = platform.router(workers=2 * max_inst)
        try:
            # compile prefill/step/assemble outside the measured window,
            # then evict back so both variants start from a cold pool
            router.submit(Request(req_id=-1, model=name,
                                  gen=spec(-1))).result()
            router.submit(Request(req_id=-2, model=name,
                                  batch=make_batch())).result()
            for _ in range(200):
                platform.pools[name].scale_in(0)
                if platform.pool_stats()[name].live == 0:
                    break
                time.sleep(0.01)
            assert platform.pool_stats()[name].live == 0
            if platform.autoscaler is not None:
                platform.autoscaler.start()
            recs = lg.run_open_loop(router.submit, name, arrivals,
                                    classes, spec, make_batch, rng)
        finally:
            if platform.autoscaler is not None:
                platform.autoscaler.stop()
            router.shutdown()
        rep = lg.slo_report(recs, classes)
        reports[tag] = rep
        ps = platform.pool_stats()[name]
        print(f"# slo/{tag}: n={rep['n']} ok={rep['n_ok']} "
              f"cold={rep['n_cold']} prewarms={ps.prewarms} "
              f"live={ps.live} attain={rep['attainment']:.2f} "
              f"ttft_p99={rep['ttft_p99_ms'] or 0.0:.1f}ms")
        rows.append([f"slo/{tag}/attainment", rep["attainment"],
                     float(rep["n"])])
        rows.append([f"slo/{tag}/ttft_p50_ms",
                     rep["ttft_p50_ms"] or 0.0, 0.0])
        rows.append([f"slo/{tag}/ttft_p99_ms",
                     rep["ttft_p99_ms"] or 0.0, float(rep["n_cold"])])
        rows.append([f"slo/{tag}/oneshot_p99_ms",
                     rep["oneshot/p99_ms"] or 0.0,
                     float(rep["oneshot/n"])])
        if tag == "autoscale":
            rows.append(["slo/autoscale/prewarms", float(ps.prewarms),
                         float(ps.live)])
    no, au = reports["noscale"], reports["autoscale"]
    if no["ttft_p99_ms"] and au["ttft_p99_ms"]:
        rows.append(["slo/improvement/p99_ttft_ratio",
                     no["ttft_p99_ms"] / au["ttft_p99_ms"],
                     au["attainment"] - no["attainment"]])
    return rows


def cluster_run(args):
    """--workload cluster: multi-node scale-out bursts over the
    peer-exchange tier vs cluster-blind origin re-reads.

    Every platform is warmed once (jit compile) and flushed back to
    cold before its measured burst, the origin store is re-wrapped at
    ``--cluster-origin-mbps`` on a single shared channel (the slow
    pipe all nodes contend on), and the intra-cluster link runs at
    ``--cluster-bw-mbps`` with one channel per node.

    Rows (name, value, derived):
      cluster/nodes{n}/burst_ms     wall time of n simultaneous cold
                                    starts (one per node) with peer
                                    exchange on; derived = origin-store
                                    reads the burst performed (the
                                    cluster-wide single-flight should
                                    hold it at one per shard regardless
                                    of n)
      cluster/nodes{n}/origin_burst_ms
                                    the same burst with cluster-blind
                                    nodes (peer exchange off): every
                                    node re-reads the shared origin;
                                    derived = origin reads (~n per
                                    shard)
      cluster/peer_vs_origin/speedup
                                    origin_burst / burst at the largest
                                    n — the paper-regime win of moving
                                    scale-out bytes onto the cluster
                                    link; derived = that n
      cluster/second_node/zero_origin_reads
                                    1.0 when a second node's cold start
                                    of an already-landed model touched
                                    the origin store zero times;
                                    derived = its peer-read count
      cluster/second_node/load_ms   that peer-served cold start's
                                    pipeline time; derived = the
                                    leader's origin-read load_ms
    """
    from repro.cluster import ClusterPlatform
    from repro.store.store import BandwidthModel, WeightStore

    rows = []
    name = args.models[0]
    cfg, model = common.get_model(name, args.quick)
    store, root = common.deployed_store(args)
    common.ensure_deployed(store, name, args.quick)
    batch = common.make_batch(cfg)
    builders = {name: (lambda: (model, batch))}

    def build(n, peer):
        # fresh BandwidthModel per platform: no token-bucket backlog
        # leaks from one measured burst into the next
        slow = WeightStore(root, BandwidthModel(args.cluster_origin_mbps,
                                                0.2))
        return ClusterPlatform(slow, builders, n_nodes=n,
                               cluster_bw_mbps=args.cluster_bw_mbps,
                               peer_exchange=peer,
                               keep_alive_s=1e9, max_instances=1)

    def origin_count(cp):
        """Origin-store reads so far: the peer tier's counter when it
        exists, else every cache miss was an origin read (cluster-blind
        baseline)."""
        if cp.nodes[0].source is not None:
            return sum(nd.origin_reads() for nd in cp.nodes)
        return sum(nd.metrics.counter("weight_cache/misses").value
                   for nd in cp.nodes)

    def burst(cp):
        """Simultaneous cold start on every node (jit warmed, cluster
        flushed): wall seconds, responses, origin-read count."""
        router = cp.router(workers_per_node=2)
        try:
            # warm EVERY node's instance (each container jit-compiles
            # its own forward) outside the timed window, then flush —
            # eviction keeps the instance objects and their compiles
            for i, nd in enumerate(cp.nodes):
                router.submit_to(nd.node_id,
                                 Request(req_id=-(i + 1), model=name,
                                         batch=batch)).result()
            cp.flush()
            o0 = origin_count(cp)
            t0 = time.monotonic()
            futs = [router.submit_to(nd.node_id,
                                     Request(req_id=i, model=name,
                                             batch=batch))
                    for i, nd in enumerate(cp.nodes)]
            rs = [f.result() for f in futs]
            wall = time.monotonic() - t0
        finally:
            router.shutdown()
        origin = origin_count(cp) - o0
        assert all(r.cold for r in rs), "burst must be all cold starts"
        return wall, rs, origin

    n_max = max(args.nodes)
    peer_wall = {}
    for n in sorted(args.nodes):
        wall, _, origin = burst(build(n, True))
        peer_wall[n] = wall
        rows.append([f"cluster/nodes{n}/burst_ms", wall * 1e3,
                     float(origin)])
    wall, _, origin = burst(build(n_max, False))
    rows.append([f"cluster/nodes{n_max}/origin_burst_ms", wall * 1e3,
                 float(origin)])
    rows.append(["cluster/peer_vs_origin/speedup",
                 wall / peer_wall[n_max], float(n_max)])

    # ---- second node cold-starts an already-landed model ------------------
    cp = build(2, True)
    router = cp.router(workers_per_node=2)
    try:
        for i, nd in enumerate(cp.nodes):
            router.submit_to(nd.node_id,
                             Request(req_id=-(i + 1), model=name,
                                     batch=batch)).result()
        cp.flush()
        r0 = router.submit_to("node0", Request(req_id=0, model=name,
                                               batch=batch)).result()
        r1 = router.submit_to("node1", Request(req_id=1, model=name,
                                               batch=batch)).result()
    finally:
        router.shutdown()
    second = cp.node("node1")
    assert r0.cold and r1.cold
    rows.append(["cluster/second_node/zero_origin_reads",
                 1.0 if second.origin_reads() == 0 else 0.0,
                 second.peer_reads()])
    rows.append(["cluster/second_node/load_ms", r1.load_s * 1e3,
                 r0.load_s * 1e3])
    return rows


def _mesh_tag(args) -> str:
    """Row prefix AND json bench name of the --mesh sweep (one source
    so the artifact's bench field can't drift from its rows)."""
    return "sharded_int8" if getattr(args, "quant", None) == "int8" \
        else "sharded"


def mesh_run(args):
    """--mesh: shard-granular cold starts on simulated meshes of
    1 / 2 / 4 devices (``--quant int8``: from a quantized deployment,
    with per-shard dequant on the placement lanes).

    Every mesh device brings its own ``--bandwidth-mbps`` store channel
    (``BandwidthModel(channels=n)``) — the λScale / HydraServe regime
    where aggregate load bandwidth scales with workers — so the
    critical-path load time of a cicada cold start should fall ~n-fold.

    Rows (name, load_ms, derived):
      sharded/mesh{n}/load_ms        end-to-end cold-start pipeline time,
                                     min of 3 warmed loads (this host's
                                     CPU-count dwarfs the simulated
                                     device count, so single-shot walls
                                     carry scheduler noise); derived =
                                     that load's retrieval-window ms
                                     (first R start -> last R end)
      sharded/mesh4_vs_mesh1/speedup load-time ratio (monotonicity +
                                     the >=2x acceptance row)
    """
    import dataclasses
    import tempfile

    import jax

    from repro.core import ColdStartEngine
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer
    from repro.models.api import get_config
    from repro.store.store import BandwidthModel, WeightStore, deploy_model

    quant = getattr(args, "quant", None)
    tag = _mesh_tag(args)
    # a mid-size LM (~155 MB f32 / ~40 MB int8) so retrieval dominates
    # the pipeline at 200 MB/s — every sharded axis divides 4 (no
    # replication fallback) and d_ff/4 int8 column runs clear the
    # byte-range floor (1024 B)
    cfg = dataclasses.replace(
        get_config("smollm-360m", smoke=True), name=f"{tag}-bench",
        n_layers=8, d_model=384, n_heads=4, n_kv_heads=4, d_ff=4096,
        vocab_size=12288)
    model = transformer.build(cfg)
    root = tempfile.mkdtemp(prefix=f"cicada-{tag}-bench-")
    deploy_model(WeightStore(root), model, cfg.name, jax.random.key(0),
                 quant=quant)
    batch = common.make_batch(cfg)

    rows = []
    load_ms = {}
    for n in (1, 2, 4):
        if n > jax.device_count():
            print(f"# mesh={n}: only {jax.device_count()} devices, "
                  f"skipping (set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count=4)")
            continue
        store = WeightStore(root, BandwidthModel(args.bandwidth_mbps, 0.2,
                                                 channels=n))
        mesh = make_serving_mesh((1, n)) if n > 1 else None
        eng = ColdStartEngine(model, cfg.name, store, strategy="cicada",
                              mesh=mesh)
        eng.warmup(batch)
        eng.load(batch)                   # warm assemble jit / put paths
        best = None
        for _ in range(3):
            res = eng.load(batch)
            if best is None or res.trace.total_time() < \
                    best.trace.total_time():
                best = res
        R = [e for e in best.trace.events if e.stage == "R"]
        r_window = max(e.t_end for e in R) - min(e.t_start for e in R)
        load_ms[n] = best.trace.total_time() * 1e3
        rows.append([f"{tag}/mesh{n}/load_ms", load_ms[n],
                     r_window * 1e3])
    if 1 in load_ms and 4 in load_ms:
        rows.append([f"{tag}/mesh4_vs_mesh1/speedup",
                     load_ms[1] / load_ms[4], 0.0])
    return rows


def run(args=None, n_invocations: int = 24, strategies=("pisel", "cicada"),
        concurrencies=(1, 4)):
    args = args or common.std_parser(models=["resnet50"]).parse_args([])
    n_invocations = getattr(args, "invocations", None) or n_invocations
    if getattr(args, "mesh", False):
        rows = mesh_run(args)
        common.print_csv(["name", "load_ms", "derived"], rows)
        _write_json(args, rows, _mesh_tag(args))
        return rows
    if getattr(args, "workload", "trace") == "generate":
        rows = generate_run(args)
        common.print_csv(["name", "value", "derived"], rows)
        _write_json(args, rows, "generate")
        return rows
    if getattr(args, "workload", "trace") == "slo":
        rows = slo_run(args)
        common.print_csv(["name", "value", "derived"], rows)
        _write_json(args, rows, "slo")
        return rows
    if getattr(args, "workload", "trace") == "cluster":
        rows = cluster_run(args)
        common.print_csv(["name", "value", "derived"], rows)
        _write_json(args, rows, "cluster")
        return rows
    rows = []
    store, _ = common.deployed_store(args)
    models = common.model_list(args)
    for name in models:
        common.ensure_deployed(store, name, args.quick)
    trace = azure_like_trace(duration_s=240.0, n_invocations=n_invocations,
                             models=models, seed=0)
    print(f"# trace: {summarize(trace)}")
    for strat in strategies:
        rs, _ = _replay(store, models, args, trace, strat)
        lat = np.array([r.latency_s for r in rs])
        cold = np.array([r.cold for r in rs])
        rows.append([f"trace/{strat}/mean", lat.mean() * 1e6,
                     float(cold.mean())])
        rows.append([f"trace/{strat}/p99",
                     np.percentile(lat, 99) * 1e6, 0.0])
        if cold.any():
            rows.append([f"trace/{strat}/cold_mean",
                         lat[cold].mean() * 1e6, int(cold.sum())])
    # concurrency sweep: same trace, Router worker pool + pool scale-out
    for conc in concurrencies:
        if conc <= 1:
            continue
        rs, platform = _replay(store, models, args, trace, "cicada",
                               concurrency=conc, max_instances=conc)
        lat = np.array([r.latency_s for r in rs])
        q = np.array([r.queue_s for r in rs])
        st = platform.last_router_stats
        rows.append([f"trace/cicada/conc{conc}/mean", lat.mean() * 1e6,
                     float(st.max_in_flight)])
        rows.append([f"trace/cicada/conc{conc}/queue_mean",
                     q.mean() * 1e6, float(st.max_queue_depth)])
    # scale-out sweep: node-local WeightCache, cold vs warm-cache
    rows.extend(scaleout_sweep(store, models, args))
    common.print_csv(["name", "us_per_call", "derived"], rows)
    _write_json(args, rows, "trace")
    return rows


def _write_json(args, rows, bench: str):
    json_out = getattr(args, "json_out", None)
    if json_out:
        header = {"generate": ["name", "value", "derived"],
                  "slo": ["name", "value", "derived"],
                  "cluster": ["name", "value", "derived"],
                  "sharded": ["name", "load_ms", "derived"],
                  "sharded_int8": ["name", "load_ms", "derived"]}.get(
            bench, ["name", "us_per_call", "derived"])
        obj = {"bench": bench, "header": header,
               "rows": [[n, float(v), float(d)] for n, v, d in rows]}
        # catch a malformed artifact at the producer, not in CI
        schema.validate(obj, source=json_out)
        with open(json_out, "w") as f:
            json.dump(obj, f, indent=2)
        print(f"# wrote {json_out}")


def main(argv=None):
    ap = common.std_parser(models=["resnet50"])
    ap.add_argument("--invocations", type=int, default=None,
                    help="trace length (default 24)")
    ap.add_argument("--json-out", default=None,
                    help="also write rows as JSON (CI artifact)")
    ap.add_argument("--workload", default="trace",
                    choices=["trace", "generate", "slo", "cluster"],
                    help="trace: one-shot replay benches (default); "
                         "generate: continuous-batching TTFT/TPOT/"
                         "tokens-per-second benches (LM model required, "
                         "e.g. --models smollm-360m); slo: open-loop "
                         "10x-burst SLO attainment, autoscaler on vs "
                         "off (LM model required); cluster: multi-node "
                         "scale-out bursts, peer shard exchange vs "
                         "origin re-reads")
    ap.add_argument("--nodes", type=int, nargs="+", default=[1, 2, 4],
                    help="--workload cluster: node counts to sweep")
    ap.add_argument("--cluster-bw-mbps", type=float, default=1000.0,
                    help="--workload cluster: intra-cluster link "
                         "bandwidth (one channel per node)")
    ap.add_argument("--cluster-origin-mbps", type=float, default=20.0,
                    help="--workload cluster: shared origin-store "
                         "bandwidth (single channel: the slow pipe)")
    ap.add_argument("--slo-bandwidth-mbps", type=float, default=5.0,
                    help="--workload slo: simulated store bandwidth for "
                         "the SLO runs (low, so a cold start has a "
                         "realistic cost relative to smoke-size "
                         "weights)")
    ap.add_argument("--n-new", type=int, default=None,
                    help="tokens per generation request "
                         "(default: 16 quick / 32 full)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-requests", type=int, default=None,
                    help="generation requests per concurrency level "
                         "(default: 8 quick / 16 full)")
    ap.add_argument("--mesh", action="store_true",
                    help="shard-granular cold-start sweep over device "
                         "meshes 1/2/4 (one store channel per device); "
                         "emits the BENCH_sharded.json rows")
    ap.add_argument("--quant", default=None, choices=["int8"],
                    help="deploy the --mesh sweep's model quantized: "
                         "shard streams carry value+scale slices and "
                         "placement lanes run the per-shard dequant")
    ap.add_argument("--compute-quant", action="store_true",
                    help="--workload generate: add quantized-resident "
                         "serving rows — an int8 deployment served with "
                         "compute_quant (QuantLeaf params + fused-"
                         "dequant quant_matmul), reporting tokens/s vs "
                         "f32 and the resident-bytes ratio")
    ap.add_argument("--compute-paged", action="store_true",
                    help="--workload generate: add block-paged KV "
                         "serving rows — tokens/s vs the slotted arena, "
                         "prefix-cache TTFT speedup on a shared "
                         "960-token prefix, and mixed-length admission "
                         "beyond the per-slot ceiling under the same "
                         "byte budget")
    ap.add_argument("--pallas", default=None,
                    choices=["auto", "pallas", "interpret", "ref"],
                    help="force the kernel dispatch registry (default: "
                         "capability-probed auto)")
    args = ap.parse_args(argv)
    if args.pallas:
        from repro.kernels import ops
        ops.set_mode(None if args.pallas == "auto" else args.pallas)
    return run(args)


if __name__ == "__main__":
    main()
