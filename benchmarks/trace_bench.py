"""Fig. 8 analogue: trace-driven platform replay — cold/warm mix and
per-strategy mean latency under the bursty Azure-like workload, plus a
concurrency sweep (serial seed-style replay vs ≥4 in-flight requests
through the Router's worker pool)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.serving.engine import ServerlessPlatform
from repro.serving.trace import azure_like_trace, summarize


def _replay(store, models, args, trace, strat, *, concurrency=1,
            max_instances=1):
    builders = {}
    for name in models:
        cfg, model = common.get_model(name, args.quick)
        builders[name] = (lambda m=model, c=cfg:
                          (m, common.make_batch(c)))
    platform = ServerlessPlatform(store, builders, strategy=strat,
                                  keep_alive_s=45.0,
                                  max_instances=max_instances)
    rs = platform.run_trace(trace,
                            lambda n: common.make_batch(
                                common.get_model(n, args.quick)[0]),
                            concurrency=concurrency)
    return rs, platform


def run(args=None, n_invocations: int = 24, strategies=("pisel", "cicada"),
        concurrencies=(1, 4)):
    args = args or common.std_parser(models=["resnet50"]).parse_args([])
    rows = []
    store, _ = common.deployed_store(args)
    models = common.model_list(args)
    for name in models:
        common.ensure_deployed(store, name, args.quick)
    trace = azure_like_trace(duration_s=240.0, n_invocations=n_invocations,
                             models=models, seed=0)
    print(f"# trace: {summarize(trace)}")
    for strat in strategies:
        rs, _ = _replay(store, models, args, trace, strat)
        lat = np.array([r.latency_s for r in rs])
        cold = np.array([r.cold for r in rs])
        rows.append([f"trace/{strat}/mean", lat.mean() * 1e6,
                     float(cold.mean())])
        rows.append([f"trace/{strat}/p99",
                     np.percentile(lat, 99) * 1e6, 0.0])
        if cold.any():
            rows.append([f"trace/{strat}/cold_mean",
                         lat[cold].mean() * 1e6, int(cold.sum())])
    # concurrency sweep: same trace, Router worker pool + pool scale-out
    for conc in concurrencies:
        if conc <= 1:
            continue
        rs, platform = _replay(store, models, args, trace, "cicada",
                               concurrency=conc, max_instances=conc)
        lat = np.array([r.latency_s for r in rs])
        q = np.array([r.queue_s for r in rs])
        st = platform.last_router_stats
        rows.append([f"trace/cicada/conc{conc}/mean", lat.mean() * 1e6,
                     float(st.max_in_flight)])
        rows.append([f"trace/cicada/conc{conc}/queue_mean",
                     q.mean() * 1e6, float(st.max_queue_depth)])
    common.print_csv(["name", "us_per_call", "derived"], rows)
    return rows


if __name__ == "__main__":
    run()
