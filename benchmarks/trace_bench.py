"""Fig. 8 analogue: trace-driven platform replay — cold/warm mix and
per-strategy mean latency under the bursty Azure-like workload."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.serving.engine import ServerlessPlatform
from repro.serving.trace import azure_like_trace, summarize


def run(args=None, n_invocations: int = 24, strategies=("pisel", "cicada")):
    args = args or common.std_parser(models=["resnet50"]).parse_args([])
    store, _ = common.deployed_store(args)
    rows = []
    models = common.model_list(args)
    for name in models:
        common.ensure_deployed(store, name, args.quick)
    trace = azure_like_trace(duration_s=240.0, n_invocations=n_invocations,
                             models=models, seed=0)
    print(f"# trace: {summarize(trace)}")
    for strat in strategies:
        builders = {}
        for name in models:
            cfg, model = common.get_model(name, args.quick)
            builders[name] = (lambda m=model, c=cfg:
                              (m, common.make_batch(c)))
        platform = ServerlessPlatform(store, builders, strategy=strat,
                                      keep_alive_s=45.0)
        rs = platform.run_trace(trace,
                                lambda n: common.make_batch(
                                    common.get_model(n, args.quick)[0]))
        lat = np.array([r.latency_s for r in rs])
        cold = np.array([r.cold for r in rs])
        rows.append([f"trace/{strat}/mean", lat.mean() * 1e6,
                     float(cold.mean())])
        rows.append([f"trace/{strat}/p99",
                     np.percentile(lat, 99) * 1e6, 0.0])
        if cold.any():
            rows.append([f"trace/{strat}/cold_mean",
                         lat[cold].mean() * 1e6, int(cold.sum())])
    common.print_csv(["name", "us_per_call", "derived"], rows)
    return rows


if __name__ == "__main__":
    run()
