"""Fig. 8 analogue: trace-driven platform replay — cold/warm mix and
per-strategy mean latency under the bursty Azure-like workload, plus

  * a concurrency sweep (serial seed-style replay vs ≥4 in-flight
    requests through the Router's worker pool), and
  * a scale-out sweep for the node-local WeightCache: cold-baseline vs
    warm-cache cold-start latency, and single-flight reads under
    concurrent scale-out of one model.

Run directly for CI's bench-smoke job:

    PYTHONPATH=src:. python benchmarks/trace_bench.py --quick \
        --invocations 8 --json-out BENCH_trace.json
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks import common
from repro.serving.engine import ServerlessPlatform
from repro.serving.trace import Invocation, azure_like_trace, summarize


def _replay(store, models, args, trace, strat, *, concurrency=1,
            max_instances=1, keep_alive_s=45.0, cache_budget_bytes=None):
    builders = {}
    for name in models:
        cfg, model = common.get_model(name, args.quick)
        builders[name] = (lambda m=model, c=cfg:
                          (m, common.make_batch(c)))
    platform = ServerlessPlatform(store, builders, strategy=strat,
                                  keep_alive_s=keep_alive_s,
                                  max_instances=max_instances,
                                  cache_budget_bytes=cache_budget_bytes)
    rs = platform.run_trace(trace,
                            lambda n: common.make_batch(
                                common.get_model(n, args.quick)[0]),
                            concurrency=concurrency)
    return rs, platform


def scaleout_sweep(store, models, args, *, n_instances=2):
    """Cold vs warm-cache cold starts under the shared WeightCache.

    Phase rows (keep-alive expires between two invocations, so both
    are cold starts; with the cache the second one's retrieval is
    all hits):
      recold_nocache — second cold start, no cache (baseline: full re-read)
      recold_cache   — second cold start, warm cache (~zero retrieval)
    Concurrency rows (n_instances simultaneous cold starts of one
    model single-flight each unit's read):
      scaleout{N}_cold_mean + the cache's deduped-read count.
    """
    rows = []
    name = models[0]
    recold = {}
    for label, budget in (("nocache", None), ("cache", 0)):
        # 0 -> unbounded budget; None -> cache disabled
        tr = [Invocation(0.0, name, 0), Invocation(1000.0, name, 1)]
        rs, platform = _replay(store, [name], args, tr, "cicada",
                               keep_alive_s=10.0,
                               cache_budget_bytes=budget)
        assert [r.cold for r in rs] == [True, True]
        recold[label] = rs[1].latency_s
        rows.append([f"trace/cicada/recold_{label}", rs[1].latency_s * 1e6,
                     rs[0].latency_s * 1e6])
    if recold["cache"] > 0:
        rows.append(["trace/cicada/recold_speedup",
                     recold["nocache"] / recold["cache"], 0.0])
    # concurrent scale-out: n_instances cold starts at once, one store
    # read per unit node-wide
    tr = [Invocation(0.0, name, i) for i in range(n_instances)]
    rs, platform = _replay(store, [name], args, tr, "cicada",
                           concurrency=n_instances,
                           max_instances=n_instances,
                           cache_budget_bytes=0)
    lat = np.array([r.latency_s for r in rs])
    cs = platform.cache_stats()
    rows.append([f"trace/cicada/scaleout{n_instances}_cold_mean",
                 lat.mean() * 1e6, float(sum(r.cold for r in rs))])
    # every hit is a store read avoided (waits are the subset of hits
    # that blocked on a concurrent leader's in-flight read)
    rows.append([f"trace/cicada/scaleout{n_instances}_deduped_reads",
                 float(cs.hits), float(cs.misses)])
    return rows


def run(args=None, n_invocations: int = 24, strategies=("pisel", "cicada"),
        concurrencies=(1, 4)):
    args = args or common.std_parser(models=["resnet50"]).parse_args([])
    n_invocations = getattr(args, "invocations", None) or n_invocations
    rows = []
    store, _ = common.deployed_store(args)
    models = common.model_list(args)
    for name in models:
        common.ensure_deployed(store, name, args.quick)
    trace = azure_like_trace(duration_s=240.0, n_invocations=n_invocations,
                             models=models, seed=0)
    print(f"# trace: {summarize(trace)}")
    for strat in strategies:
        rs, _ = _replay(store, models, args, trace, strat)
        lat = np.array([r.latency_s for r in rs])
        cold = np.array([r.cold for r in rs])
        rows.append([f"trace/{strat}/mean", lat.mean() * 1e6,
                     float(cold.mean())])
        rows.append([f"trace/{strat}/p99",
                     np.percentile(lat, 99) * 1e6, 0.0])
        if cold.any():
            rows.append([f"trace/{strat}/cold_mean",
                         lat[cold].mean() * 1e6, int(cold.sum())])
    # concurrency sweep: same trace, Router worker pool + pool scale-out
    for conc in concurrencies:
        if conc <= 1:
            continue
        rs, platform = _replay(store, models, args, trace, "cicada",
                               concurrency=conc, max_instances=conc)
        lat = np.array([r.latency_s for r in rs])
        q = np.array([r.queue_s for r in rs])
        st = platform.last_router_stats
        rows.append([f"trace/cicada/conc{conc}/mean", lat.mean() * 1e6,
                     float(st.max_in_flight)])
        rows.append([f"trace/cicada/conc{conc}/queue_mean",
                     q.mean() * 1e6, float(st.max_queue_depth)])
    # scale-out sweep: node-local WeightCache, cold vs warm-cache
    rows.extend(scaleout_sweep(store, models, args))
    common.print_csv(["name", "us_per_call", "derived"], rows)
    json_out = getattr(args, "json_out", None)
    if json_out:
        with open(json_out, "w") as f:
            json.dump({"bench": "trace",
                       "header": ["name", "us_per_call", "derived"],
                       "rows": rows}, f, indent=2)
        print(f"# wrote {json_out}")
    return rows


def main(argv=None):
    ap = common.std_parser(models=["resnet50"])
    ap.add_argument("--invocations", type=int, default=None,
                    help="trace length (default 24)")
    ap.add_argument("--json-out", default=None,
                    help="also write rows as JSON (CI artifact)")
    return run(ap.parse_args(argv))


if __name__ == "__main__":
    main()
