"""Fig. 14: pipeline timeline (Gantt) per model x strategy.

Renders the ASCII Gantt (rows: Layer / Retrieve / Weight / Compute) and
emits the raw rows as CSV for plotting.  The qualitative patterns to
look for (paper Sec. V-D): Mini shortens the Layer row; Preload/Cicada
add the overlapped Retrieve row and start Weight immediately after
Layer; Cicada's Compute row starts earliest.
"""
from __future__ import annotations

from benchmarks import common


def run(args=None):
    args = args or common.std_parser(
        models=["resnet50"], strategies=["pisel", "cicada"]
    ).parse_args([])
    store, _ = common.deployed_store(args)
    rows = []
    for name in common.model_list(args):
        for strat in args.strategies:
            res = common.load_with_strategy(store, name, strat, args.quick)
            tr = res.trace
            print(f"## {name} / {strat} "
                  f"(total {tr.total_time() * 1e3:.1f} ms, "
                  f"util {tr.utilization():.0%})")
            print(tr.render_gantt(90))
            for g in tr.gantt_rows():
                rows.append([f"fig14/{name}/{strat}/{g['row']}/{g['layer']}",
                             (g["end"] - g["start"]) * 1e6, g["start"] * 1e3])
    common.print_csv(["name", "us_per_call", "start_ms"], rows)
    return rows


if __name__ == "__main__":
    run(common.std_parser().parse_args())
