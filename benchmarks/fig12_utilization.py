"""Figs. 12-13: pipeline utilization (merged busy / total) and the
active-vs-total decomposition.

Paper claims: strategies with MiniLoader reach ~99%+ utilization vs
28-70% without — up to 2.52x — because PISeL's total pipeline time far
exceeds its active time.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run(args=None):
    args = args or common.std_parser().parse_args([])
    store, _ = common.deployed_store(args)
    rows = []
    utils = {}
    for name in common.model_list(args):
        for strat in args.strategies:
            res = common.load_with_strategy(store, name, strat, args.quick)
            tr = res.trace
            u = tr.utilization()
            utils.setdefault(strat, []).append(u)
            rows.append([f"fig12/{name}/{strat}", tr.total_time() * 1e6, u])
            rows.append([f"fig13/{name}/{strat}/active",
                         tr.busy_time() * 1e6, tr.busy_time() * 1e3])
    for s in args.strategies:
        if s in utils:
            print(f"# fig12 mean utilization [{s}]: "
                  f"{np.mean(utils[s]):.1%}")
    if "pisel" in utils and "cicada" in utils:
        speedup = np.mean(utils["cicada"]) / max(np.mean(utils["pisel"]),
                                                 1e-9)
        print(f"# fig12 utilization speedup cicada/pisel: {speedup:.2f}x "
              f"(paper: up to 2.52x)")
    common.print_csv(["name", "us_per_call", "value"], rows)
    return rows


if __name__ == "__main__":
    run(common.std_parser().parse_args())
