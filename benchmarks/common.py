"""Shared benchmark harness: deploy models, run strategies, CSV output.

Default model set is the paper's own trio (one per family:
ResNet-50 / VGG-16 / ViT-B-16) at full size; ``--sweep`` runs all ten
paper models; ``--quick`` uses smoke variants (CI).  The simulated
storage device (800 MB/s, 0.2 ms latency — cloud local-NVMe envelope)
makes the I/O phase visible where this container's page cache would
hide it (documented deviation; the byte copies still happen).
"""
from __future__ import annotations

import argparse
import os
import tempfile
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ColdStartEngine, LoadResult
from repro.models import transformer
from repro.models.api import get_config
from repro.store.store import BandwidthModel, WeightStore, deploy_model

PAPER_TRIO = ["resnet50", "vgg16", "vit_b_16"]
PAPER_ALL = ["resnet50", "resnet101", "resnet152",
             "vgg11", "vgg13", "vgg16", "vgg19",
             "vit_b_16", "vit_b_32", "vit_l_16"]
STRATEGIES = ["traditional", "pisel", "mini", "preload", "cicada"]

_STORE_CACHE: Dict[Tuple[str, bool], str] = {}


def std_parser(**defaults) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="+",
                    default=defaults.get("models", PAPER_TRIO))
    ap.add_argument("--sweep", action="store_true",
                    help="all 10 paper models")
    ap.add_argument("--quick", action="store_true",
                    help="smoke-size models (CI)")
    ap.add_argument("--strategies", nargs="+",
                    default=defaults.get("strategies", STRATEGIES))
    ap.add_argument("--bandwidth-mbps", type=float, default=400.0)
    ap.add_argument("--repeats", type=int,
                    default=defaults.get("repeats", 1))
    ap.add_argument("--store-dir", default=None)
    return ap


def model_list(args) -> List[str]:
    return PAPER_ALL if args.sweep else args.models


def make_batch(cfg):
    r = np.random.default_rng(0)
    if cfg.family.value == "vision":
        return {"image": jnp.asarray(
            r.standard_normal((1, 3, cfg.img_res, cfg.img_res)),
            jnp.float32)}
    return {"tokens": jnp.asarray(
        r.integers(0, cfg.vocab_size, (1, 32)), jnp.int32)}


def deployed_store(args) -> Tuple[WeightStore, str]:
    """Persistent across benchmark modules in one process run."""
    key = (args.store_dir or "default", args.quick)
    if key not in _STORE_CACHE:
        _STORE_CACHE[key] = args.store_dir or tempfile.mkdtemp(
            prefix="cicada-bench-")
    d = _STORE_CACHE[key]
    store = WeightStore(d, BandwidthModel(args.bandwidth_mbps, 0.2))
    return store, d


def get_model(name: str, quick: bool):
    cfg = get_config(name, smoke=quick)
    return cfg, transformer.build(cfg)


def ensure_deployed(store: WeightStore, name: str, quick: bool):
    cfg, model = get_model(name, quick)
    if not store.has_model(name):
        deploy_model(store, model, name, jax.random.key(0))
    return cfg, model


_ENGINE_CACHE: Dict[Tuple[str, str, bool], ColdStartEngine] = {}


def load_with_strategy(store: WeightStore, name: str, strategy: str,
                       quick: bool) -> LoadResult:
    cfg, model = ensure_deployed(store, name, quick)
    batch = make_batch(cfg)
    ck = (name, strategy, quick)
    if ck not in _ENGINE_CACHE:
        eng = ColdStartEngine(model, name, store, strategy=strategy)
        eng.warmup(batch)
        _ENGINE_CACHE[ck] = eng
    return _ENGINE_CACHE[ck].load(batch)


def print_csv(header: List[str], rows: List[List]):
    print(",".join(header))
    for r in rows:
        print(",".join(f"{v:.6g}" if isinstance(v, float) else str(v)
                       for v in r))
