"""Component ablation (the paper's Mini / Preload / Cicada decomposition)
on one model: which mechanism buys what.

    PYTHONPATH=src python examples/ablation_components.py
"""
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import ColdStartEngine  # noqa: E402
from repro.models import transformer  # noqa: E402
from repro.models.api import get_config  # noqa: E402
from repro.store.store import (BandwidthModel, WeightStore,  # noqa: E402
                               deploy_model)


def main():
    cfg = get_config("resnet50", smoke=True)
    model = transformer.build(cfg)
    store = WeightStore(tempfile.mkdtemp(),
                        BandwidthModel(bandwidth_mbps=300, latency_ms=0.3))
    deploy_model(store, model, "m", jax.random.key(0))
    batch = {"image": jnp.asarray(
        np.random.default_rng(0).standard_normal(
            (1, 3, cfg.img_res, cfg.img_res)), jnp.float32)}

    print(f"{'strategy':12s} {'e2e ms':>8s} {'util':>6s} {'L ms':>7s} "
          f"{'R ms':>7s} {'A ms':>7s} {'mem KB':>8s}")
    base = None
    for strat in ("traditional", "pisel", "mini", "preload", "cicada"):
        eng = ColdStartEngine(model, "m", store, strategy=strat)
        eng.warmup(batch)
        s = eng.load(batch).trace.summary()
        if strat == "pisel":
            base = s["total_s"]
        delta = "" if base is None or strat == "pisel" else \
            f"  ({1 - s['total_s'] / base:+.0%} vs pisel)"
        print(f"{strat:12s} {s['total_s'] * 1e3:8.1f} "
              f"{s['utilization']:6.0%} {s['work_L'] * 1e3:7.1f} "
              f"{s['work_R'] * 1e3:7.1f} {s['work_A'] * 1e3:7.1f} "
              f"{s['mem_overhead_bytes'] / 1e3:8.1f}{delta}")


if __name__ == "__main__":
    main()
