"""Train a ~100M-param llama-family model for a few hundred steps
(deliverable b: end-to-end training driver).

    PYTHONPATH=src python examples/train_smollm.py [--full]

Default trains a width-reduced SmolLM for 300 steps on the synthetic
Markov LM task (loss falls from ~ln V toward the bigram entropy floor);
--full uses the real smollm-360m config (slow on CPU).  Demonstrates:
sharded init, remat train step, microbatching, checkpoint + resume,
int8 error-feedback gradient compression.
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.train import main as train_main  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="cicada-train-")
    cli = ["--arch", "smollm-360m",
           "--steps", str(args.steps), "--seq", "128", "--batch", "8",
           "--lr", "3e-3", "--ckpt-dir", ckpt, "--ckpt-every", "100",
           "--compress-grads"]
    if not args.full:
        cli.append("--smoke")
    hist = train_main(cli)
    print(f"\ntrained {args.steps} steps; "
          f"loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}; "
          f"checkpoints in {ckpt}")
    # resume for 20 more steps from the checkpoint (restart-safety demo)
    train_main(cli[:-1] + ["--resume", "--steps", "20"])


if __name__ == "__main__":
    main()
