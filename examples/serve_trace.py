"""End-to-end serverless serving driver (deliverable b): replay a
bursty Azure-like trace against a multi-model platform, comparing
strategies.

    PYTHONPATH=src python examples/serve_trace.py [--full]

--full uses the paper's actual model sizes (ResNet-50 at 224x224 etc.)
— several minutes on CPU; default uses smoke variants.
"""
import argparse
import sys
import tempfile

import numpy as np

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    store = tempfile.mkdtemp(prefix="cicada-trace-")
    common = ["--models", "smollm-360m", "mamba2-780m-smoke"
              if False else "smollm-360m",
              "--invocations", "16", "--duration", "300",
              "--keep-alive", "20", "--store", store,
              "--bandwidth-mbps", "600"]
    if args.full:
        common += ["--full"]

    results = {}
    for strategy in ("pisel", "cicada"):
        print(f"\n===== strategy: {strategy} =====")
        responses = serve_main(common + ["--strategy", strategy])
        lat = np.array([r.latency_s for r in responses])
        results[strategy] = lat
    speedup = results["pisel"].mean() / results["cicada"].mean()
    print(f"\nmean-latency speedup cicada vs pisel: {speedup:.2f}x")


if __name__ == "__main__":
    main()
