"""Quickstart: deploy a model, cold-start it through the Cicada
pipeline, inspect the Gantt chart, then serve warm requests.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ColdStartEngine
from repro.models import transformer
from repro.models.api import get_config
from repro.store.store import BandwidthModel, WeightStore, deploy_model


def main():
    # 1. pick an architecture (any of the 10 assigned ids, or the paper's
    #    own resnet50/vgg16/vit_b_16 families) — smoke size for CPU
    cfg = get_config("smollm-360m", smoke=True)
    model = transformer.build(cfg)
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.2f}M params, "
          f"{cfg.n_layers} layers -> {len(model.unit_names())} pipeline "
          f"units)")

    # 2. publish it to a weight store (one extent per pipeline unit);
    #    the BandwidthModel simulates a cloud NVMe device
    store = WeightStore(tempfile.mkdtemp(),
                        BandwidthModel(bandwidth_mbps=400, latency_ms=0.2))
    deploy_model(store, model, "demo", jax.random.key(0))
    print(f"deployed: {store.model_nbytes('demo') / 1e6:.1f} MB across "
          f"{len(store.manifest('demo')['units'])} extents")

    # 3. a request arrives -> cold start through the Cicada pipeline
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 16)),
        jnp.int32)}
    engine = ColdStartEngine(model, "demo", store, strategy="cicada")
    engine.warmup(batch)                     # deploy-time jit snapshot
    result = engine.load(batch)

    print(f"\ncold start ({result.strategy}): "
          f"{result.trace.total_time() * 1e3:.1f} ms, "
          f"utilization {result.trace.utilization():.0%}")
    print(result.trace.render_gantt(80))

    # 4. compare against the PISeL baseline
    pisel = ColdStartEngine(model, "demo", store, strategy="pisel")
    pisel.warmup(batch)
    base = pisel.load(batch)
    print(f"\npisel baseline: {base.trace.total_time() * 1e3:.1f} ms, "
          f"utilization {base.trace.utilization():.0%}")
    print(base.trace.render_gantt(80))
    speedup = base.trace.total_time() / result.trace.total_time()
    print(f"\ncicada speedup vs pisel: {speedup:.2f}x")

    # 5. the assembled params serve warm requests directly
    logits, _ = model.forward(result.params, batch)
    same = np.allclose(np.asarray(logits, np.float32),
                       np.asarray(result.logits, np.float32), atol=1e-4)
    print(f"warm forward matches in-pipeline logits: {same}")


if __name__ == "__main__":
    main()
