"""Concurrent serving through the Router/InstancePool API (deliverable
of the serving-surface redesign): submit overlapping invocations of a
cold model, watch the pool scale out, keep-alive reclaim instances, and
the router dispatch inference-first — then the generation-first path:
overlapping GenerateSpec requests join one instance's
continuous-batching decode scheduler (a cold generation request's first
token is sampled inside the loading pipeline) — and finally a two-node
cluster scale-out: the second node cold-starts the model by streaming
every shard from its peer over the fast intra-cluster link, touching
the origin store zero times (repro.cluster).

    PYTHONPATH=src python examples/router_serving.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import sys
sys.path.insert(0, "src")

from repro.models import transformer                       # noqa: E402
from repro.models.api import get_config                    # noqa: E402
from repro.serving import (GenerateSpec, InstancePool,     # noqa: E402
                           KeepAliveTTL, Request, Router)
from repro.store.store import (BandwidthModel, WeightStore,  # noqa: E402
                               deploy_model)


def main():
    cfg = get_config("smollm-360m", smoke=True)
    model = transformer.build(cfg)
    store = WeightStore(tempfile.mkdtemp(),
                        BandwidthModel(bandwidth_mbps=400, latency_ms=0.2))
    deploy_model(store, model, "demo", jax.random.key(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 16)),
        jnp.int32)}

    # one pool, up to two containers, 30 s keep-alive on the caller's clock
    pool = InstancePool("demo", lambda: (model, batch), store,
                        strategy="cicada", policy=KeepAliveTTL(30.0),
                        max_instances=2)

    with Router({"demo": pool}, workers=4) as router:
        # four overlapping invocations of a cold function: the pool
        # scales to two instances (two pipelines), the rest are warm
        futs = [router.submit(Request(req_id=i, model="demo", batch=batch))
                for i in range(4)]
        for f in futs:
            r = f.result()
            print(f"req {r.req_id}: {'COLD' if r.cold else 'warm'}  "
                  f"class={r.cls.name}  latency={r.latency_s * 1e3:7.1f}ms  "
                  f"queue={r.queue_s * 1e3:6.1f}ms")
        print("router:", router.stats)

    st = pool.stats()
    print(f"pool: instances={st.size} live={st.live} "
          f"cold={st.cold_starts} warm={st.warm_hits}")

    # keep-alive: 31 s of idleness (logical clock) reclaims both
    n = pool.sweep(31.0)
    print(f"swept after 31 s idle: {n} evicted -> live={pool.stats().live}")

    # ---- generation-first path -------------------------------------------
    # Both instances were just evicted, so the first generation request
    # is cold: its first token is sampled inside the loading pipeline
    # (ttft < load time).  The following requests join the instance's
    # continuous decode batch instead of waiting for each other.
    rng = np.random.default_rng(1)
    with Router({"demo": pool}, workers=4) as router:
        futs = [router.submit(Request(
                    req_id=i, model="demo",
                    gen=GenerateSpec(
                        prompt=rng.integers(0, cfg.vocab_size,
                                            (16,)).astype(np.int32),
                        n_new=12)))
                for i in range(4)]
        for f in futs:
            r = f.result()
            tpot = 1e3 * sum(r.tpot_s) / max(len(r.tpot_s), 1)
            print(f"gen {r.req_id}: {'COLD' if r.cold else 'warm'}  "
                  f"ttft={r.ttft_s * 1e3:7.1f}ms  tpot={tpot:5.1f}ms  "
                  f"tokens={list(r.tokens)[:6]}...")
    inst = next(i for i in pool._instances if i.scheduler is not None)
    print("decode scheduler:", inst.scheduler.stats())

    # ---- two-node cluster scale-out --------------------------------------
    # A slow shared origin (20 MB/s) and a fast intra-cluster link:
    # node0 cold-starts from the origin and publishes every shard to
    # the placement table; node1's cold start of the same model streams
    # all of its shards from node0's cache — zero origin reads.
    from repro.cluster import ClusterPlatform                # noqa: E402

    slow = WeightStore(store.root,
                       BandwidthModel(bandwidth_mbps=20, latency_ms=0.2))
    cluster = ClusterPlatform(slow, {"demo": (lambda: (model, batch))},
                              n_nodes=2, cluster_bw_mbps=2000,
                              keep_alive_s=1e9)
    router = cluster.router(workers_per_node=2)
    try:
        r0 = router.submit_to("node0", Request(req_id=0, model="demo",
                                               batch=batch)).result()
        r1 = router.submit_to("node1", Request(req_id=1, model="demo",
                                               batch=batch)).result()
        # a routed (not pinned) warm request lands on a warm node
        r2 = router.submit(Request(req_id=2, model="demo",
                                   batch=batch)).result()
    finally:
        router.shutdown()
    n0, n1 = cluster.nodes
    print(f"cluster: node0 cold load={r0.load_s * 1e3:.1f}ms "
          f"(origin reads={n0.origin_reads():.0f})")
    print(f"         node1 cold load={r1.load_s * 1e3:.1f}ms "
          f"(origin reads={n1.origin_reads():.0f}, "
          f"peer reads={n1.peer_reads():.0f})  "
          f"<- served entirely by its peer")
    print(f"         warm request routed to {r2.node} "
          f"(locality-aware placement)")
    print("placement:", cluster.placement.snapshot())


if __name__ == "__main__":
    main()
