"""Cluster-scale serving: N simulated :class:`~repro.cluster.node.Node`
s behind a locality-aware front-end router, coordinated by one
:class:`~repro.cluster.placement.PlacementTable` and one fast
intra-cluster link for peer-to-peer shard exchange.

:class:`ClusterPlatform` is the multi-node sibling of
:class:`~repro.serving.engine.ServerlessPlatform` — the same surface
(``router`` / ``run_trace`` / ``sweep`` / ``metrics``), scaled out.
Every node runs the full single-node stack privately; the cluster adds
exactly three shared things:

  * the **placement table** — where every ``(model, unit, shard)``
    lives, with cluster-wide single-flight leader election so an
    N-node scale-out burst pays at most one origin read per shard;
  * the **cluster link** — one per-channel
    :class:`~repro.store.store.BandwidthModel` (channel = node NIC)
    that prices peer transfers at intra-cluster speeds, in contrast to
    the shared slow origin pipe;
  * the **front-end router** (:class:`ClusterRouter`) — places each
    request on the node already warm for the model, else the node whose
    cache holds the most of the model's shards (placement-table
    locality), else the least-loaded node by the live
    ``router/in_flight`` + ``router/queue_depth`` gauges of each node's
    PR-7 metrics surface.
"""
from __future__ import annotations

import time
from concurrent.futures import CancelledError, Future
from typing import Any, Callable, Dict, List, Optional

from repro.cluster.node import Node
from repro.cluster.placement import PlacementTable
from repro.serving.api import GenerateSpec, Request, Response
from repro.serving.router import _resolve
from repro.store.store import BandwidthModel, WeightStore


class ClusterRouter:
    """Locality-aware front end over one Router per node.

    ``submit`` scores every node and forwards to the winner's node-local
    Router; the returned Future resolves to the inner Response with
    ``Response.node`` stamped.  ``submit_to`` bypasses placement
    (benchmarks/tests that need a deterministic target node)."""

    def __init__(self, cluster: "ClusterPlatform", *,
                 workers_per_node: int = 4,
                 max_pending: Optional[int] = None):
        self.cluster = cluster
        self._routers = {
            node.node_id: node.router(workers=workers_per_node,
                                      max_pending=max_pending)
            for node in cluster.nodes}

    # ------------------------------------------------------------- placement
    def place(self, model: str) -> Node:
        """Pick the serving node: warm instance beats cache locality
        beats load; the node index breaks exact ties deterministically."""
        resident = self.cluster.placement.nodes_for_model(model)
        return min(
            self.cluster.nodes,
            key=lambda n: (0 if n.any_live(model) else 1,
                           -resident.get(n.node_id, 0),
                           n.load_score(),
                           n.index))

    # -------------------------------------------------------------- dispatch
    def submit(self, req: Request) -> "Future[Response]":
        return self.submit_to(self.place(req.model).node_id, req)

    def submit_to(self, node_id: str, req: Request) -> "Future[Response]":
        """Admit ``req`` on a specific node (admission errors surface
        here, on the submitting thread, exactly like Router.submit)."""
        inner = self._routers[node_id].submit(req)
        outer: "Future[Response]" = Future()

        def _done(f: "Future[Response]", nid=node_id):
            try:
                resp = f.result()
            except CancelledError:
                outer.cancel()
                return
            except BaseException as e:
                _resolve(outer, exc=e)
                return
            resp.node = nid
            _resolve(outer, result=resp)

        inner.add_done_callback(_done)
        return outer

    # --------------------------------------------------------------- queries
    def stats(self) -> Dict[str, Any]:
        """node id -> that node's RouterStats."""
        return {nid: r.stats for nid, r in self._routers.items()}

    def queue_depth(self) -> int:
        return sum(r.queue_depth() for r in self._routers.values())

    # -------------------------------------------------------------- shutdown
    def shutdown(self, wait: bool = True):
        for r in self._routers.values():
            r.shutdown(wait=wait)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False


class ClusterPlatform:
    """N-node serving platform: shared origin store, shared placement
    table, shared cluster link, one private serving stack per node."""

    def __init__(self, store: WeightStore,
                 builders: Dict[str, Callable[[], tuple]], *,
                 n_nodes: int = 2,
                 cluster_bw_mbps: float = 1000.0,
                 cluster_latency_ms: float = 0.1,
                 peer_exchange: bool = True,
                 cache_budget_bytes: int = 0,
                 chunk_bytes: int = 1 << 20,
                 **platform_kw):
        """``store``: the shared origin store — its BandwidthModel is
        the slow pipe all nodes contend on.  ``cluster_bw_mbps``: the
        intra-cluster link, one channel per node (0 -> unthrottled).
        ``peer_exchange=False``: nodes stay cluster-blind — every cold
        start reads the origin; the benchmark's baseline.  Per-node
        cache budget defaults to unbounded (0).  Remaining kwargs reach
        every node's ServerlessPlatform."""
        self.store = store
        self.placement = PlacementTable()
        self.link: Optional[BandwidthModel] = None
        if cluster_bw_mbps > 0:
            self.link = BandwidthModel(bandwidth_mbps=cluster_bw_mbps,
                                       latency_ms=cluster_latency_ms,
                                       channels=max(1, int(n_nodes)))
        self._by_id: Dict[str, Node] = {}
        self.nodes: List[Node] = []
        for i in range(max(1, int(n_nodes))):
            nid = f"node{i}"
            node = Node(nid, i, store, builders,
                        placement=self.placement, link=self.link,
                        resolve_peer=self._by_id.get,
                        cache_budget_bytes=cache_budget_bytes,
                        peer_exchange=peer_exchange,
                        chunk_bytes=chunk_bytes, **platform_kw)
            self._by_id[nid] = node
            self.nodes.append(node)
        self.last_router_stats = None   # per-node stats of the last replay

    # --------------------------------------------------------------- access
    def node(self, node_id: str) -> Node:
        return self._by_id[node_id]

    def router(self, *, workers_per_node: int = 4,
               max_pending: Optional[int] = None) -> ClusterRouter:
        """A live front-end router (caller shuts down)."""
        return ClusterRouter(self, workers_per_node=workers_per_node,
                             max_pending=max_pending)

    # ---------------------------------------------------------- maintenance
    def sweep(self, logical_now: float) -> int:
        """Keep-alive eviction on every node's pools; total reclaimed."""
        return sum(n.sweep(logical_now) for n in self.nodes)

    def flush(self):
        """Whole cluster back to cold (benchmarks): every node's
        instances and caches dropped, then any placement entries the
        per-node on-evict hooks didn't already withdraw."""
        for n in self.nodes:
            n.flush()
        self.placement.clear()

    # ------------------------------------------------------------- snapshot
    _AGG_COUNTERS = ("router/submitted", "router/completed",
                     "router/cold", "router/warm",
                     "cluster/origin_reads", "cluster/origin_bytes",
                     "cluster/peer_reads", "cluster/peer_bytes",
                     "cluster/peer_served", "cluster/stale_referrals",
                     "weight_cache/hits", "weight_cache/misses")

    def cluster_snapshot(self) -> Dict[str, Any]:
        """The cluster observability surface: every node's full
        ``metrics_snapshot`` (the PR-7 per-node registry), a cluster
        roll-up of the cross-node counters, the per-node load term the
        front-end router places by, and the placement table's view of
        where everything lives."""
        per_node: Dict[str, Any] = {}
        agg: Dict[str, float] = {}
        load: Dict[str, float] = {}
        for n in self.nodes:
            snap = n.metrics_snapshot()
            per_node[n.node_id] = snap
            counters = snap.get("counters", {})
            for name in self._AGG_COUNTERS:
                if name in counters:
                    agg[name] = agg.get(name, 0.0) + counters[name]
            g = snap.get("gauges", {})
            load[n.node_id] = (g.get("router/in_flight", {}).get("value", 0.0)
                               + g.get("router/queue_depth", {}
                                       ).get("value", 0.0))
        return {"n_nodes": len(self.nodes),
                "nodes": per_node,
                "cluster": {"counters": agg, "load": load},
                "placement": self.placement.snapshot()}

    # ----------------------------------------------------------- trace replay
    def run_trace(self, invocations, make_batch,
                  *, time_scale: float = 0.0,
                  concurrency: int = 1,
                  make_spec: Optional[Callable[[str], GenerateSpec]] = None
                  ) -> List[Response]:
        """Replay a trace through the locality-aware front end — the
        cluster twin of ``ServerlessPlatform.run_trace`` (same logical
        keep-alive clock, same serial/concurrent semantics, same
        generation mode); each Response additionally carries the
        serving ``node``."""
        router = self.router(workers_per_node=max(1, concurrency))
        try:
            futures = []
            logical_prev = None
            clock = 0.0
            for inv in invocations:
                if logical_prev is not None:
                    gap = inv.t - logical_prev
                    clock += gap
                    if time_scale > 0:
                        # replay pacing (same as the single-node engine)
                        time.sleep(gap * time_scale)  # analysis: ignore[R4]
                logical_prev = inv.t
                self.sweep(clock)
                if make_spec is not None:
                    req = Request(req_id=inv.req_id, model=inv.model,
                                  gen=make_spec(inv.model), t_logical=clock)
                else:
                    req = Request(req_id=inv.req_id, model=inv.model,
                                  batch=make_batch(inv.model),
                                  t_logical=clock)
                fut = router.submit(req)
                futures.append(fut)
                if concurrency <= 1:
                    fut.result()           # strict serial replay
            return [f.result() for f in futures]
        finally:
            router.shutdown()
            self.last_router_stats = router.stats()
