"""Cluster-wide placement table: where every ``(model, unit, shard)``
has landed, and who is allowed to read it from the origin store.

The table is the cluster's single point of coordination (λScale's
model-placement metadata, scoped to Cicada's retrieval granularity).
It answers two questions:

  * **locality** — which nodes hold a model's shards right now
    (:meth:`nodes_for_model` feeds the front-end router's placement
    score; :meth:`locate` feeds the peer-exchange tier);
  * **cluster-wide single-flight** — when N nodes cold-start the same
    key at once, :meth:`begin_fetch` elects exactly one origin-store
    *leader* per key; everyone else waits on the table's condition
    variable and is redirected to a peer once the leader publishes.
    Combined with the per-node WeightCache (which single-flights
    *within* a node), an N-way scale-out burst does at most **one**
    origin read per shard, cluster-wide — the rest moves over the fast
    intra-cluster link.

State machine per key (all transitions under ``_cv``):

    absent --begin_fetch--> loading(leader)
    loading --publish--> held(leader)          waiters wake -> PEER
    loading --abort--> absent                  waiters wake -> re-elect
    held --drop (cache eviction)--> absent (when last holder drops)

Entries never go stale silently: every node's WeightCache carries an
``on_evict`` callback that calls :meth:`drop`, so a PEER answer is at
worst *transiently* wrong (eviction racing the fetch) — the peer tier
handles that by dropping the dead holder and retrying begin_fetch,
which eventually degrades to an ORIGIN read.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Optional, Tuple

from repro import analysis

# begin_fetch() outcomes
ORIGIN = "origin"   # caller elected leader: read the origin store, then
                    # publish() (or abort() on failure)
PEER = "peer"       # a holder exists: stream from the returned node

Key = Tuple[str, str, Hashable]


class PlacementTable:
    """Thread-safe cluster-wide ``key -> holders`` map with leader
    election for origin reads (cluster-wide single-flight)."""

    def __init__(self):
        self._cv = analysis.make_condition("PlacementTable._cv")
        # key -> node ids holding the key (insertion order = landing order)
        self._holders: Dict[Key, List[str]] = {}     # guarded-by: _cv
        # key -> node id currently leading the origin read
        self._loading: Dict[Key, str] = {}           # guarded-by: _cv
        self._origin_elections = 0                   # guarded-by: _cv
        self._peer_referrals = 0                     # guarded-by: _cv
        self._waits = 0                              # guarded-by: _cv

    # ------------------------------------------------------ fetch protocol
    def begin_fetch(self, node: str, model: str, unit: str,
                    shard: Hashable = 0) -> Tuple[str, Optional[str]]:
        """Ask where ``node`` should read this key from.

        Returns ``(ORIGIN, None)`` — the caller is the cluster-wide
        leader and must read the origin store, then :meth:`publish` (or
        :meth:`abort`) — or ``(PEER, holder)`` — stream from that
        node's cache.  While another node is leading the origin read
        the caller blocks here; on publish it is redirected to the
        fresh holder, on abort one waiter is re-elected leader.
        """
        key = (model, unit, shard)
        with self._cv:
            waited = False
            while True:
                holders = self._holders.get(key)
                if holders:
                    # prefer a holder that is not the asking node: the
                    # asker's own cache already missed (a self-referral
                    # can happen when its eviction raced this fetch)
                    peer = next((h for h in holders if h != node),
                                holders[0])
                    self._peer_referrals += 1
                    return PEER, peer
                if key not in self._loading:
                    self._loading[key] = node
                    self._origin_elections += 1
                    return ORIGIN, None
                if not waited:
                    waited = True
                    self._waits += 1
                self._cv.wait()

    def publish(self, node: str, model: str, unit: str,
                shard: Hashable = 0):
        """``node``'s copy of the key is resident (its cache completed
        the entry): record it and wake begin_fetch waiters."""
        key = (model, unit, shard)
        with self._cv:
            holders = self._holders.setdefault(key, [])
            if node not in holders:
                holders.append(node)
            if self._loading.get(key) == node:
                del self._loading[key]
            self._cv.notify_all()

    def abort(self, node: str, model: str, unit: str, shard: Hashable = 0):
        """``node``'s origin read failed (or it never led): release the
        leadership claim so a waiter is re-elected.  Idempotent."""
        key = (model, unit, shard)
        with self._cv:
            if self._loading.get(key) == node:
                del self._loading[key]
                self._cv.notify_all()

    def drop(self, node: str, model: str, unit: str, shard: Hashable = 0):
        """``node`` no longer holds the key (cache eviction — wired to
        ``WeightCache(on_evict=...)`` — or a stale-referral repair)."""
        key = (model, unit, shard)
        with self._cv:
            holders = self._holders.get(key)
            if holders and node in holders:
                holders.remove(node)
                if not holders:
                    del self._holders[key]

    # -------------------------------------------------------------- queries
    def locate(self, model: str, unit: str, shard: Hashable = 0
               ) -> List[str]:
        """Node ids currently holding the key (landing order)."""
        with self._cv:
            return list(self._holders.get((model, unit, shard), ()))

    def nodes_for_model(self, model: str) -> Dict[str, int]:
        """node id -> number of this model's keys it holds — the
        locality term of the front-end router's placement score."""
        with self._cv:
            out: Dict[str, int] = {}
            for (m, _u, _s), holders in self._holders.items():
                if m != model:
                    continue
                for h in holders:
                    out[h] = out.get(h, 0) + 1
            return out

    def snapshot(self) -> Dict[str, object]:
        """Observability view: per-model key/holder counts plus the
        single-flight counters (how much origin traffic the table
        deduplicated)."""
        with self._cv:
            models: Dict[str, Dict[str, int]] = {}
            for (m, _u, _s), holders in self._holders.items():
                rec = models.setdefault(m, {"keys": 0, "copies": 0})
                rec["keys"] += 1
                rec["copies"] += len(holders)
            return {"models": models,
                    "loading": len(self._loading),
                    "origin_elections": self._origin_elections,
                    "peer_referrals": self._peer_referrals,
                    "waits": self._waits}

    def clear(self):
        """Forget every placement (tests / benchmark flushes).  Any
        in-flight leadership claims are kept — clearing mid-load must
        not re-elect a second origin reader for the same key."""
        with self._cv:
            self._holders.clear()
            self._cv.notify_all()
