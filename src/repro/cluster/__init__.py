"""Cluster-scale serving: multi-node platform with locality-aware
routing and peer-to-peer shard exchange.

The single-node stack (``repro.serving`` + ``repro.store`` +
``repro.core``) scales out to N simulated nodes:

  * :class:`~repro.cluster.platform.ClusterPlatform` — N
    :class:`~repro.cluster.node.Node` s (each a private
    ServerlessPlatform + WeightCache + metrics registry) over one
    shared origin store;
  * :class:`~repro.cluster.platform.ClusterRouter` — the locality-aware
    front end: warm node > cache-resident node > least-loaded node;
  * :class:`~repro.cluster.placement.PlacementTable` — cluster-wide
    ``(model, unit, shard) -> holders`` map with origin-read leader
    election (cluster-wide single-flight);
  * :class:`~repro.cluster.peer.ClusterShardSource` — the peer-exchange
    store tier each node's cold-start retrieval streams read through.
"""
from repro.cluster.node import Node
from repro.cluster.peer import ClusterShardSource
from repro.cluster.placement import ORIGIN, PEER, PlacementTable
from repro.cluster.platform import ClusterPlatform, ClusterRouter

__all__ = [
    "ClusterPlatform", "ClusterRouter", "ClusterShardSource",
    "Node", "PlacementTable", "ORIGIN", "PEER",
]
