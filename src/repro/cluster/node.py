"""One simulated cluster node: a full single-node serving stack —
private :class:`~repro.serving.pool.InstancePool` s behind a private
Router, a private :class:`~repro.store.cache.WeightCache`, a private
metrics registry — plus the node's membership in the cluster: its
cache publishes/withdraws placement-table entries, and its cold-start
retrieval streams read through a :class:`~repro.cluster.peer.
ClusterShardSource` (peer exchange) instead of always hitting the
origin store.

Everything inside the node is exactly the single-node platform
(``ServerlessPlatform``); the node only *wires* it into the cluster:

  * ``WeightCache(on_evict=...)`` → ``PlacementTable.drop`` — a
    dropped shard is withdrawn from the placement table immediately,
    so peer referrals can't point at evicted bytes for long;
  * every ``cache.complete`` of a leader read is followed (by the
    decoupler) with ``source.publish`` → ``PlacementTable.publish`` —
    the moment a shard lands it can serve every other node;
  * :meth:`serve_shard` / :meth:`end_serve` are the peer-facing read
    path: a pinned, non-blocking cache peek (``try_get``) so a remote
    fetch can never become this cache's load leader and the entry
    can't be evicted mid-transfer.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Hashable, Optional

from repro import metrics as metrics_mod
from repro.cluster.peer import ClusterShardSource
from repro.cluster.placement import PlacementTable
from repro.serving.engine import ServerlessPlatform
from repro.store.cache import WeightCache
from repro.store.store import BandwidthModel, WeightStore


class Node:
    """One cluster node: node-local serving platform + cluster wiring."""

    def __init__(self, node_id: str, index: int, store: WeightStore,
                 builders: Dict[str, Callable[[], tuple]], *,
                 placement: PlacementTable,
                 link: Optional[BandwidthModel] = None,
                 resolve_peer: Optional[
                     Callable[[str], Optional["Node"]]] = None,
                 cache_budget_bytes: int = 0,
                 peer_exchange: bool = True,
                 chunk_bytes: int = 1 << 20,
                 **platform_kw):
        """``store``: the *shared* origin store (all nodes contend on
        its BandwidthModel — the slow pipe peer exchange avoids).
        ``link``/``resolve_peer``: the shared intra-cluster link and
        the node directory, both owned by the ClusterPlatform.
        ``peer_exchange=False`` keeps the node cluster-blind (its cold
        starts always read the origin) — the baseline the benchmark
        measures peer exchange against.  Remaining kwargs go to this
        node's ServerlessPlatform (strategy, keep_alive_s,
        max_instances, gen_slots, ...)."""
        self.node_id = node_id
        self.index = int(index)
        self.placement = placement
        self.metrics = metrics_mod.MetricsRegistry()
        self.cache = WeightCache(cache_budget_bytes,
                                 metrics=self.metrics,
                                 on_evict=self._on_evict)
        self.source: Optional[ClusterShardSource] = None
        if peer_exchange:
            self.source = ClusterShardSource(
                node_id, placement, link,
                resolve_peer or (lambda _nid: None),
                channel=self.index, chunk_bytes=chunk_bytes,
                metrics=self.metrics)
        self.platform = ServerlessPlatform(
            store, builders, cache=self.cache, metrics=self.metrics,
            source=self.source, chunk_bytes=chunk_bytes, **platform_kw)
        self._m_peer_served = self.metrics.counter("cluster/peer_served")

    # --------------------------------------------------- placement wiring
    def _on_evict(self, key):
        """WeightCache eviction hook (runs outside the cache lock):
        withdraw the dropped shard from the placement table."""
        model, unit, shard = key
        self.placement.drop(self.node_id, model, unit, shard)

    # ------------------------------------------------------ peer-facing read
    def serve_shard(self, model: str, unit: str, skey: Hashable = 0
                    ) -> Optional[Any]:
        """A peer's transfer source: this node's cached payload with a
        reference pinned (call :meth:`end_serve` after the transfer),
        or None when the key is absent/loading — the *stale referral*
        signal; the asker repairs the placement table and falls back."""
        payload = self.cache.try_get(model, unit, skey)
        if payload is not None:
            self._m_peer_served.inc()
        return payload

    def end_serve(self, model: str, unit: str, skey: Hashable = 0):
        self.cache.release(model, unit, skey)

    # ------------------------------------------------------------- queries
    def any_live(self, model: str) -> bool:
        """A live instance of ``model`` on this node (warm-servable)."""
        pool = self.platform.pools.get(model)
        return pool is not None and pool.any_live()

    def load_score(self) -> float:
        """The placement load term: requests in service + queued on
        this node, read from the same live gauges
        :meth:`metrics_snapshot` exports (``router/in_flight`` +
        ``router/queue_depth``)."""
        g = self.metrics.gauge
        return g("router/in_flight").value + g("router/queue_depth").value

    def origin_reads(self) -> float:
        """Cumulative origin-store reads this node performed as a
        cluster-wide single-flight leader (peer-served streams don't
        count — that's the point)."""
        return self.metrics.counter("cluster/origin_reads").value

    def peer_reads(self) -> float:
        return self.metrics.counter("cluster/peer_reads").value

    def metrics_snapshot(self) -> Dict[str, Any]:
        """The PR-7 per-node observability surface (this node's private
        registry) — aggregated by ClusterPlatform.cluster_snapshot."""
        return self.platform.metrics_snapshot()

    # ------------------------------------------------------------ lifecycle
    def router(self, *, workers: int = 4, max_pending: Optional[int] = None):
        """This node's Router (the cluster front-end creates one per
        node and places requests across them)."""
        return self.platform.router(workers=workers,
                                    max_pending=max_pending)

    def sweep(self, logical_now: float) -> int:
        return self.platform.sweep(logical_now)

    def flush(self) -> None:
        """Back to cold (benchmarks/tests): evict every idle live
        instance and drop all cached weights — the on-evict hook
        withdraws this node's placement entries as a side effect."""
        for pool in self.platform.pools.values():
            pool.scale_in(0)
        self.cache.clear()
