"""Peer-to-peer shard exchange: the cluster's second store tier.

A :class:`ClusterShardSource` is one node's view of the tier.  It plugs
into the :class:`~repro.core.decoupler.WeightDecoupler` as its
``ShardSource``: whenever a retrieval stream misses the node-local
WeightCache, the source asks the cluster-wide
:class:`~repro.cluster.placement.PlacementTable` where the key lives —

  * **nowhere yet** → this node is elected the cluster-wide leader and
    the stream runs the decoupler's ordinary origin-store read (the
    one origin read the whole burst pays for this key);
  * **on a peer** → the payload is taken straight out of the peer
    node's cache (:meth:`~repro.cluster.node.Node.serve_shard`, a
    pinned non-blocking peek) and the transfer is charged to the fast
    intra-cluster link — the same per-channel
    :class:`~repro.store.store.BandwidthModel` machinery as the origin
    store, just with λScale-regime numbers (GB/s instead of a shared
    origin pipe), chunked and suspendable under the same Algorithm-1
    gate as any other stream.

Payloads cross nodes by reference — the simulation's stand-in for an
RDMA transfer; the wire cost is modeled by the link, and both caches
account the bytes as resident (exactly what a real cluster would hold).
Payload leaves are treated as immutable by every consumer, so sharing
is safe.

**Stale referrals** (the peer evicted between publish and our fetch —
its on-evict drop raced the table read): ``serve_shard`` returns None,
the source drops the dead holder from the table and retries
``begin_fetch``, which eventually degrades to an ORIGIN read.  The
origin store is always the correctness backstop; peers are purely a
fast path.
"""
from __future__ import annotations

from typing import Any, Callable, Hashable, Optional, Tuple

from repro import metrics as metrics_mod
from repro.core.decoupler import ShardSource
from repro.cluster.placement import ORIGIN, PlacementTable
from repro.store.store import BandwidthModel


class ClusterShardSource(ShardSource):
    """One node's byte source for cache-missing retrieval streams:
    placement-table lookup, peer transfer over the cluster link, origin
    fallback — with cluster-wide single-flight leader election."""

    def __init__(self, node_id: str, placement: PlacementTable,
                 link: Optional[BandwidthModel],
                 resolve_peer: Callable[[str], Optional[Any]], *,
                 channel: int = 0, chunk_bytes: int = 1 << 20,
                 metrics: Optional[metrics_mod.MetricsRegistry] = None):
        """``link``: the shared intra-cluster BandwidthModel (None ->
        unthrottled, e.g. unit tests); ``channel``: this node's NIC —
        every node charges its own channel, so peer transfers to
        different nodes run in parallel like λScale's per-host links.
        ``resolve_peer``: node id -> Node (None when unknown)."""
        self.node_id = node_id
        self.placement = placement
        self.link = link
        self.resolve_peer = resolve_peer
        self.channel = int(channel)
        self.chunk_bytes = int(chunk_bytes)
        m = metrics_mod.resolve(metrics)
        self._m_origin = m.counter("cluster/origin_reads")
        self._m_origin_bytes = m.counter("cluster/origin_bytes")
        self._m_peer = m.counter("cluster/peer_reads")
        self._m_peer_bytes = m.counter("cluster/peer_bytes")
        self._m_stale = m.counter("cluster/stale_referrals")

    # ------------------------------------------------------------ ShardSource
    def fetch(self, model: str, unit: str, skey: Hashable, nbytes: int,
              read_origin: Callable[[], Any], *,
              gate=None, on_chunk=None) -> Tuple[Any, str]:
        while True:
            mode, peer_id = self.placement.begin_fetch(
                self.node_id, model, unit, skey)
            if mode == ORIGIN:
                # leadership is released by publish()/abort(), both
                # driven by the decoupler after the local cache settles
                payload = read_origin()
                self._m_origin.inc()
                self._m_origin_bytes.inc(max(0, int(nbytes)))
                return payload, "origin"
            payload = self._fetch_from_peer(peer_id, model, unit, skey,
                                            nbytes, gate, on_chunk)
            if payload is not None:
                self._m_peer.inc()
                self._m_peer_bytes.inc(max(0, int(nbytes)))
                return payload, "peer"
            # stale referral: repair the table and re-resolve (another
            # holder, a new leader's publish, or our own election)
            self._m_stale.inc()
            self.placement.drop(peer_id, model, unit, skey)

    def publish(self, model: str, unit: str, skey: Hashable):
        self.placement.publish(self.node_id, model, unit, skey)

    def abort(self, model: str, unit: str, skey: Hashable):
        self.placement.abort(self.node_id, model, unit, skey)

    # ------------------------------------------------------------- internals
    def _fetch_from_peer(self, peer_id: str, model: str, unit: str,
                         skey: Hashable, nbytes: int, gate, on_chunk
                         ) -> Optional[Any]:
        """One peer transfer: pin the entry in the peer's cache, charge
        the wire cost to this node's cluster-link channel, unpin.
        Returns None when the peer no longer holds the key."""
        peer = self.resolve_peer(peer_id)
        if peer is None:
            return None
        payload = peer.serve_shard(model, unit, skey)
        if payload is None:
            return None
        try:
            if self.link is not None:
                self.link.transfer(nbytes, channel=self.channel,
                                   chunk_bytes=self.chunk_bytes,
                                   gate=gate, on_chunk=on_chunk)
            elif on_chunk is not None:
                on_chunk(max(0, int(nbytes)))
        finally:
            # the pin held the entry against eviction for the whole
            # modeled transfer — a mid-stream eviction can only happen
            # *before* serve_shard pins (the stale-referral path above)
            peer.end_serve(model, unit, skey)
        return payload
