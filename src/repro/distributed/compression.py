"""Gradient compression for the data-parallel reduce: int8 with error
feedback.

At pod scale the DP gradient all-reduce is the dominant inter-pod
collective (the multi-pod mesh's `pod` axis crosses DCN, ~10x slower
than ICI).  Int8 quantization cuts those bytes 4x; **error feedback**
(Karimireddy et al.) accumulates the quantization residual locally and
re-injects it next step, which restores convergence to the uncompressed
trajectory asymptotically.

Two entry points:

  * ``make_error_feedback_transform`` — a ``grad_transform`` hook for
    the optimizer (models the compress->reduce->decompress round trip;
    usable on any device count);
  * ``compressed_psum`` — the shard_map collective itself: quantize,
    ``all_gather`` int8 + scales over the DP axis, dequantize and mean.
    4x fewer bytes on the wire than an f32 all-reduce ring.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), params)


def make_error_feedback_transform():
    """Returns f(grads, ef) -> (compressed_grads, new_ef).

    compressed = dequant(quant(g + ef));  new_ef = (g + ef) - compressed.
    """
    def f(grads: PyTree, ef: PyTree) -> Tuple[PyTree, PyTree]:
        def per_leaf(g, e):
            corrected = g.astype(jnp.float32) + e
            q, s = quantize_int8(corrected)
            deq = dequantize_int8(q, s)
            return deq.astype(g.dtype), corrected - deq
        out = jax.tree.map(per_leaf, grads, ef)
        comp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        new_ef = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return comp, new_ef
    return f


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean-reduce over a shard_map axis with int8 wire format.

    all_gather(int8) + local dequant-mean: N*n int8 bytes per link vs
    2*(N-1)/N*n f32 for a ring all-reduce -> ~4x collective-byte saving
    on the inter-pod hop at the cost of N-way gather fan-in (acceptable:
    the pod axis is small, N=2..8, while n is huge).
    """
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)            # (N, ...)
    scales = jax.lax.all_gather(scale, axis_name)    # (N,)
    deq = qs.astype(jnp.float32) * scales.reshape(
        (-1,) + (1,) * (qs.ndim - 1))
    return jnp.mean(deq, axis=0)


def compressed_psum_tree(grads: PyTree, axis_name: str) -> PyTree:
    return jax.tree.map(lambda g: compressed_psum(g, axis_name).astype(
        g.dtype), grads)
