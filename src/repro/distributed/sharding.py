"""Logical-axis sharding rules (MaxText-style).

Model code never mentions mesh axes.  It annotates activations with *logical*
names (``constrain(x, "batch", "seq", "embed")``) and parameters are
classified by leaf path into logical axes.  A :class:`ShardingRules` mapping
resolves logical names to physical mesh axes; unresolvable or non-divisible
axes silently fall back to replication so that *every* (arch x mesh) cell
compiles — the hillclimb then tightens rules per cell.

Outside of an active ``use_rules`` context every annotation is a no-op, so
the same model code runs on a single CPU device in tests.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any
Axes = Union[None, str, Tuple[str, ...]]

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_ctx", default=None)


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of axes, or None)."""
    mapping: Dict[str, Axes]

    def resolve(self, name: Optional[str]) -> Axes:
        if name is None:
            return None
        return self.mapping.get(name, None)


def serve_rules(*, multi_pod: bool = False) -> ShardingRules:
    """Serving: weights TP over `model`, replicated over data; batch DP."""
    dp = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules({
        # activations
        "batch": dp,
        "seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": None,
        "ff": "model",
        "vocab": "model",
        "expert_act": "model",
        # decode KV cache: sequence-sharded over `model` (split-K decode)
        "kv_seq": "model",
        # params
        "fsdp": None,
        "tensor": "model",
        "tensor_alt": None,
        "expert": "model",
        "vocab_p": "model",
    })


def train_rules(*, multi_pod: bool = False) -> ShardingRules:
    """Training: TP over `model` + FSDP/DP over (`pod`,)`data`."""
    dp = ("pod", "data") if multi_pod else ("data",)
    r = serve_rules(multi_pod=multi_pod)
    r.mapping.update({
        "batch": dp,
        "fsdp": dp,
    })
    return r


# ---------------------------------------------------------------------------
# context
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: ShardingRules):
    token = _ACTIVE.set((mesh, rules))
    try:
        with mesh:
            yield
    finally:
        _ACTIVE.reset(token)


def active_context() -> Optional[Tuple[Mesh, ShardingRules]]:
    return _ACTIVE.get()


def _axis_size(mesh: Mesh, axes: Axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _guarded_spec(mesh: Mesh, rules: ShardingRules, shape: Sequence[int],
                  logical: Sequence[Optional[str]]) -> P:
    """Resolve logical names to a PartitionSpec; drop any axis that does not
    divide its dimension or reuses an already-assigned mesh axis."""
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        axes = rules.resolve(name)
        if axes is None:
            out.append(None)
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        # drop mesh axes already used by an earlier dim
        tup = tuple(a for a in tup if a not in used and a in mesh.shape)
        size = 1
        for a in tup:
            size *= mesh.shape[a]
        if not tup or size <= 1 or dim % size != 0:
            out.append(None)
            continue
        used.update(tup)
        out.append(tup[0] if len(tup) == 1 else tup)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axis names (no-op w/o context)."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical) != x.ndim:
        # padded/squeezed intermediate; skip rather than crash
        return x
    spec = _guarded_spec(mesh, rules, x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter classification
# ---------------------------------------------------------------------------

# leaf-name -> logical axes for the *trailing* dims (leading stacked `L`
# dims are padded with None automatically).
_PARAM_TABLE: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / head
    "tok": ("vocab_p", "fsdp"),
    "pos": (None, "fsdp"),
    "head.w": ("fsdp", "vocab_p"),
    # attention
    "wq": ("fsdp", "tensor", None),
    "wk": ("fsdp", "tensor", None),
    "wv": ("fsdp", "tensor", None),
    "wo": ("tensor", None, "fsdp"),
    # mlp
    "wg": ("fsdp", "tensor"),
    "wu": ("fsdp", "tensor"),
    "wd": ("tensor", "fsdp"),
    # moe
    "router": ("fsdp", None),
    "moe.wg": ("expert", "fsdp", "tensor"),
    "moe.wu": ("expert", "fsdp", "tensor"),
    "moe.wd": ("expert", "tensor", "fsdp"),
    # mamba-2 ssd
    "in_proj": ("fsdp", "tensor"),
    "out_proj": ("tensor", "fsdp"),
    "conv": (None, "tensor"),
    "a_log": (None,),
    "d_skip": (None,),
    "dt_bias": (None,),
    "ssd_norm": ("tensor",),
    # rg-lru
    "wx": ("fsdp", "tensor"),
    "wa": ("fsdp", "tensor"),
    "wy": ("tensor", "fsdp"),
    "lam": (None,),
    "gate_bias": (None,),
    # norms / misc
    "scale": (None,),
    "bias": (None,),
    # vision
    "kernel": (None, None, None, "tensor"),
    "w": ("fsdp", "tensor"),
}


def _leaf_logical(path: Tuple[Any, ...], shape: Tuple[int, ...]
                  ) -> Tuple[Optional[str], ...]:
    keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    name = keys[-1] if keys else ""
    parent = keys[-2] if len(keys) >= 2 else ""
    lookup = None
    if f"{parent}.{name}" in _PARAM_TABLE:
        lookup = _PARAM_TABLE[f"{parent}.{name}"]
    elif parent == "experts" and f"moe.{name}" in _PARAM_TABLE:
        lookup = _PARAM_TABLE[f"moe.{name}"]
    elif name in _PARAM_TABLE:
        lookup = _PARAM_TABLE[name]
    if lookup is None:
        lookup = (None,) * len(shape)
    # pad leading stacked dims (scan-stacked layer axis etc.)
    if len(lookup) < len(shape):
        lookup = (None,) * (len(shape) - len(lookup)) + tuple(lookup)
    elif len(lookup) > len(shape):
        lookup = tuple(lookup[-len(shape):])
    return tuple(lookup)


def param_specs(abstract_params: PyTree, mesh: Mesh,
                rules: ShardingRules) -> PyTree:
    """NamedSharding tree matching the (abstract) parameter tree.

    Works on the full (scan-stacked) tree *and* on a single streaming
    unit's tree: classification keys off the trailing leaf-path
    components, which are identical in both views."""
    def f(path, leaf):
        logical = _leaf_logical(path, leaf.shape)
        spec = _guarded_spec(mesh, rules, leaf.shape, logical)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, abstract_params)


def leaf_specs(abstract_unit: PyTree, mesh: Mesh, rules: ShardingRules
               ) -> Dict[str, NamedSharding]:
    """Per-leaf NamedShardings of one streaming unit, keyed by the
    WeightStore's flat leaf path ("attn/wq", "norm1/scale", ...) — the
    resolution the shard-granular cold-start pipeline plans its
    byte-range retrieval streams from."""
    from repro.store.store import leaf_path_name
    flat = jax.tree_util.tree_flatten_with_path(
        param_specs(abstract_unit, mesh, rules))[0]
    return {leaf_path_name(path): sharding for path, sharding in flat}


def cache_specs(abstract_cache: PyTree, mesh: Mesh,
                rules: ShardingRules) -> PyTree:
    """KV/recurrent-state cache sharding: (L, B, S, K, dh) — batch over DP,
    cache sequence over `kv_seq` (split-K decode); state tensors batch-only."""
    def f(path, leaf):
        shape = leaf.shape
        if len(shape) == 5:      # (L, B, K, S, dh) attn cache (kv-major)
            logical = (None, "batch", "kv_heads", "kv_seq", None)
        elif len(shape) == 4:    # (L, B, nh, ...) ssd state / conv state
            logical = (None, "batch", "tensor", None)
        elif len(shape) == 3:    # (L, B, width) rg-lru state
            logical = (None, "batch", "tensor")
        elif len(shape) == 2:    # (B,) aux / (L,B)
            logical = (None, "batch")
        elif len(shape) == 1:
            logical = ("batch",)
        else:
            logical = (None,) * len(shape)
        spec = _guarded_spec(mesh, rules, shape, logical)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, abstract_cache)


def batch_specs(abstract_batch: PyTree, mesh: Mesh,
                rules: ShardingRules) -> PyTree:
    """Input batches: leading dim is global batch -> DP axes."""
    def f(leaf):
        logical = ("batch",) + (None,) * (len(leaf.shape) - 1)
        spec = _guarded_spec(mesh, rules, leaf.shape, logical)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map(f, abstract_batch)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
