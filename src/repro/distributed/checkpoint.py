"""Fault-tolerant checkpointing, reusing the weight-store extent format.

Properties a 1000-node deployment needs and this implements:

  * **atomic**: write to ``step_<n>.tmp/``, fsync, rename — a crash
    mid-save never corrupts the latest checkpoint; ``latest`` is a
    pointer file updated after the rename;
  * **integrity**: every leaf extent carries crc32 (store format);
  * **elastic**: leaves are stored as full (unsharded) arrays; restore
    targets *any* mesh — ``jax.device_put`` with the new
    ``NamedSharding`` re-shards on load, so a checkpoint written on a
    16x16 mesh restores onto 2x16x16 (or a single CPU) unchanged;
  * **retention**: keeps the last ``keep`` checkpoints, reaps older;
  * **resume determinism**: the data pipeline is a pure function of
    (seed, step), so (step, params, opt_state) is the *complete* state.

On a real multi-host pod each host would write its address-space shards
(per-shard sub-extents of the same manifest) instead of host-gathered
full arrays; the single-process container collapses that to one writer.
"""
from __future__ import annotations

import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.store.store import WeightStore
from repro.training.optim import AdamWState

PyTree = Any


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, params: PyTree,
             opt_state: Optional[AdamWState] = None) -> str:
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        store = WeightStore(tmp)
        units = {"params": jax.tree.map(np.asarray, params)}
        if opt_state is not None:
            units["opt_m"] = jax.tree.map(np.asarray, opt_state.m)
            units["opt_v"] = jax.tree.map(np.asarray, opt_state.v)
            units["opt_step"] = {"step": np.asarray(opt_state.step)}
        store.deploy("ckpt", units)
        # fsync the manifest + extents, then atomic rename
        for root, _, files in os.walk(tmp):
            for fn in files:
                with open(os.path.join(root, fn), "rb") as f:
                    os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._write_latest(name)
        self._reap()
        return final

    def _write_latest(self, name: str):
        ptr = os.path.join(self.dir, "latest.tmp")
        with open(ptr, "w") as f:
            f.write(name)
            f.flush()
            os.fsync(f.fileno())
        os.replace(ptr, os.path.join(self.dir, "latest"))

    def _reap(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "latest")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            return int(f.read().strip().split("_")[1])

    def restore(self, abstract_params: PyTree,
                abstract_opt: Optional[AdamWState] = None, *,
                step: Optional[int] = None,
                shardings: Optional[PyTree] = None
                ) -> Tuple[int, PyTree, Optional[AdamWState]]:
        """Load (params, opt) and place onto the current mesh.

        shardings: optional NamedSharding tree matching abstract_params —
        the *elastic* path: bytes written on any mesh load onto this one.
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        store = WeightStore(path)

        def load_unit(unit: str, abstract: PyTree,
                      shards: Optional[PyTree]) -> PyTree:
            from repro.store.store import unflatten_unit
            leaves = store.read_and_deserialize("ckpt", unit)
            tree = unflatten_unit(abstract,
                                  {k: v for k, (v, _) in leaves.items()})
            if shards is not None:
                tree = jax.tree.map(jax.device_put, tree, shards)
            return tree

        params = load_unit("params", abstract_params, shardings)
        opt = None
        if abstract_opt is not None:
            m = load_unit("opt_m", abstract_opt.m, shardings)
            v = load_unit("opt_v", abstract_opt.v, shardings)
            st = store.read_and_deserialize("ckpt", "opt_step")
            opt = AdamWState(jax.numpy.asarray(st["step"][0]), m, v)
        return step, params, opt
