"""Straggler mitigation + elastic-scaling utilities.

``HeartbeatMonitor`` is the control-plane piece a 1000-node job needs:
every host reports per-step durations; hosts whose EMA exceeds
``threshold x`` the fleet median are flagged.  The remediation hooks are
deliberately mechanism-not-policy:

  * ``suggest_evict`` — drop the straggler and let ``reshard`` rebalance
    (elastic down-scale; the deterministic (seed, step) data pipeline
    means survivors recompute the lost shard with zero coordination);
  * backup-task dispatch for the *input* pipeline is free here because
    batches are pure functions of (seed, step) — any host can
    regenerate any shard.

``reshard`` is the elastic-scaling primitive: move a pytree onto a new
mesh/sharding (used by checkpoint restore across mesh shapes, and by
scale-up/scale-down events).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Dict, List, Optional

import jax

PyTree = Any


@dataclasses.dataclass
class HostStats:
    host: str
    ema_s: float = 0.0
    steps: int = 0
    last_seen: float = 0.0


class HeartbeatMonitor:
    def __init__(self, *, alpha: float = 0.3, threshold: float = 1.5,
                 timeout_s: float = 60.0):
        self.alpha = alpha
        self.threshold = threshold
        self.timeout_s = timeout_s
        self.hosts: Dict[str, HostStats] = {}

    def report(self, host: str, step_duration_s: float,
               now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        st = self.hosts.setdefault(host, HostStats(host))
        st.ema_s = (step_duration_s if st.steps == 0
                    else (1 - self.alpha) * st.ema_s
                    + self.alpha * step_duration_s)
        st.steps += 1
        st.last_seen = now

    def stragglers(self, now: Optional[float] = None) -> List[str]:
        now = time.monotonic() if now is None else now
        live = [s for s in self.hosts.values()
                if now - s.last_seen <= self.timeout_s and s.steps > 0]
        out = [s.host for s in self.hosts.values()
               if s.steps > 0 and now - s.last_seen > self.timeout_s]
        if len(live) >= 2:
            med = statistics.median(s.ema_s for s in live)
            out += [s.host for s in live if s.ema_s > self.threshold * med]
        return sorted(set(out))

    def suggest_evict(self, now: Optional[float] = None) -> List[str]:
        """Hosts to drop at the next elastic re-shard."""
        return self.stragglers(now)


def reshard(tree: PyTree, shardings: PyTree) -> PyTree:
    """Move a pytree to new shardings (elastic scale-up/down, mesh
    change).  jax.device_put handles cross-sharding transfers."""
    return jax.tree.map(jax.device_put, tree, shardings)
