"""Quantized-resident weight leaves (``compute_quant`` serving mode).

PR 5 made int8 a first-class *storage* format: shards stream as int8
values + per-column f32 scales and are dequanted at commit, so int8
buys I/O, then gives the memory back.  Under ``compute_quant`` the
cold-start apply path skips that dequant and keeps each quantized leaf
resident as a :class:`QuantLeaf` — a registered pytree node holding the
int8 values at the leaf's logical shape plus its scale vector — so an
instance's params charge ~quarter the f32 bytes, and the model forward
paths dispatch weight einsums through the fused-dequant
``ops.quant_matmul`` kernel.

Design notes:

  * Registered pytree node: ``jnp.stack`` via ``jax.tree.map`` (model
    assembly), ``jax.lax.scan`` over stacked layer blocks, jit
    flattening and ``device_put`` all traverse the two children
    independently — the stacked form slices back to per-layer
    ``QuantLeaf``s inside a scan body with no special casing.
  * ``.shape``/``.ndim`` mirror the logical (dequantized) leaf, so
    structural checks against the abstract f32 tree still pass.
  * ``.astype(dt)`` / ``__jax_array__`` dequantize — any model site not
    explicitly dispatched (embedding tie, routers, conv taps, SSM
    projections) degrades transparently to the dequant-then-einsum
    reference instead of crashing on a non-array leaf.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
class QuantLeaf:
    """One int8-resident weight: values at the logical leaf shape,
    per-column f32 scales over the last axis."""

    __slots__ = ("q", "scale")

    def __init__(self, q, scale):
        self.q = q
        self.scale = scale

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- logical-array surface ---------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.q.shape)

    @property
    def ndim(self) -> int:
        return self.q.ndim

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    @property
    def nbytes(self) -> int:
        """Resident bytes: int8 values + f32 scales (~quarter of f32)."""
        return self.q.nbytes + self.scale.nbytes

    def astype(self, dtype):
        """Dequantize to ``dtype`` — the transparent fallback for model
        sites that expect a plain array (matches ``ref.weight_transform``
        bit-for-bit: f32 multiply, then cast)."""
        return (self.q.astype(jnp.float32)
                * self.scale.astype(jnp.float32)).astype(dtype)

    def __jax_array__(self):
        return self.astype(jnp.float32)

    def __repr__(self):
        return (f"QuantLeaf(shape={self.shape}, "
                f"scale={tuple(self.scale.shape)})")


def is_quant(leaf) -> bool:
    return isinstance(leaf, QuantLeaf)


def einsum(eq: str, x, w, cd, *, n_contract: int = 1):
    """Activation x weight contraction with fused-dequant dispatch.

    Plain-array weights take the caller's einsum verbatim (the existing
    f32 path, bit-identical).  A :class:`QuantLeaf` routes through
    ``ops.quant_matmul``: the first ``n_contract`` axes of the weight
    contract against the trailing axes of ``x``; remaining weight axes
    are output columns.  The per-column scale (over the weight's last
    axis) tiles across any middle output axes — column ``j`` of the
    collapsed (K, N) weight is ``(j // last, j % last)`` row-major, so
    ``tile(scale, N // last)`` reproduces the right per-column factor.
    """
    if not isinstance(w, QuantLeaf):
        return jnp.einsum(eq, x, w.astype(cd))
    from repro.kernels import ops
    kdims = w.q.shape[:n_contract]
    ndims = w.q.shape[n_contract:]
    K = math.prod(kdims)
    N = math.prod(ndims)
    reps = N // w.scale.shape[0]
    scale = jnp.tile(w.scale, reps) if reps > 1 else w.scale
    xr = x.reshape(x.shape[:x.ndim - n_contract] + (K,))
    out = ops.quant_matmul(xr.astype(cd), w.q.reshape(K, N), scale,
                           out_dtype=cd)
    return out.reshape(x.shape[:x.ndim - n_contract] + ndims)


def expert_einsum(eq: str, x, w, cd, *, shared_x: bool = False):
    """Per-expert contraction ``becd,edf->becf`` (and its ``wd`` twin
    ``becf,efd->becd``): the expert axis is a batch dim shared by both
    operands, so each expert's (d, f) slab goes through its own fused
    quant_matmul; scales are shared across experts (per-column over the
    weight's last axis).  ``shared_x``: every expert sees the same
    activations (the dense-oracle form ``bsd,edf->besf``)."""
    if not isinstance(w, QuantLeaf):
        return jnp.einsum(eq, x, w.astype(cd))
    from repro.kernels import ops
    E = w.q.shape[0]
    outs = [ops.quant_matmul((x if shared_x else x[:, e]).astype(cd),
                             w.q[e], w.scale, out_dtype=cd)
            for e in range(E)]
    return jnp.stack(outs, axis=1)


def gather_rows(w, idx, cd):
    """Embedding lookup ``w[idx]`` — gather the int8 rows, then scale
    (elementwise, so gather-then-dequant == dequant-then-gather
    bit-for-bit, without materializing the full dequantized table)."""
    if not isinstance(w, QuantLeaf):
        return w.astype(cd)[idx]
    return (w.q[idx].astype(jnp.float32)
            * w.scale.astype(jnp.float32)).astype(cd)
