"""Training step + loop: remat'd loss, AdamW, preemption-safe.

``make_train_step`` builds the jittable (params, opt_state, batch) ->
(params, opt_state, metrics) function; under a mesh it is pjit'd with
the sharding rules (``launch/train.py`` drives that).  The loop handles
periodic checkpointing (atomic, via ``distributed.checkpoint``) and
save-on-signal preemption safety.
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optim import AdamW, AdamWState

PyTree = Any


def make_train_step(model, opt: AdamW, *,
                    grad_transform: Optional[Callable] = None,
                    remat: bool = True, micro_batches: int = 1,
                    unroll: bool = False, mixed_precision: bool = False):
    """grad_transform(grads) -> grads: hook for DP compression etc.

    micro_batches > 1: gradient accumulation — the global batch is split
    along its leading axis into M microbatches scanned sequentially;
    activation memory scales 1/M while the optimizer update still sees
    the full-batch gradient.  Mandatory at pod scale (a 1M-token global
    batch does not fit activations otherwise).

    mixed_precision: differentiate wrt a bf16 *copy* of the params (f32
    masters stay in the optimizer).  The cast happens before the SPMD
    sharding boundary, so FSDP weight all-gathers and DP gradient
    reduces move bf16 on the wire — halving the collective term (the
    dominant cost for MoE training at pod scale).
    """

    def grad_of(params, mb):
        if not mixed_precision:
            return jax.value_and_grad(
                lambda p: model.loss(p, mb, remat=remat, unroll=unroll),
                has_aux=True)(params)
        half = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        return jax.value_and_grad(
            lambda p: model.loss(p, mb, remat=remat, unroll=unroll),
            has_aux=True)(half)

    def step(params: PyTree, opt_state: AdamWState,
             batch: Dict[str, jax.Array]):
        if micro_batches == 1:
            (loss, metrics), grads = grad_of(params, batch)
        else:
            M = micro_batches
            split = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                batch)

            def micro(carry, mb):
                gacc, lacc = carry
                (l, m), g = grad_of(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), m

            gacc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gacc, lsum), ms = jax.lax.scan(
                micro, (gacc0, jnp.zeros((), jnp.float32)), split)
            grads = jax.tree.map(lambda g: g / M, gacc)
            loss = lsum / M
            metrics = jax.tree.map(lambda x: x[-1], ms)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


class TrainLoop:
    """Checkpointed, preemption-safe host loop."""

    def __init__(self, model, opt: AdamW, *, step_fn=None,
                 checkpointer=None, ckpt_every: int = 100,
                 log_every: int = 10,
                 log_fn: Callable[[str], None] = print):
        self.model = model
        self.opt = opt
        self.step_fn = step_fn or jax.jit(make_train_step(model, opt))
        self.checkpointer = checkpointer
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log_fn
        self._preempted = False

    def install_signal_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def run(self, params: PyTree, opt_state: AdamWState,
            batches: Iterator[Dict[str, np.ndarray]], *,
            start_step: int = 0, n_steps: int = 100
            ) -> Tuple[PyTree, AdamWState, Dict[str, list]]:
        history: Dict[str, list] = {"loss": [], "step": [], "tps": []}
        t_last = time.monotonic()
        step = start_step
        for step in range(start_step, start_step + n_steps):
            batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            if (step + 1) % self.log_every == 0 or step == start_step:
                loss = float(jax.block_until_ready(metrics["loss"]))
                now = time.monotonic()
                tokens = batch["labels"].size * self.log_every
                tps = tokens / max(now - t_last, 1e-9)
                t_last = now
                history["loss"].append(loss)
                history["step"].append(step + 1)
                history["tps"].append(tps)
                self.log(f"step {step + 1:5d}  loss {loss:.4f}  "
                         f"tok/s {tps:,.0f}")
            if self.checkpointer is not None and \
                    ((step + 1) % self.ckpt_every == 0 or self._preempted):
                self.checkpointer.save(step + 1, params, opt_state)
            if self._preempted:
                self.log(f"preempted at step {step + 1}: checkpoint saved, "
                         "exiting cleanly")
                break
        return params, opt_state, history
