"""AdamW optimizer + schedules, pure pytree (no optax dependency).

Supports global-norm gradient clipping, decoupled weight decay,
warmup-cosine LR, and (for the distributed path) an optional gradient
transform hook — the int8 error-feedback compressor plugs in there.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: PyTree) -> AdamWState:
        zeros = lambda p: jax.tree.map(  # noqa: E731
            lambda l: jnp.zeros(l.shape, jnp.float32), p)
        return AdamWState(jnp.zeros((), jnp.int32), zeros(params),
                          zeros(params))

    def _lr(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads: PyTree, state: AdamWState, params: PyTree
               ) -> Tuple[PyTree, AdamWState, Dict[str, jax.Array]]:
        step = state.step + 1
        gnorm = global_norm(grads)
        if self.clip_norm > 0:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1)
                         * g.astype(jnp.float32), state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, mm, vv):
            mhat = mm / bc1
            vhat = vv / bc2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay > 0 and p.ndim >= 2:   # decay matrices only
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v), \
            {"grad_norm": gnorm, "lr": lr}


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def sched(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup, warm, cos)
    return sched
