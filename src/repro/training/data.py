"""Synthetic-but-learnable data pipeline.

A seeded first-order Markov chain over the vocabulary (sparse row
support so the conditional entropy is well below log V): a model that
learns the bigram statistics drives the loss down — giving the training
examples/tests a real convergence signal with no external data.

The pipeline is sharding-aware: ``host_batches`` yields the *local*
slice of the global batch for this host (data-parallel loading), and
every batch is a pure function of (seed, step) — restart-safe resume
(the checkpoint records the step; no data-iterator state to persist)
and straggler-free (no inter-host coordination).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class MarkovLM:
    vocab: int
    branching: int = 8          # successors per token
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.succ = rng.integers(0, self.vocab,
                                 (self.vocab, self.branching), np.int64)
        probs = rng.dirichlet(np.ones(self.branching) * 0.5, self.vocab)
        self.cum = np.cumsum(probs, axis=1)

    def sample(self, batch: int, seq: int, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, 0xC1CADA]))
        out = np.empty((batch, seq + 1), np.int64)
        out[:, 0] = rng.integers(0, self.vocab, batch)
        u = rng.random((batch, seq))
        for t in range(seq):
            k = (u[:, t:t + 1] < self.cum[out[:, t]]).argmax(axis=1)
            out[:, t + 1] = self.succ[out[:, t], k]
        return out

    def batch(self, batch: int, seq: int, step: int
              ) -> Dict[str, np.ndarray]:
        toks = self.sample(batch, seq, step)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def bigram_ce_floor(self, n: int = 4096) -> float:
        """Entropy of the chain — the loss floor a perfect model reaches."""
        probs = np.diff(np.concatenate(
            [np.zeros((self.vocab, 1)), self.cum], axis=1), axis=1)
        h = -(probs * np.log(np.maximum(probs, 1e-12))).sum(axis=1)
        return float(h.mean())


def host_batches(gen: MarkovLM, *, global_batch: int, seq: int,
                 host_id: int = 0, n_hosts: int = 1,
                 start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Deterministic per-host shard of the global batch stream."""
    local = global_batch // n_hosts
    step = start_step
    while True:
        full = gen.batch(global_batch, seq, step)
        yield {k: v[host_id * local:(host_id + 1) * local]
               for k, v in full.items()}
        step += 1
