"""Production mesh definitions.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run forces 512 host devices *before*
any jax initialization; everything else sees the real topology).

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the `pod` axis is
the DCN dimension (gradient reduce / FSDP outer axis), `model` stays
inside the ICI domain.
"""
from __future__ import annotations

from typing import Sequence

import jax


def _make_mesh(shape, axes) -> jax.sharding.Mesh:
    # jax 0.4.x `make_mesh` has no ``axis_types`` parameter (it appeared
    # in 0.5+, where Auto is also the default) — call it portably.
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Whatever this process actually has (tests / smoke runs)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return _make_mesh((n // mp, mp), ("data", "model"))


def make_serving_mesh(mesh_shape: Sequence[int]) -> jax.sharding.Mesh:
    """A ("data", "model") mesh of exactly ``prod(mesh_shape)`` local
    devices — the serving stack's knob for sharded cold starts.  A 1-d
    shape means pure model parallelism: ``(4,)`` == ``(1, 4)``."""
    shape = tuple(int(s) for s in mesh_shape)
    if len(shape) == 1:
        shape = (1,) + shape
    if len(shape) != 2:
        raise ValueError(f"mesh_shape must be 1- or 2-d, got {mesh_shape}")
    need = shape[0] * shape[1]
    have = len(jax.devices())
    if need > have:
        raise ValueError(
            f"mesh_shape {shape} needs {need} devices, have {have} "
            f"(CPU simulation: set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need})")
    return _make_mesh(shape, ("data", "model"))
