"""Production mesh definitions.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run forces 512 host devices *before*
any jax initialization; everything else sees the real topology).

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the `pod` axis is
the DCN dimension (gradient reduce / FSDP outer axis), `model` stays
inside the ICI domain.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Whatever this process actually has (tests / smoke runs)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh(
        (n // mp, mp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
