"""Serving launcher: trace-driven serverless inference with Cicada.

``python -m repro.launch.serve --strategy cicada --models smollm-360m``

Deploys the requested models to a local weight store (with a simulated
storage device so the I/O phase is visible), generates an Azure-like
invocation trace, replays it through the ServerlessPlatform and prints
per-strategy latency / utilization statistics.

``--workload generate --n-new 16`` replays the same trace as
*generation* requests: each invocation decodes n-new tokens through the
instances' continuous-batching DecodeSchedulers, and the report adds
TTFT / TPOT / tokens-per-second.

``--mesh 4`` streams weights shard-granularly onto a 4-way model-
parallel device mesh (one byte-range retrieval stream per device, each
on its own simulated store channel) and serves warm requests from the
mesh-sharded params.  On CPU the devices are simulated — the flag below
is set automatically when unset.

``--pallas {auto,pallas,interpret,ref}`` forces the kernel dispatch
registry for every jitted serving path (default: auto — capability-
probed per kernel; see :mod:`repro.kernels.ops`).

``--nodes N`` serves the trace from an N-node cluster
(:mod:`repro.cluster`): a locality-aware front-end router places each
invocation on the node already warm / cache-resident for the model,
and scale-out cold starts stream weights from peer nodes over the
intra-cluster link (``--cluster-bw-mbps``) instead of re-reading the
shared origin store — at most one origin read per shard, cluster-wide.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

# Must precede the jax import: jax locks the device count on first init.
# A CPU run of `--mesh N` needs N simulated host devices.
if "XLA_FLAGS" not in os.environ:
    _n = 0
    for _i, _a in enumerate(sys.argv):
        try:
            if _a == "--mesh":
                _n = int(sys.argv[_i + 1])
            elif _a.startswith("--mesh="):
                _n = int(_a.split("=", 1)[1])
        except (IndexError, ValueError):
            _n = 4
    if _n > 1:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={_n}"

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.api import get_config
from repro.serving.api import GenerateSpec
from repro.serving.engine import ServerlessPlatform
from repro.serving.trace import azure_like_trace, summarize
from repro.store.store import BandwidthModel, WeightStore, deploy_model


def example_batch(cfg, seq: int = 32):
    rng = np.random.default_rng(0)
    if cfg.family.value == "vision":
        return {"image": jnp.asarray(
            rng.standard_normal((1, 3, cfg.img_res, cfg.img_res)),
            jnp.float32)}
    if cfg.family.value == "audio":
        return {"frames": jnp.asarray(
            rng.standard_normal((1, seq, cfg.frontend_dim)),
            jnp.bfloat16)}
    if cfg.family.value == "vlm":
        n_img = min(8, seq // 2)
        return {"tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (1, seq - n_img)),
                    jnp.int32),
                "img": jnp.asarray(
                    rng.standard_normal((1, n_img, cfg.frontend_dim)),
                    jnp.bfloat16)}
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (1, seq)), jnp.int32)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="+", default=["smollm-360m"])
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--strategy", default="cicada",
                    choices=["traditional", "pisel", "mini", "preload",
                             "cicada"])
    ap.add_argument("--invocations", type=int, default=40)
    ap.add_argument("--duration", type=float, default=600.0)
    ap.add_argument("--keep-alive", type=float, default=30.0)
    ap.add_argument("--concurrency", type=int, default=1,
                    help="router workers / max in-flight invocations")
    ap.add_argument("--max-instances", type=int, default=1,
                    help="instance-pool scale-out limit per model")
    ap.add_argument("--workload", default="oneshot",
                    choices=["oneshot", "generate"],
                    help="oneshot: batch->logits forwards (seed "
                         "semantics); generate: multi-token decode "
                         "through the continuous-batching scheduler")
    ap.add_argument("--n-new", type=int, default=16,
                    help="tokens to generate per invocation "
                         "(--workload generate)")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="prompt length for generation invocations")
    ap.add_argument("--gen-slots", type=int, default=8,
                    help="decode-scheduler slots per instance "
                         "(max concurrent generations batching)")
    ap.add_argument("--gen-cache-len", type=int, default=256,
                    help="KV cache positions per slot")
    ap.add_argument("--kv-page-tokens", type=int, default=None,
                    metavar="PT",
                    help="enable block-paged decode KV: full-attention "
                         "K/V lives in a shared refcounted pool of "
                         "PT-token pages (page-budget admission + "
                         "prefix caching) instead of per-slot arena "
                         "rows (default: slotted)")
    ap.add_argument("--kv-budget-mb", type=float, default=None,
                    help="with --kv-page-tokens: device byte budget for "
                         "the page pool across all attention layers "
                         "(default: the slotted arena's worth, "
                         "gen-slots x gen-cache-len tokens)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 = sampled generation")
    ap.add_argument("--cache-budget-mb", type=float, default=None,
                    help="enable the node-local shared WeightCache with "
                         "this byte budget (0 = unbounded; default: no "
                         "cache)")
    ap.add_argument("--mesh", type=int, default=1,
                    help="model-parallel mesh width: stream weights "
                         "shard-granularly onto (1, N) devices and "
                         "serve warm requests sharded (1 = seed path)")
    ap.add_argument("--pallas", default=None,
                    choices=["auto", "pallas", "interpret", "ref"],
                    help="force the kernel dispatch registry for every "
                         "jitted serving path (default: capability-"
                         "probed auto; see repro.kernels.ops)")
    ap.add_argument("--compute-quant", action="store_true",
                    help="serve int8 weights in place: deploy models "
                         "quantized (int8 values + per-column scales), "
                         "keep them quantized-resident across cold "
                         "starts (~quarter the f32 bytes) and run "
                         "weight matmuls through the fused-dequant "
                         "quant_matmul kernel (single device only)")
    ap.add_argument("--nodes", type=int, default=1,
                    help="serve from an N-node cluster (repro.cluster): "
                         "locality-aware routing + peer-to-peer shard "
                         "exchange (1 = single-node platform)")
    ap.add_argument("--cluster-bw-mbps", type=float, default=1000.0,
                    help="--nodes N: intra-cluster link bandwidth, one "
                         "channel per node (0 = unthrottled)")
    ap.add_argument("--bandwidth-mbps", type=float, default=400.0,
                    help="simulated store bandwidth per channel; with "
                         "--mesh N the store exposes N channels (one "
                         "independent link per device)")
    ap.add_argument("--store", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the platform's metrics snapshot (the "
                         "scrapeable counter/gauge/histogram JSON) to "
                         "this path after the replay")
    ap.add_argument("--autoscale", action="store_true",
                    help="attach the SLO autoscaler: pre-provision warm "
                         "instances on arrival-rate slope / queue "
                         "depth, scale-in on idle")
    ap.add_argument("--rps-per-instance", type=float, default=2.0,
                    help="--autoscale: arrival rate one warm instance "
                         "is budgeted to absorb")
    args = ap.parse_args(argv)

    if args.pallas:
        from repro.kernels import ops
        ops.set_mode(None if args.pallas == "auto" else args.pallas)

    if args.compute_quant and (args.mesh > 1 or args.nodes > 1):
        raise SystemExit("--compute-quant serves int8 leaves in place on "
                         "a single device; not supported with --mesh/"
                         "--nodes")

    store_dir = args.store or tempfile.mkdtemp(prefix="cicada-store-")
    store = WeightStore(store_dir,
                        BandwidthModel(args.bandwidth_mbps, 0.2,
                                       channels=max(1, args.mesh)))

    builders = {}
    for name in args.models:
        cfg = get_config(name, smoke=args.smoke)
        model = transformer.build(cfg)
        if args.workload == "generate" and not hasattr(model,
                                                       "decode_step"):
            raise SystemExit(
                f"--workload generate needs decoder LMs, got {name!r} "
                f"({cfg.family.value}); try --models smollm-360m")
        if not store.has_model(name):
            print(f"deploying {name} "
                  f"({cfg.param_count() / 1e6:.1f}M params"
                  f"{', int8' if args.compute_quant else ''}) ...")
            deploy_model(store, model, name, jax.random.key(args.seed),
                         quant="int8" if args.compute_quant else None)
        builders[name] = (lambda m=model, c=cfg:
                          (m, example_batch(c)))

    trace = azure_like_trace(duration_s=args.duration,
                             n_invocations=args.invocations,
                             models=args.models, seed=args.seed)
    print("trace:", summarize(trace))

    cache_budget = None if args.cache_budget_mb is None \
        else int(args.cache_budget_mb * 1e6)
    is_cluster = args.nodes > 1
    if is_cluster:
        if args.autoscale:
            raise SystemExit("--autoscale is a per-node policy; not "
                             "supported with --nodes > 1")
        if args.kv_page_tokens:
            raise SystemExit("--kv-page-tokens is per-node scheduler "
                             "state; not yet plumbed with --nodes > 1")
        from repro.cluster import ClusterPlatform
        # the peer tier requires per-node caches: default unbounded
        platform = ClusterPlatform(
            store, builders, n_nodes=args.nodes,
            cluster_bw_mbps=args.cluster_bw_mbps,
            cache_budget_bytes=0 if cache_budget is None else cache_budget,
            strategy=args.strategy, keep_alive_s=args.keep_alive,
            max_instances=args.max_instances, gen_slots=args.gen_slots,
            gen_cache_len=args.gen_cache_len,
            mesh_shape=(1, args.mesh) if args.mesh > 1 else None)
    else:
        platform = ServerlessPlatform(
            store, builders, strategy=args.strategy,
            keep_alive_s=args.keep_alive,
            max_instances=args.max_instances,
            cache_budget_bytes=cache_budget,
            gen_slots=args.gen_slots,
            gen_cache_len=args.gen_cache_len,
            kv_page_tokens=args.kv_page_tokens,
            kv_budget_bytes=None if args.kv_budget_mb is None
            else int(args.kv_budget_mb * 1e6),
            mesh_shape=(1, args.mesh) if args.mesh > 1 else None,
            compute_quant=args.compute_quant,
            autoscale=dict(rps_per_instance=args.rps_per_instance)
            if args.autoscale else None)
        if platform.autoscaler is not None:
            platform.autoscaler.start()

    def make_batch(name):
        return example_batch(get_config(name, smoke=args.smoke))

    make_spec = None
    if args.workload == "generate":
        rng = np.random.default_rng(args.seed)

        def make_spec(name):
            cfg = get_config(name, smoke=args.smoke)
            return GenerateSpec(
                prompt=rng.integers(0, cfg.vocab_size,
                                    (args.prompt_len,)).astype(np.int32),
                n_new=args.n_new, temperature=args.temperature,
                seed=args.seed)

    responses = platform.run_trace(trace, make_batch,
                                   concurrency=args.concurrency,
                                   make_spec=make_spec)
    lat = np.array([r.latency_s for r in responses])
    cold = np.array([r.cold for r in responses])
    print(f"strategy={args.strategy}  n={len(responses)}  "
          f"cold={cold.sum()} ({cold.mean():.0%})  "
          f"concurrency={args.concurrency}")
    print(f"latency: mean={lat.mean() * 1e3:.1f}ms "
          f"p50={np.percentile(lat, 50) * 1e3:.1f}ms "
          f"p99={np.percentile(lat, 99) * 1e3:.1f}ms")
    if cold.any():
        cl = lat[cold]
        ut = np.array([r.utilization for r in responses])[cold]
        print(f"cold-start: mean={cl.mean() * 1e3:.1f}ms "
              f"pipeline-util={ut.mean():.1%}")
    if args.workload == "generate":
        ttft = np.array([r.ttft_s for r in responses])
        tpot = np.concatenate([r.tpot_s for r in responses
                               if r.tpot_s]) if any(
            r.tpot_s for r in responses) else np.array([0.0])
        n_tok = sum(r.n_generated for r in responses)
        span = max(r.t_done for r in responses) - \
            min(r.t_arrival for r in responses)
        print(f"generation: n_new={args.n_new}  total-tokens={n_tok}  "
              f"tokens/s={n_tok / max(span, 1e-9):.1f}")
        print(f"TTFT: p50={np.percentile(ttft, 50) * 1e3:.1f}ms "
              f"p99={np.percentile(ttft, 99) * 1e3:.1f}ms   "
              f"TPOT: mean={tpot.mean() * 1e3:.2f}ms")
        if cold.any():
            ct = ttft[cold]
            cl2 = np.array([r.load_s for r in responses])[cold]
            print(f"cold TTFT: mean={ct.mean() * 1e3:.1f}ms "
                  f"(load {cl2.mean() * 1e3:.1f}ms — first token "
                  f"in-pipeline: {bool((ct < cl2).all())})")
    if args.concurrency > 1 and not is_cluster:
        q = np.array([r.queue_s for r in responses])
        rs = platform.last_router_stats
        print(f"queueing: mean={q.mean() * 1e3:.1f}ms "
              f"max={q.max() * 1e3:.1f}ms  "
              f"max-in-flight={rs.max_in_flight}")
    if is_cluster:
        served = np.array([r.node for r in responses])
        for nd in platform.nodes:
            ps = nd.platform.pool_stats()
            print(f"node[{nd.node_id}]: "
                  f"served={int((served == nd.node_id).sum())} "
                  f"cold={sum(p.cold_starts for p in ps.values())} "
                  f"warm={sum(p.warm_hits for p in ps.values())} "
                  f"origin-reads={nd.origin_reads():.0f} "
                  f"peer-reads={nd.peer_reads():.0f}")
        snap = platform.cluster_snapshot()
        agg = snap["cluster"]["counters"]
        print(f"cluster: origin-reads="
              f"{agg.get('cluster/origin_reads', 0):.0f} "
              f"peer-reads={agg.get('cluster/peer_reads', 0):.0f} "
              f"peer-bytes={agg.get('cluster/peer_bytes', 0) / 1e6:.1f}MB")
        pl = snap["placement"]
        print(f"placement: models={pl['models']} "
              f"origin-elections={pl['origin_elections']} "
              f"peer-referrals={pl['peer_referrals']}")
    else:
        for name, ps in platform.pool_stats().items():
            print(f"pool[{name}]: instances={ps.size} live={ps.live} "
                  f"cold={ps.cold_starts} warm={ps.warm_hits} "
                  f"evictions={ps.evictions}")
        cs = platform.cache_stats()
        if cs is not None:
            print(f"weight-cache: hits={cs.hits} misses={cs.misses} "
                  f"deduped-reads={cs.waits} evictions={cs.evictions} "
                  f"resident={cs.bytes_cached / 1e6:.1f}MB "
                  f"hit-rate={cs.hit_rate:.0%}")
        if platform.autoscaler is not None:
            platform.autoscaler.stop()
    if args.metrics_out:
        import json
        snap = platform.cluster_snapshot() if is_cluster \
            else platform.metrics_snapshot()
        with open(args.metrics_out, "w") as f:
            json.dump(snap, f, indent=2)
        if is_cluster:
            print(f"cluster snapshot -> {args.metrics_out} "
                  f"({snap['n_nodes']} nodes)")
        else:
            print(f"metrics snapshot -> {args.metrics_out} "
                  f"({len(snap['counters'])} counters, "
                  f"{len(snap['gauges'])} gauges, "
                  f"{len(snap['histograms'])} histograms)")
    return responses


if __name__ == "__main__":
    main()
