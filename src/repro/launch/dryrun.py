import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The dry-run (and only the dry-run) builds the production meshes out of
# 512 placeholder host devices; smoke tests and benches see 1 device.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, on the single-pod 16x16
mesh and the 2x16x16 multi-pod mesh:

  1. **proof compile** — jit the full (scan-stacked) step with explicit
     in/out shardings, ``.lower().compile()``; print
     ``memory_analysis()`` (fits-HBM evidence) and record the
     collective schedule;
  2. **cost compiles** (single-pod) — the same step at depth 1 and 2
     pattern-units with the layer loop *unrolled* (XLA cost analysis
     visits a while body once, so scanned costs undercount by the trip
     count); totals combine linearly:
     ``total = c1 + (n_units - 1) * (c2 - c1)``.

Outputs one JSON record per cell for ``benchmarks/roofline.py``.

Usage:
  python -m repro.launch.dryrun --all [--multi-pod] [--out dryrun.json]
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, SHAPES, ShapeCell, supported
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models.api import ArchConfig, Family, get_config
from repro.training.optim import AdamW
from repro.training.train import make_train_step

PyTree = Any
HBM_PER_CHIP = 16 * 1024 ** 3          # v5e: 16 GiB


# ---------------------------------------------------------------------------
# abstract inputs + shardings per cell kind
# ---------------------------------------------------------------------------

def _cast_abstract(tree: PyTree, dtype) -> PyTree:
    def f(l):
        if jnp.issubdtype(l.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(l.shape, dtype)
        return l
    return jax.tree.map(f, tree)


def train_micro_batches(cell: ShapeCell, mesh, micro_rows: int = 2) -> int:
    """Gradient-accumulation factor: ``micro_rows`` sequences per device
    per microbatch (default 2, the realistic pod-scale configuration).
    Fewer microbatches -> fewer FSDP weight re-gathers (collective
    term) but proportionally more activation memory."""
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    m = max(1, cell.batch // (dp * micro_rows))
    while cell.batch % m:
        m -= 1
    return m


def build_cell(cfg: ArchConfig, cell: ShapeCell, mesh, *,
               unroll: bool = False,
               serve_dtype=jnp.bfloat16,
               mixed_precision: bool = False,
               micro_rows: int = 2,
               chunked_prefill: int = 0):
    """Returns (fn, abstract_args, in_shardings, out_shardings, donate).

    unroll=True is the cost-lowering mode: layer loop unrolled AND (for
    train) a single microbatch of the global batch — the caller scales
    the measured costs back up by the microbatch count.

    Perf-iteration levers (§Perf):
      mixed_precision — bf16 param copy inside the train step;
      chunked_prefill — process prompts in N-token segments
                        (full-attention decoder LMs).
    """
    model = transformer.build(cfg)
    multi_pod = "pod" in mesh.shape
    if cell.kind == "train":
        rules = shd.train_rules(multi_pod=multi_pod)
    else:
        rules = shd.serve_rules(multi_pod=multi_pod)
        # big models cannot serve with TP-16 alone: bf16 params must
        # shard the full mesh (per-layer weight gathers are the price)
        if cfg.param_count() * 2 / 16 > 8e9:
            rules.mapping["fsdp"] = ("pod", "data") if multi_pod \
                else ("data",)

    if cell.kind == "train":
        micro = train_micro_batches(cell, mesh, micro_rows)
        batch_size = cell.batch // micro if unroll else cell.batch
        specs = model.input_specs(cell.kind, cell.seq, batch_size)
        batch_sh = shd.batch_specs(specs, mesh, rules)
        params_ab = model.abstract()
        opt = AdamW(lr=1e-4)
        opt_ab = jax.eval_shape(opt.init, params_ab)
        p_sh = shd.param_specs(params_ab, mesh, rules)
        # m/v mirror the param shardings; step scalar replicated
        o_sh = type(opt_ab)(shd.replicated(mesh),
                            shd.param_specs(opt_ab.m, mesh, rules),
                            shd.param_specs(opt_ab.v, mesh, rules))
        fn = make_train_step(model, opt, remat=True,
                             micro_batches=1 if unroll else micro,
                             unroll=unroll, mixed_precision=mixed_precision)
        args = (params_ab, opt_ab, specs)
        in_sh = (p_sh, o_sh, batch_sh)
        out_sh = (p_sh, o_sh, None)
        return fn, args, in_sh, out_sh, (0, 1), rules, model

    specs = model.input_specs(cell.kind, cell.seq, cell.batch)
    batch_sh = shd.batch_specs(specs, mesh, rules)

    params_ab = _cast_abstract(model.abstract(), serve_dtype)
    p_sh = shd.param_specs(params_ab, mesh, rules)

    if cell.kind == "prefill":
        if cfg.is_encoder:
            def fn(params, batch):
                return model.forward(params, batch, unroll=unroll)[0]
            return fn, (params_ab, specs), (p_sh, batch_sh), None, (), \
                rules, model
        cache_ab = model.abstract_cache(cell.batch, cell.seq)
        c_sh = shd.cache_specs(cache_ab, mesh, rules)

        chunkable = (chunked_prefill > 0 and cfg.sliding_window == 0
                     and cfg.family not in (Family.SSM, Family.HYBRID))
        if chunkable:
            def fn(params, batch, cache):
                return model.prefill_chunked(params, batch, cache,
                                             chunk=chunked_prefill,
                                             unroll=unroll)
        else:
            def fn(params, batch, cache):
                return model.prefill(params, batch, cache, unroll=unroll)
        return fn, (params_ab, specs, cache_ab), (p_sh, batch_sh, c_sh), \
            None, (2,), rules, model

    # decode
    cache_ab = model.abstract_cache(cell.batch, cell.seq)
    c_sh = shd.cache_specs(cache_ab, mesh, rules)

    def fn(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, unroll=unroll)
    args = (params_ab, cache_ab, specs["tokens"], specs["pos"])
    in_sh = (p_sh, c_sh, batch_sh["tokens"], batch_sh["pos"])
    return fn, args, in_sh, None, (1,), rules, model


def _reduced_cfg(cfg: ArchConfig, n_units: int) -> ArchConfig:
    if cfg.family == Family.HYBRID:
        u = len(cfg.block_pattern or ("rglru", "rglru", "attn"))
    else:
        u = 1
    tail = cfg.n_layers % u
    return dataclasses.replace(cfg, n_layers=n_units * u + tail)


def _n_units(cfg: ArchConfig) -> int:
    if cfg.family == Family.HYBRID:
        u = len(cfg.block_pattern or ("rglru", "rglru", "attn"))
    else:
        u = 1
    return cfg.n_layers // u


# ---------------------------------------------------------------------------
# per-cell dry run
# ---------------------------------------------------------------------------

def _compile(cfg, cell, mesh, *, unroll: bool, **opt_flags):
    fn, args, in_sh, out_sh, donate, rules, model = build_cell(
        cfg, cell, mesh, unroll=unroll, **opt_flags)
    with shd.use_rules(mesh, rules):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def _memory_record(compiled) -> Dict[str, Any]:
    m = compiled.memory_analysis()
    rec = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        rec[k] = int(getattr(m, k, 0))
    live = rec["argument_size_in_bytes"] + rec["temp_size_in_bytes"] \
        + rec["output_size_in_bytes"] - rec["alias_size_in_bytes"]
    rec["live_bytes_per_device"] = live
    rec["fits_hbm_16g"] = bool(live <= HBM_PER_CHIP)
    return rec


def _cost_record(compiled) -> Dict[str, Any]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):          # jax 0.4.x: one dict/program
        ca = ca[0] if ca else {}
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "collectives": coll}


def dryrun_cell(arch: str, shape: str, *, multi_pod: bool = False,
                costs: bool = True, smoke: bool = False,
                opts: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    opts = opts or {}
    cfg = get_config(arch, smoke=smoke)
    cell = SHAPES[shape]
    if smoke:
        cell = dataclasses.replace(cell, seq=min(cell.seq, 128),
                                   batch=min(cell.batch, 32))
    rec: Dict[str, Any] = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi_pod else "16x16"}
    if opts:
        rec["opts"] = dict(opts)
    ok, reason = supported(cfg, cell)
    if not ok:
        rec["status"] = "skip"
        rec["skip_reason"] = reason
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        rec["devices"] = int(mesh.size)
        t0 = time.monotonic()
        _, compiled = _compile(cfg, cell, mesh, unroll=False, **opts)
        rec["compile_s"] = round(time.monotonic() - t0, 2)
        rec["memory"] = _memory_record(compiled)
        # collective schedule of the production (scanned) program — counts
        # are per-trip; roofline uses the unrolled cost compiles below.
        rec["scan_collectives"] = hlo_analysis.collective_bytes(
            compiled.as_text())["_counts"]
        del compiled

        if costs:
            t0 = time.monotonic()
            c1 = _cost_record(_compile(_reduced_cfg(cfg, 1), cell, mesh,
                                       unroll=True, **opts)[1])
            c2 = _cost_record(_compile(_reduced_cfg(cfg, 2), cell, mesh,
                                       unroll=True, **opts)[1])
            rec["cost_compile_s"] = round(time.monotonic() - t0, 2)
            n = _n_units(cfg)
            cost = hlo_analysis.combine_linear(c1, c2, n)
            if cell.kind == "train":
                # cost compiles ran ONE microbatch; scale to the full step
                micro = train_micro_batches(
                    cell, mesh, opts.get("micro_rows", 2))
                cost = hlo_analysis.scale_cost(cost, micro)
                rec["micro_batches"] = micro
            rec["cost_per_device"] = cost
            rec["n_units"] = n
        rec["status"] = "ok"
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def iter_cells():
    for arch in ASSIGNED:
        for shape in SHAPES:
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-costs", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (machinery self-test)")
    ap.add_argument("--mixed-precision", action="store_true",
                    help="perf lever: bf16 param copy in the train step")
    ap.add_argument("--micro-rows", type=int, default=2,
                    help="perf lever: sequences/device/microbatch")
    ap.add_argument("--chunked-prefill", type=int, default=0,
                    help="perf lever: prefill segment length (0 = off)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    opts = {}
    if args.mixed_precision:
        opts["mixed_precision"] = True
    if args.micro_rows != 2:
        opts["micro_rows"] = args.micro_rows
    if args.chunked_prefill:
        opts["chunked_prefill"] = args.chunked_prefill
    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    records = []
    for arch, shape in cells:
        for mp in meshes:
            # roofline costs are a single-pod deliverable
            costs = (not args.no_costs) and not mp
            rec = dryrun_cell(arch, shape, multi_pod=mp, costs=costs,
                              smoke=args.smoke, opts=opts)
            records.append(rec)
            status = rec["status"]
            extra = ""
            if status == "ok":
                mem = rec["memory"]["live_bytes_per_device"] / 2 ** 30
                extra = f"live/dev={mem:.2f}GiB compile={rec['compile_s']}s"
                if "cost_per_device" in rec:
                    c = rec["cost_per_device"]
                    extra += (f" flops/dev={c['flops']:.3e}"
                              f" coll/dev={c['collectives']['total']:.3e}B")
            elif status == "skip":
                extra = rec["skip_reason"]
            else:
                extra = rec["error"]
            print(f"[{rec['mesh']:7s}] {arch:18s} {shape:12s} {status:5s} "
                  f"{extra}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    n_fail = sum(r["status"] == "fail" for r in records)
    if n_fail:
        raise SystemExit(f"{n_fail} cells FAILED")


if __name__ == "__main__":
    main()
