"""HLO text analysis: collective-byte accounting for the roofline.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but not
collective traffic; we parse the (SPMD-partitioned) HLO text and sum
the result-shape bytes of every collective op, per kind.  Sync and
async (``-start``) forms are recognized; ``-done`` lines are skipped so
nothing is double-counted.

The dry-run lowers cost graphs with *no while loops* (layer scan
unrolled at reduced depth, inner block loops are Python loops), so a
flat line scan is exact — no trip-count attribution is needed.
"""
from __future__ import annotations

import re
from typing import Dict

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^=]*?\)|[a-z0-9_\[\],{}\s]*?)\s*"
    r"(?P<kind>" + "|".join(COLLECTIVE_KINDS) + r")(?P<async>-start)?\(")


def shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """kind -> summed result bytes over all collective ops."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        out[kind] += shape_bytes(m.group("shape"))
        counts[kind] += 1
    out_named = {k: v for k, v in out.items() if v}
    out_named["_counts"] = {k: v for k, v in counts.items() if v}
    out_named["total"] = sum(v for k, v in out.items())
    return out_named


def scale_cost(c: dict, factor: float) -> dict:
    """Multiply every numeric entry (e.g. per-microbatch -> full step)."""
    def f(v):
        if isinstance(v, dict):
            return {k: f(x) for k, x in v.items()}
        return v * factor
    return f(c)


def combine_linear(c1: dict, c2: dict, n_units: int) -> dict:
    """Total cost for n_units pattern units from 1-unit (c1) and 2-unit
    (c2) measurements: total = c1 + (n_units - 1) * (c2 - c1).

    Applied elementwise to numeric entries (flops, bytes, collective
    bytes per kind).  Negative per-unit deltas (compiler noise /
    CSE differences) clamp to zero.
    """
    def comb(a, b):
        if isinstance(a, dict):
            keys = set(a) | set(b)
            return {k: comb(a.get(k, 0), b.get(k, 0)) for k in keys}
        per_unit = max(b - a, 0)
        return a + (n_units - 1) * per_unit
    return comb(c1, c2)
