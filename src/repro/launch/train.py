"""Training launcher: ``python -m repro.launch.train --arch smollm-360m``.

Drives the full stack on whatever devices this process has: mesh
construction, sharded param init, pjit'd train step (remat + optional
int8 error-feedback DP compression), Markov data pipeline, atomic
checkpointing with resume, preemption-safe loop.

On a real pod this same file runs under the multi-host runtime
(jax.distributed.initialize is a no-op on one process); the mesh comes
from ``make_production_mesh`` instead of ``make_local_mesh``.
"""
from __future__ import annotations

import argparse
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.compression import (init_error_feedback,
                                           make_error_feedback_transform)
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import transformer
from repro.models.api import get_config
from repro.training.data import MarkovLM, host_batches
from repro.training.optim import AdamW, warmup_cosine
from repro.training.train import TrainLoop, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = transformer.build(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh(args.model_parallel))
    rules = shd.train_rules()
    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    opt = AdamW(lr=warmup_cosine(args.lr, args.steps // 10, args.steps),
                weight_decay=0.01)

    with shd.use_rules(mesh, rules):
        params_ab = model.abstract()
        p_sh = shd.param_specs(params_ab, mesh, rules)
        params = jax.jit(model.init, out_shardings=p_sh)(
            jax.random.key(args.seed))
        opt_state = jax.jit(opt.init)(params)

        ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        start_step = 0
        if ckpt and args.resume and ckpt.latest_step() is not None:
            opt_ab = jax.eval_shape(opt.init, params_ab)
            start_step, params, opt_state = ckpt.restore(
                params_ab, opt_ab, shardings=p_sh)
            print(f"resumed from step {start_step}")

        if args.compress_grads:
            # the error-feedback residual is jit-carried state, folded
            # into the opt_state slot; it is deliberately NOT part of
            # the checkpoint (soft state — a restart loses one step's
            # residual, which error feedback re-absorbs)
            ef_transform = make_error_feedback_transform()

            def _step(params, state, batch):
                adam_state, ef = state
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: model.loss(p, batch), has_aux=True)(params)
                grads, ef = ef_transform(grads, ef)
                params, adam_state, om = opt.update(grads, adam_state,
                                                    params)
                metrics = dict(metrics)
                metrics.update(om)
                metrics["loss"] = loss
                return params, (adam_state, ef), metrics

            step_fn = jax.jit(_step, donate_argnums=(0, 1))
            opt_state = (opt_state, init_error_feedback(params))
            if ckpt is not None:
                import types
                inner = ckpt

                def save(step, params, state):
                    return inner.save(step, params, state[0])
                ckpt = types.SimpleNamespace(save=save,
                                             latest_step=inner.latest_step)
        else:
            step_fn = jax.jit(make_train_step(model, opt),
                              donate_argnums=(0, 1))
        data = MarkovLM(cfg.vocab_size, seed=args.seed)
        batches = host_batches(data, global_batch=args.batch, seq=args.seq,
                               start_step=start_step)
        loop = TrainLoop(model, opt, step_fn=step_fn, checkpointer=ckpt,
                         ckpt_every=args.ckpt_every)
        loop.install_signal_handler()
        params, opt_state, hist = loop.run(
            params, opt_state, batches, start_step=start_step,
            n_steps=args.steps)
        print(f"final loss {hist['loss'][-1]:.4f} "
              f"(bigram floor ~{data.bigram_ce_floor():.3f})")
        return hist


if __name__ == "__main__":
    main()
