"""The five loading strategies the paper evaluates (Sec. IV-A).

  traditional — Fig. 1: all layers constructed, then monolithic weight
                loading, then inference.  No pipelining.
  pisel       — the CIKM'24 baseline: 3-unit layer-wise pipeline
                (L_i -> W_i+A_i fused -> E_i), full numerical init,
                retrieval starts only after L_i completes.
  mini        — PISeL + MiniLoader (abstract construction, 1-bit
                placeholders).
  preload     — PISeL + WeightDecoupler (async retrieval issued at
                request arrival, out-of-order application) + the
                Priority-Aware Scheduler.
  cicada      — mini + preload (+ scheduler): the full system.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str
    mini: bool            # MiniLoader construction
    decouple: bool        # WeightDecoupler: async retrieval + OOO apply
    pipelined: bool       # layer-wise 3-unit pipeline (False: Fig. 1)
    scheduler: bool       # Priority-Aware Scheduler (Algorithm 1)


STRATEGIES = {
    "traditional": Strategy("traditional", False, False, False, False),
    "pisel": Strategy("pisel", False, False, True, False),
    "mini": Strategy("mini", True, False, True, False),
    "preload": Strategy("preload", False, True, True, True),
    "cicada": Strategy("cicada", True, True, True, True),
}


def get_strategy(name: str) -> Strategy:
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; have {sorted(STRATEGIES)}")
    return STRATEGIES[name]
