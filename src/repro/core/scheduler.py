"""Priority-Aware Scheduler (paper Sec. III-E, Algorithm 1).

Asynchronous retrieval completes in unpredictable order; if layer L_i's
structure is ready but its weight file W_i is *late* — past its expected
completion time ``(t_issue + a) + D_{W_i}`` — every other in-flight
retrieval stream is suspended (cooperative gates cleared) so W_i gets
the full I/O bandwidth.  Streams resume when W_i completes.

Under shard-granular cold starts one layer unit is retrieved by several
concurrent *shard streams* (one per mesh device, each on its own
simulated-device channel).  Streams register as ``(unit, shard)``;
Algorithm 1 still reasons about *units* — the pipeline needs unit i's
weights, which land when its **last** shard lands — so a unit's
expected completion is the max over its in-flight shard streams, and
prioritizing a late unit suspends every stream of every *other* unit
(its own shards keep all their channels).

Expected durations D_W are size-based: ``nbytes / bw_estimate`` with an
EMA of observed stream bandwidth (the paper's "records the execution
times of each ... weight file (W) operation").  Shard streams observe
per-channel bandwidth — their sizes are per-shard, so the deadline of a
shard stream is exactly its own channel's expected service time.  ``a``
is the measured pipeline-unit scheduling overhead.

Complexity matches the paper: O(n) over in-flight streams to suspend,
O(1) space per stream.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Hashable, Optional, Tuple

from repro import analysis

HIGH = "HIGH"
NORMAL = "NORMAL"


@dataclasses.dataclass
class StreamState:
    unit: str
    nbytes: int
    gate: threading.Event                 # set = may run; cleared = suspended
    shard: Hashable = 0                   # shard id ((unit, shard) is the key)
    t_issue: float = 0.0
    t_done: Optional[float] = None
    bytes_done: int = 0
    external: bool = False                # served by the WeightCache, not
                                          # a local device read

    @property
    def completed(self) -> bool:
        return self.t_done is not None


class PriorityAwareScheduler:
    def __init__(self, *, bw_bytes_per_s: float = 1e9,
                 a_overhead_s: float = 1e-3, enabled: bool = True):
        self.enabled = enabled
        self._lock = analysis.make_lock("PriorityAwareScheduler._lock")
        self._streams: Dict[Tuple[str, Hashable], StreamState] = {}  # guarded-by: _lock
        # EMA of observed bandwidth
        self._bw = bw_bytes_per_s                 # guarded-by: _lock
        self._a = a_overhead_s
        # unit being prioritized
        self._critical: Optional[str] = None      # guarded-by: _lock
        # observability / tests
        self.suspend_count = 0                    # guarded-by: _lock

    # ------------------------------------------------------------- streams
    def register(self, unit: str, nbytes: int, shard: Hashable = 0
                 ) -> StreamState:
        st = StreamState(unit, nbytes, threading.Event(), shard)
        st.gate.set()
        with self._lock:
            self._streams[(unit, shard)] = st
        return st

    def on_issue(self, unit: str, shard: Hashable = 0):
        with self._lock:
            self._streams[(unit, shard)].t_issue = time.monotonic()

    def on_progress(self, unit: str, done: int, total: int,
                    shard: Hashable = 0):
        with self._lock:
            self._streams[(unit, shard)].bytes_done = done

    def mark_external(self, unit: str, external: bool = True,
                      shard: Hashable = 0):
        """The stream is being served by the node-local WeightCache (a
        hit, or a wait on another load's read): it is not a local device
        read, so Algorithm 1 must neither prioritize it (suspending
        local streams cannot speed it up — and doing so across two
        concurrent loads that lead each other's units would deadlock)
        nor arm a bandwidth-based deadline for it."""
        with self._lock:
            self._streams[(unit, shard)].external = external

    def on_complete(self, unit: str, *, observed: bool = True,
                    shard: Hashable = 0):
        """``observed=False``: the stream finished without a device
        read (cache hit) — complete it without folding the ~zero
        duration into the bandwidth EMA."""
        with self._lock:
            st = self._streams[(unit, shard)]
            st.t_done = time.monotonic()
            if observed:
                dur = max(st.t_done - st.t_issue, 1e-9)
                obs = st.nbytes / dur
                self._bw = 0.7 * self._bw + 0.3 * obs
            if self._critical == unit and self._unit_done_locked(unit):
                self._critical = None
                for other in self._streams.values():
                    other.gate.set()       # resume suspended streams

    def on_error(self, unit: str, shard: Hashable = 0):
        """A stream failed: mark it done and lift any suspension so no
        other reader stays parked on a cleared gate forever.  Without
        this, a failed critical stream would leave ``_critical`` set
        and every suspended stream — including one acting as the
        node-local WeightCache's single-flight leader for a unit —
        blocked indefinitely, wedging all future loads of that unit."""
        with self._lock:
            st = self._streams.get((unit, shard))
            if st is not None and st.t_done is None:
                st.t_done = time.monotonic()
            self._critical = None
            for other in self._streams.values():
                other.gate.set()

    def _unit_done_locked(self, unit: str) -> bool:
        return all(st.completed for st in self._streams.values()
                   if st.unit == unit)

    # ---------------------------------------------------------- Algorithm 1
    def _expected_completion_locked(self, st: StreamState) -> float:
        return (st.t_issue + self._a) + st.nbytes / max(self._bw, 1.0)

    def expected_completion(self, unit: str, shard: Hashable = 0) -> float:
        with self._lock:
            return self._expected_completion_locked(
                self._streams[(unit, shard)])

    def time_until_expected(self, unit: str) -> Optional[float]:
        """Seconds until *unit*'s expected completion — the wake-up
        deadline an event-driven waiter arms to run Algorithm 1 at
        exactly the right moment.  A sharded unit completes when its
        last shard lands, so the deadline is the max over its issued,
        non-external, incomplete shard streams.  None = no deadline
        applies (scheduler disabled, unit unknown / nothing issued yet
        / completed, or the unit is already the prioritized critical
        one)."""
        if not self.enabled:
            return None
        with self._lock:
            if self._critical == unit:
                return None
            exp = None
            for st in self._streams.values():
                if st.unit != unit or st.completed or st.t_issue == 0.0 \
                        or st.external:
                    continue
                e = self._expected_completion_locked(st)
                exp = e if exp is None else max(exp, e)
            if exp is None:
                return None
            return max(0.0, exp - time.monotonic())

    def adjust_priority(self, unit: str) -> str:
        """Algorithm 1: called for the layer the pipeline needs next.

        If any of W_unit's shard streams is past its expected completion
        and still running, suspend every other unit's in-flight streams
        and mark the unit HIGH (all of its own shards keep their
        channels).
        """
        if not self.enabled:
            return NORMAL
        now = time.monotonic()
        with self._lock:
            late = False
            for st in self._streams.values():
                if st.unit != unit or st.completed or st.t_issue == 0.0 \
                        or st.external:
                    continue
                if now >= self._expected_completion_locked(st):
                    late = True
                    break
            if not late:
                return NORMAL
            for other in self._streams.values():            # O(n)
                if other.unit != unit and not other.completed:
                    other.gate.clear()                      # block W
                    self.suspend_count += 1
            for own in self._streams.values():
                if own.unit == unit:
                    own.gate.set()
            self._critical = unit
            return HIGH

    # --------------------------------------------------------------- lookup
    def gate(self, unit: str, shard: Hashable = 0) -> threading.Event:
        # R1 (real finding): this read raced register()'s dict insert
        # from concurrent shard streams before it took the lock
        with self._lock:
            return self._streams[(unit, shard)].gate

    def stats(self) -> dict:
        with self._lock:
            return {"bw_estimate": self._bw,
                    "suspends": self.suspend_count,
                    "streams": len(self._streams)}
