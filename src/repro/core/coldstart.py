"""ColdStartEngine: request -> live model, through the paper's pipeline.

Three execution units run as threads (exactly the paper's decomposition,
as :class:`~repro.core.units.PipelineUnit` objects on one event-driven
:class:`~repro.core.units.PipelineRuntime`):

  * **Layer unit** — constructs unit structures in order (MiniLoader or
    PISeL-faithful numerical init);
  * **Weight unit** — applies retrieved weights.  Under the
    WeightDecoupler, retrieval streams were issued at request arrival on
    an I/O pool and application is out-of-order; under PISeL, retrieval
    is fused into this unit and strictly ordered after L_i;
  * **Compute unit** — executes layer i's forward as soon as its weights
    are applied (and layer i-1 executed): the triggering request is
    answered *while the model is still loading*.

After the pipeline drains, the per-unit parameters are assembled into
the steady-state (scan-stacked) representation and handed to the serving
engine for warm requests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import miniloader
from repro.core.decoupler import WeightDecoupler
from repro.core.pipeline import PipelineTrace
from repro.core.scheduler import PriorityAwareScheduler
from repro.core.strategies import Strategy, get_strategy
from repro.core.units import (APPLIED, OUTPUT, PipelineContext,
                              PipelineRuntime, PipelineState, standard_units)
from repro.kernels import ops
from repro.store.cache import WeightCache
from repro.store.store import WeightStore, unflatten_unit

PyTree = Any


@dataclasses.dataclass
class LoadResult:
    logits: jax.Array            # first-request output (computed in-pipeline)
    params: PyTree               # assembled steady-state parameters
    trace: PipelineTrace
    strategy: str


class ColdStartEngine:
    def __init__(self, model, model_name: str, store: WeightStore, *,
                 strategy: str = "cicada", io_workers: int = 4,
                 chunk_bytes: int = 1 << 20,
                 apply_dtype=None, cache: Optional[WeightCache] = None):
        """apply_dtype: cast weights to this dtype at application time
        (None -> keep stored dtype).

        cache: node-local shared WeightCache — decoupled retrieval
        streams consult it before issuing I/O, so scale-out cold starts
        of the same model single-flight every store read."""
        self.model = model
        self.model_name = model_name
        self.store = store
        self.strategy: Strategy = get_strategy(strategy)
        self.io_workers = io_workers
        self.chunk_bytes = chunk_bytes
        self.apply_dtype = apply_dtype
        self.cache = cache
        self._jit_apply: Dict[str, Any] = {}

    # -------------------------------------------------------------- helpers
    def _apply_fn(self, unit: str):
        if unit not in self._jit_apply:
            model = self.model
            self._jit_apply[unit] = jax.jit(
                lambda p, s, _u=unit: model.unit_apply(_u, p, s))
        return self._jit_apply[unit]

    def warmup(self, batch: Dict[str, jax.Array]):
        """Pre-compile per-unit forwards (deploy-time step, like a
        serverless snapshot of compiled code) so first-request E_i
        timings measure execution, not XLA compilation."""
        names = self.model.unit_names()
        keys = jax.random.split(jax.random.key(0), len(names))
        state: Dict[str, Any] = {"batch": batch}
        for name, k in zip(names, keys):
            self.model.abstract_unit(name)   # precompute static structure
            p = self.model.init_unit(name, k)
            state = self._apply_fn(name)(p, state)
        jax.block_until_ready(state["logits"])

    def _apply_leaves(self, unit: str, abstract: PyTree, leaves) -> PyTree:
        """The weight-application compute phase: dequant/cast (fused
        ``weight_transform`` kernel) + device placement."""
        flat = {}
        for name, (arr, scale) in leaves.items():
            if scale is not None:                      # int8 extent
                out_dt = self.apply_dtype or jnp.float32
                deq = ops.weight_transform(jnp.asarray(arr),
                                           jnp.asarray(scale),
                                           out_dtype=out_dt)
                flat[name] = deq.reshape(self._leaf_shape(abstract, name))
            elif self.apply_dtype is not None and \
                    np.issubdtype(arr.dtype, np.floating):
                flat[name] = ops.weight_transform(
                    jnp.asarray(arr).reshape(arr.shape[0], -1)
                    if arr.ndim >= 2 else jnp.asarray(arr)[None],
                    None, out_dtype=self.apply_dtype).reshape(arr.shape)
            else:
                flat[name] = jax.device_put(arr)
        tree = unflatten_unit(abstract, flat)
        return jax.block_until_ready(tree)

    @staticmethod
    def _leaf_shape(abstract: PyTree, name: str):
        flat = jax.tree_util.tree_flatten_with_path(abstract)[0]
        for path, leaf in flat:
            n = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path)
            if n == name:
                return leaf.shape
        raise KeyError(name)

    # ----------------------------------------------------------------- load
    def load(self, batch: Dict[str, jax.Array], *,
             key: Optional[jax.Array] = None,
             on_logits: Optional[Any] = None) -> LoadResult:
        """Serve one cold-start request end-to-end.

        on_logits: called with the request's logits the moment the
        final unit's E completes (inside the pipeline, before drain +
        assemble) — the generation path samples the first token here so
        a cold generation request's TTFT lands within the pipeline
        trace instead of after load + a separate prefill."""
        strat = self.strategy
        model = self.model
        units = model.unit_names()
        key = key if key is not None else jax.random.key(0)
        keys = list(jax.random.split(key, len(units)))

        trace = PipelineTrace()
        scheduler = PriorityAwareScheduler(enabled=strat.scheduler)
        state = PipelineState()
        dec = WeightDecoupler(self.store, self.model_name, scheduler, trace,
                              io_workers=self.io_workers,
                              chunk_bytes=self.chunk_bytes, state=state,
                              cache=self.cache if strat.decouple else None)
        trace.start()

        try:
            if not strat.pipelined:
                result = self._load_traditional(batch, units, keys, trace,
                                                dec, on_logits)
            else:
                result = self._load_pipelined(batch, units, keys, trace, dec,
                                              scheduler, state, on_logits)
        finally:
            # shutdown now guards shared-cache invariants (pin sweep +
            # unregister_load), so it must run on the failure path too
            dec.shutdown()
        trace.finish()
        return result

    # ------------------------------------------------- traditional (Fig. 1)
    def _load_traditional(self, batch, units, keys, trace, dec,
                          on_logits=None) -> LoadResult:
        constructed = {}
        for u, k in zip(units, keys):                    # all L
            with trace.record("L", u):
                constructed[u] = miniloader.construct_unit(
                    self.model, u, k, mini=False)
        applied = {}
        for u in units:                                  # monolithic W+A
            t0 = time.monotonic()
            leaves = dec.fetch_sync(u)                   # blocking I/O
            t_io = time.monotonic()
            applied[u] = self._apply_leaves(u, constructed[u].abstract,
                                            leaves)
            t1 = time.monotonic()
            trace.add_event("R", u, t0, t_io)            # unit idles (DMA)
            trace.add_event("A", u, t_io, t1)
            trace.record_memory(u, constructed[u].mem_bytes,
                                constructed[u].t_construct_end, t1)
        state: Dict[str, Any] = {"batch": batch}
        for u in units:                                  # all E
            with trace.record("E", u):
                state = self._apply_fn(u)(applied[u], state)
                jax.block_until_ready(
                    state["logits" if u == units[-1] else "x"])
                if u == units[-1] and on_logits is not None:
                    on_logits(state["logits"])
        params = self.model.assemble(applied)
        return LoadResult(state["logits"], params, trace,
                          self.strategy.name)

    # ------------------------------------------------------- pipelined path
    def _load_pipelined(self, batch, units, keys, trace, dec,
                        scheduler, state: PipelineState,
                        on_logits=None) -> LoadResult:
        strat = self.strategy
        if strat.decouple:
            dec.prefetch(units)                 # issue I/O at request arrival

        ctx = PipelineContext(model=self.model, units=list(units),
                              keys=list(keys), batch=batch, strategy=strat,
                              trace=trace, decoupler=dec, scheduler=scheduler,
                              state=state, apply_leaves=self._apply_leaves,
                              apply_fn=self._apply_fn, on_output=on_logits)
        PipelineRuntime(standard_units(ctx), state).run()

        params = self.model.assemble(state.peek(APPLIED))
        return LoadResult(state.get(OUTPUT, "logits"), params, trace,
                          strat.name)
