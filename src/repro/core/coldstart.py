"""ColdStartEngine: request -> live model, through the paper's pipeline.

Three execution units run as threads (exactly the paper's decomposition,
as :class:`~repro.core.units.PipelineUnit` objects on one event-driven
:class:`~repro.core.units.PipelineRuntime`):

  * **Layer unit** — constructs unit structures in order (MiniLoader or
    PISeL-faithful numerical init); under a mesh every leaf's
    NamedSharding is resolved here, so the structure handed downstream
    is already the sharded layout;
  * **Weight unit** — applies retrieved weights.  Under the
    WeightDecoupler, retrieval streams were issued at request arrival on
    an I/O pool and application is out-of-order; under PISeL, retrieval
    is fused into this unit and strictly ordered after L_i;
  * **Compute unit** — executes layer i's forward as soon as its weights
    are applied (and layer i-1 executed): the triggering request is
    answered *while the model is still loading*.

**Shard-granular cold starts** (``mesh=`` + ``rules=``): the unit of
pipelined retrieval becomes a *(layer-unit, shard)* pair — one stream
per mesh device, each reading only the byte ranges its device owns and
committing them to that device the moment they land (see
:mod:`repro.core.shards`).  The pipeline's compute units still run the
triggering request on the default device from the host-merged leaves —
numerically *identical* to the single-device path (sharded collectives
never touch the first request's logits) — while the steady-state
(scan-stacked) parameters are assembled **on the mesh** from the
already-committed shards and handed to the serving engine for warm
tensor-parallel requests.  A mesh of one device degenerates to the
seed's unit-granular path exactly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import metrics as metrics_mod
from repro.core import miniloader
from repro.core.decoupler import ShardSource, WeightDecoupler
from repro.core.pipeline import PipelineTrace
from repro.core.scheduler import PriorityAwareScheduler
from repro.core.shards import ShardedUnitData, UnitShardPlan, plan_unit
from repro.core.strategies import Strategy, get_strategy
from repro.core.units import (APPLIED, OUTPUT, SHARDED, PipelineContext,
                              PipelineRuntime, PipelineState, standard_units)
from repro.distributed.sharding import (ShardingRules, leaf_specs,
                                        param_specs, serve_rules)
from repro.kernels import ops
from repro.quant import QuantLeaf
from repro.store.cache import WeightCache
from repro.store.store import WeightStore, leaf_path_name, unflatten_unit

PyTree = Any


@dataclasses.dataclass
class LoadResult:
    logits: jax.Array            # first-request output (computed in-pipeline)
    params: PyTree               # assembled steady-state parameters (on the
                                 # mesh, sharded, when the engine has one)
    trace: PipelineTrace
    strategy: str


class ColdStartEngine:
    def __init__(self, model, model_name: str, store: WeightStore, *,
                 strategy: str = "cicada", io_workers: int = 4,
                 chunk_bytes: int = 1 << 20,
                 apply_dtype=None, compute_quant: bool = False,
                 cache: Optional[WeightCache] = None,
                 mesh=None, rules: Optional[ShardingRules] = None,
                 metrics: Optional[metrics_mod.MetricsRegistry] = None,
                 source: Optional[ShardSource] = None):
        """apply_dtype: cast weights to this dtype at application time
        (None -> keep stored dtype).

        compute_quant: keep int8 extents *resident* — application skips
        the ``weight_transform`` dequant and builds
        :class:`~repro.quant.QuantLeaf` (int8 values + scale) leaves, so
        params charge ~quarter the f32 bytes and forward passes dispatch
        through the fused-dequant ``quant_matmul`` kernel.  Leaves the
        store serves as plain floats (norms, gates, 1-D vectors) are
        untouched.  Single-device serving only.

        cache: node-local shared WeightCache — decoupled retrieval
        streams consult it before issuing I/O, so scale-out cold starts
        of the same model single-flight every store read (per shard,
        under a mesh).

        source: where cache-missing streams read their bytes (default:
        the origin store) — a cluster node passes its peer-exchange
        tier here so cold starts of already-landed models stream over
        the intra-cluster link instead (requires a cache).

        mesh/rules: shard-granular cold start — retrieval fans out into
        one stream per mesh device and the assembled params live on the
        mesh as NamedSharding arrays.  rules defaults to
        ``serve_rules()``; a 1-device mesh degenerates to the seed
        path."""
        self.model = model
        self.model_name = model_name
        self.store = store
        self.strategy: Strategy = get_strategy(strategy)
        self.io_workers = io_workers
        self.chunk_bytes = chunk_bytes
        self.apply_dtype = apply_dtype
        self.cache = cache
        self.source = source
        self.metrics = metrics_mod.resolve(metrics)
        if mesh is not None and mesh.size <= 1:
            mesh = None                    # degenerate: exact seed path
        if compute_quant and mesh is not None:
            raise ValueError(
                "compute_quant serves int8 leaves in place on a single "
                "device; mesh-sharded quantized residency is not "
                "supported (shard plans describe the dequantized layout)")
        self.compute_quant = compute_quant
        self.mesh = mesh
        self.rules = (rules if rules is not None else serve_rules()) \
            if mesh is not None else None
        self._jit_apply: Dict[str, Any] = {}
        self._shard_plans: Dict[str, UnitShardPlan] = {}
        self._unit_specs: Dict[str, Dict[str, Any]] = {}
        self._assemble_jit = None

    # -------------------------------------------------------------- helpers
    def _apply_fn(self, unit: str):
        if unit not in self._jit_apply:
            model = self.model
            self._jit_apply[unit] = jax.jit(
                lambda p, s, _u=unit: model.unit_apply(_u, p, s))
        return self._jit_apply[unit]

    def warmup(self, batch: Dict[str, jax.Array]):
        """Pre-compile per-unit forwards (deploy-time step, like a
        serverless snapshot of compiled code) so first-request E_i
        timings measure execution, not XLA compilation."""
        names = self.model.unit_names()
        keys = jax.random.split(jax.random.key(0), len(names))
        state: Dict[str, Any] = {"batch": batch}
        for name, k in zip(names, keys):
            self.model.abstract_unit(name)   # precompute static structure
            p = self.model.init_unit(name, k)
            state = self._apply_fn(name)(p, state)
        jax.block_until_ready(state["logits"])

    def _plan(self, unit: str) -> UnitShardPlan:
        """Static per-unit shard plan (cached across loads)."""
        if unit not in self._shard_plans:
            self._shard_plans[unit] = plan_unit(
                self.store, self.model_name, unit,
                self.model.abstract_unit(unit), self.mesh, self.rules,
                apply_dtype=self.apply_dtype)
        return self._shard_plans[unit]

    def _specs(self, unit: str) -> Dict[str, Any]:
        if unit not in self._unit_specs:
            self._unit_specs[unit] = leaf_specs(
                self.model.abstract_unit(unit), self.mesh, self.rules)
        return self._unit_specs[unit]

    def _apply_leaves(self, unit: str, abstract: PyTree, leaves,
                      prefetched=None) -> PyTree:
        """The weight-application compute phase: dequant/cast (fused
        ``weight_transform`` kernel) + device placement (one batched
        transfer per unit).

        prefetched: {leaf: default-device array} already placed — and,
        for dequant/cast leaves, already transformed — by the shard
        committer's placement lane; those leaves skip the transfer (and
        the transform) here and A only waits on them."""
        flat = {}
        put_names, put_arrs = [], []
        qnames, qvals, qscales = [], [], []
        for name, (arr, scale) in leaves.items():
            if prefetched is not None and name in prefetched:
                flat[name] = prefetched[name]
            elif scale is not None and self.compute_quant:
                # quantized residency: place the int8 values (at the
                # logical leaf shape) + scale, skip weight_transform
                qnames.append(name)
                qvals.append(np.asarray(arr).reshape(
                    self._leaf_shape(abstract, name)))
                qscales.append(np.asarray(scale))
            elif scale is not None:                    # int8 extent
                out_dt = self.apply_dtype or jnp.float32
                a2 = jnp.asarray(arr).reshape(-1, arr.shape[-1])
                deq = ops.weight_transform(a2, jnp.asarray(scale),
                                           out_dtype=out_dt)
                flat[name] = deq.reshape(self._leaf_shape(abstract, name))
            elif self.apply_dtype is not None and \
                    np.issubdtype(arr.dtype, np.floating):
                flat[name] = ops.weight_transform(
                    jnp.asarray(arr).reshape(arr.shape[0], -1)
                    if arr.ndim >= 2 else jnp.asarray(arr)[None],
                    None, out_dtype=self.apply_dtype).reshape(arr.shape)
            else:
                put_names.append(name)
                put_arrs.append(arr)
        if qnames:
            bufs = jax.device_put(qvals + qscales)     # one batched transfer
            nq = len(qnames)
            for i, name in enumerate(qnames):
                flat[name] = QuantLeaf(bufs[i], bufs[nq + i])
        if put_arrs:
            flat.update(zip(put_names, jax.device_put(put_arrs)))
        tree = unflatten_unit(abstract, flat)
        return jax.block_until_ready(tree)

    def _apply_unit(self, unit: str, abstract: PyTree, leaves):
        """A_i: returns ``(compute_tree, mesh_tree_or_None)``.

        compute_tree lives on the default device and feeds the
        pipeline's E — byte-for-byte the single-device application, so
        the first request's logits are bit-identical regardless of the
        mesh (the per-shard transform is elementwise: dequant/cast of a
        slice equals the slice of the dequant/cast).  mesh_tree (mesh
        mode only) is the unit's steady-state sharded leaves: stitched
        from the shards' eagerly-committed — transformed, for
        dequant/cast leaves — device buffers where possible, raw
        per-device transfers otherwise."""
        data: Optional[ShardedUnitData] = None
        if isinstance(leaves, ShardedUnitData):
            data = leaves
            leaves = data.host_leaves()
        compute = self._apply_leaves(
            unit, abstract, leaves,
            prefetched=data.compute_bufs if data is not None else None)
        if self.mesh is None:
            return compute, None
        specs = data.plan.specs if data is not None else self._specs(unit)
        flatc = {
            leaf_path_name(path): leaf
            for path, leaf in jax.tree_util.tree_flatten_with_path(compute)[0]}
        dev = {}
        # leaves without committed buffers are placed here: raw
        # per-device transfers in one batch + a metadata stitch (a
        # device_put against a NamedSharding would route through the
        # resharding machinery — far slower on the apply path)
        pending = []                    # (name, sharding, imap)
        put_arrs, put_devs = [], []
        for name, (arr, scale) in leaves.items():
            transformed = scale is not None or (
                self.apply_dtype is not None and
                np.issubdtype(arr.dtype, np.floating))
            if data is not None and data.plan.commit[name]:
                dev[name] = data.global_array(name)    # metadata stitch
                continue
            sharding = specs[name]
            host = np.asarray(flatc[name]) if transformed else arr
            imap = sharding.devices_indices_map(tuple(host.shape))
            pending.append((name, sharding, imap))
            for d, idx in imap.items():
                put_arrs.append(host[idx])
                put_devs.append(d)
        if put_arrs:
            bufs = iter(jax.device_put(put_arrs, put_devs))
            for name, sharding, imap in pending:
                shape = tuple(self._leaf_shape(abstract, name))
                dev[name] = jax.make_array_from_single_device_arrays(
                    shape, sharding, [next(bufs) for _ in imap])
        # not block_until_ready: only the compute tree gates E — the
        # steady-state placement drains during E and is awaited by the
        # final assemble
        mesh_tree = unflatten_unit(abstract, dev)
        return compute, mesh_tree

    def _assemble(self, state: PipelineState) -> PyTree:
        """Stack the applied units into the steady-state params — on
        the mesh (sharded, from the committed per-device buffers) when
        the engine has one, on the default device otherwise."""
        if self.mesh is None:
            return self.model.assemble(state.peek(APPLIED))
        return self._assemble_sharded(state.peek(SHARDED))

    def _assemble_sharded(self, units_dev: Dict[str, PyTree]) -> PyTree:
        if self._assemble_jit is None:
            out_specs = param_specs(self.model.abstract(), self.mesh,
                                    self.rules)
            self._assemble_jit = jax.jit(self.model.assemble,
                                         out_shardings=out_specs)
        return jax.block_until_ready(self._assemble_jit(units_dev))

    @staticmethod
    def _leaf_shape(abstract: PyTree, name: str):
        flat = jax.tree_util.tree_flatten_with_path(abstract)[0]
        for path, leaf in flat:
            if leaf_path_name(path) == name:
                return leaf.shape
        raise KeyError(name)

    # ----------------------------------------------------------------- load
    def load(self, batch: Dict[str, jax.Array], *,
             key: Optional[jax.Array] = None,
             on_logits: Optional[Any] = None) -> LoadResult:
        """Serve one cold-start request end-to-end.

        on_logits: called with the request's logits the moment the
        final unit's E completes (inside the pipeline, before drain +
        assemble) — the generation path samples the first token here so
        a cold generation request's TTFT lands within the pipeline
        trace instead of after load + a separate prefill."""
        strat = self.strategy
        model = self.model
        units = model.unit_names()
        key = key if key is not None else jax.random.key(0)
        keys = list(jax.random.split(key, len(units)))

        trace = PipelineTrace()
        scheduler = PriorityAwareScheduler(enabled=strat.scheduler)
        state = PipelineState()
        sharded = self.mesh is not None and strat.decouple
        dec = WeightDecoupler(self.store, self.model_name, scheduler, trace,
                              io_workers=self.io_workers,
                              chunk_bytes=self.chunk_bytes, state=state,
                              cache=self.cache if strat.decouple else None,
                              source=self.source if strat.decouple
                              and self.cache is not None else None,
                              plan_fn=self._plan if sharded else None)
        trace.start()

        try:
            if not strat.pipelined:
                result = self._load_traditional(batch, units, keys, trace,
                                                dec, on_logits)
            else:
                result = self._load_pipelined(batch, units, keys, trace, dec,
                                              scheduler, state, on_logits)
        finally:
            # shutdown now guards shared-cache invariants (pin sweep +
            # unregister_load), so it must run on the failure path too
            dec.shutdown()
        trace.finish()
        self._record_load(trace)
        return result

    # 0..1 in even tenths — utilization is a ratio, not a latency, so
    # the log-spaced second buckets would collapse it into two bins
    UTIL_BUCKETS = tuple(i / 10 for i in range(1, 11))

    def _record_load(self, trace: PipelineTrace):
        """Per-load instruments: pipeline time, utilization, and the
        paper's per-stage waiting times (Q3) as live histograms."""
        m = self.metrics
        m.counter("coldstart/loads").inc()
        m.histogram("coldstart/load_s").observe(trace.total_time())
        m.histogram("coldstart/utilization",
                    buckets=self.UTIL_BUCKETS).observe(trace.utilization())
        wait = trace.wait_by_stage()
        m.histogram("pipeline/wait_A_s").observe(wait.get("A", 0.0))
        m.histogram("pipeline/wait_E_s").observe(wait.get("E", 0.0))

    # ------------------------------------------------- traditional (Fig. 1)
    def _load_traditional(self, batch, units, keys, trace, dec,
                          on_logits=None) -> LoadResult:
        constructed = {}
        for u, k in zip(units, keys):                    # all L
            with trace.record("L", u):
                constructed[u] = miniloader.construct_unit(
                    self.model, u, k, mini=False,
                    mesh=self.mesh, rules=self.rules)
        applied = {}
        sharded = {}
        for u in units:                                  # monolithic W+A
            t0 = time.monotonic()
            leaves = dec.fetch_sync(u)                   # blocking I/O
            t_io = time.monotonic()
            applied[u], mesh_tree = self._apply_unit(
                u, constructed[u].abstract, leaves)
            if mesh_tree is not None:
                sharded[u] = mesh_tree
            t1 = time.monotonic()
            trace.add_event("R", u, t0, t_io)            # unit idles (DMA)
            trace.add_event("A", u, t_io, t1)
            trace.record_memory(u, constructed[u].mem_bytes,
                                constructed[u].t_construct_end, t1)
        state: Dict[str, Any] = {"batch": batch}
        for u in units:                                  # all E
            with trace.record("E", u):
                state = self._apply_fn(u)(applied[u], state)
                jax.block_until_ready(
                    state["logits" if u == units[-1] else "x"])
                if u == units[-1] and on_logits is not None:
                    on_logits(state["logits"])
        params = self._assemble_sharded(sharded) if self.mesh is not None \
            else self.model.assemble(applied)
        return LoadResult(state["logits"], params, trace,
                          self.strategy.name)

    # ------------------------------------------------------- pipelined path
    def _load_pipelined(self, batch, units, keys, trace, dec,
                        scheduler, state: PipelineState,
                        on_logits=None) -> LoadResult:
        strat = self.strategy
        if strat.decouple:
            dec.prefetch(units)                 # issue I/O at request arrival

        ctx = PipelineContext(model=self.model, units=list(units),
                              keys=list(keys), batch=batch, strategy=strat,
                              trace=trace, decoupler=dec, scheduler=scheduler,
                              state=state, apply_leaves=self._apply_unit,
                              apply_fn=self._apply_fn, on_output=on_logits,
                              mesh=self.mesh, rules=self.rules)
        PipelineRuntime(standard_units(ctx), state).run()

        params = self._assemble(state)
        return LoadResult(state.get(OUTPUT, "logits"), params, trace,
                          strat.name)
