"""MiniLoader — opportunistic layer construction (paper Sec. III-B).

Conventional construction (the PISeL-faithful path) does two things per
layer: (1) instantiate the structure, (2) *numerically initialize* every
parameter (Kaiming/normal draws) and materialize fp32 buffers.  In
inference the initialization values are dead — pre-trained weights
overwrite them — yet they cost >50 % of construction time (paper
Fig. 5b) and a full fp32 footprint.

MiniLoader replaces that with:

  * **abstract construction** — ``jax.eval_shape`` builds the layer's
    ShapeDtypeStruct tree: the structural container (shapes, dtypes,
    tree layout) with *zero* init FLOPs;
  * **bit-packed placeholders** — 1 bit per parameter (``ceil(n/8)``
    uint8 bytes), exactly the paper's 1/32-of-fp32 memory, holding slot
    identity between construction and weight application.

The placeholder is dropped at application time when the retrieved bytes
are cast/dequantized to the compute dtype (the "restore to default
precision before weight application" step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any


@dataclasses.dataclass
class ConstructedUnit:
    """A layer structure produced by the Layer construction unit."""
    name: str
    abstract: PyTree                     # ShapeDtypeStruct tree; under a
                                         # mesh every leaf carries its
                                         # resolved NamedSharding
    init_params: Optional[PyTree]        # PISeL path: materialized init
    placeholders: Optional[Dict[str, np.ndarray]]  # Mini path: bit-packed
    mem_bytes: int                       # residency between L-end and A-end
    t_construct_end: float = 0.0
    specs: Optional[Dict[str, Any]] = None   # leaf path -> NamedSharding

    @property
    def mini(self) -> bool:
        return self.placeholders is not None


def n_params(abstract: PyTree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract))


def full_bytes(abstract: PyTree) -> int:
    return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(abstract))


def construct_unit(model, name: str, key: jax.Array, *,
                   mini: bool, mesh=None, rules=None) -> ConstructedUnit:
    """The pipeline's L_i.

    mini=False — PISeL-faithful: run the real numerical initialization
    (this is deliberately the expensive path the paper measures).
    mini=True — MiniLoader: eval_shape + 1-bit placeholders.

    mesh/rules — shard-granular cold start: every leaf's NamedSharding
    is resolved here (MaxText-style logical-axis rules) and attached to
    the abstract structure, so the structural container the pipeline
    hands downstream *is* the sharded layout the retrieval streams fill
    and ``jax.device_put`` commits against.
    """
    specs = None
    if mesh is not None:
        from repro.distributed.sharding import leaf_specs
        specs = leaf_specs(model.abstract_unit(name), mesh, rules)
    if mini:
        from repro.store.store import leaf_path_name
        abstract = model.abstract_unit(name)
        flat = jax.tree_util.tree_flatten_with_path(abstract)[0]
        placeholders: Dict[str, np.ndarray] = {}
        mem = 0
        vals = []
        for path, leaf in flat:
            pname = leaf_path_name(path)
            n = int(np.prod(leaf.shape))
            packed = np.zeros((n + 7) // 8, np.uint8)   # 1 bit / param
            placeholders[pname] = packed
            mem += packed.nbytes
            vals.append(leaf if specs is None else jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype, sharding=specs[pname]))
        if specs is not None:        # abstract params as *sharded* leaves
            treedef = jax.tree_util.tree_structure(abstract)
            abstract = jax.tree_util.tree_unflatten(treedef, vals)
        return ConstructedUnit(name, abstract, None, placeholders, mem,
                               time.monotonic(), specs)
    from repro.store.store import leaf_path_name
    params = model.init_unit(name, key)
    params = jax.block_until_ready(params)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    vals = []
    for path, leaf in flat:
        pname = leaf_path_name(path)
        vals.append(jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=None if specs is None else specs[pname]))
    abstract = jax.tree_util.tree_unflatten(treedef, vals)
    return ConstructedUnit(name, abstract, params, None,
                           full_bytes(abstract), time.monotonic(), specs)
