"""MiniLoader — opportunistic layer construction (paper Sec. III-B).

Conventional construction (the PISeL-faithful path) does two things per
layer: (1) instantiate the structure, (2) *numerically initialize* every
parameter (Kaiming/normal draws) and materialize fp32 buffers.  In
inference the initialization values are dead — pre-trained weights
overwrite them — yet they cost >50 % of construction time (paper
Fig. 5b) and a full fp32 footprint.

MiniLoader replaces that with:

  * **abstract construction** — ``jax.eval_shape`` builds the layer's
    ShapeDtypeStruct tree: the structural container (shapes, dtypes,
    tree layout) with *zero* init FLOPs;
  * **bit-packed placeholders** — 1 bit per parameter (``ceil(n/8)``
    uint8 bytes), exactly the paper's 1/32-of-fp32 memory, holding slot
    identity between construction and weight application.

The placeholder is dropped at application time when the retrieved bytes
are cast/dequantized to the compute dtype (the "restore to default
precision before weight application" step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any


@dataclasses.dataclass
class ConstructedUnit:
    """A layer structure produced by the Layer construction unit."""
    name: str
    abstract: PyTree                     # ShapeDtypeStruct tree
    init_params: Optional[PyTree]        # PISeL path: materialized init
    placeholders: Optional[Dict[str, np.ndarray]]  # Mini path: bit-packed
    mem_bytes: int                       # residency between L-end and A-end
    t_construct_end: float = 0.0

    @property
    def mini(self) -> bool:
        return self.placeholders is not None


def n_params(abstract: PyTree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract))


def full_bytes(abstract: PyTree) -> int:
    return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(abstract))


def construct_unit(model, name: str, key: jax.Array, *,
                   mini: bool) -> ConstructedUnit:
    """The pipeline's L_i.

    mini=False — PISeL-faithful: run the real numerical initialization
    (this is deliberately the expensive path the paper measures).
    mini=True — MiniLoader: eval_shape + 1-bit placeholders.
    """
    if mini:
        abstract = model.abstract_unit(name)
        flat = jax.tree_util.tree_flatten_with_path(abstract)[0]
        placeholders: Dict[str, np.ndarray] = {}
        mem = 0
        for path, leaf in flat:
            pname = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                             for p in path)
            n = int(np.prod(leaf.shape))
            packed = np.zeros((n + 7) // 8, np.uint8)   # 1 bit / param
            placeholders[pname] = packed
            mem += packed.nbytes
        return ConstructedUnit(name, abstract, None, placeholders, mem,
                               time.monotonic())
    params = model.init_unit(name, key)
    params = jax.block_until_ready(params)
    abstract = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), params)
    return ConstructedUnit(name, abstract, params, None,
                           full_bytes(abstract), time.monotonic())
