"""WeightDecoupler — asynchronous file retrieval + out-of-order
application support (paper Sec. III-C / III-D).

Weight loading has two phases with a ~4:1 cost ratio (paper Fig. 5c):

  * **file retrieval** (I/O-bound): chunked extent read + deserialize +
    crc — runs on an I/O thread pool, *issued at request arrival* so it
    overlaps layer construction.  Each stream carries a suspension gate
    owned by the Priority-Aware Scheduler.
  * **weight application** (compute-bound): dequant/cast via the
    ``weight_transform`` kernel + device placement — performed by the
    Weight execution unit, *out of order*: any unit whose bytes and
    structure are both ready can be applied.

With a ``Mesh`` attached, retrieval is **shard-granular**: every unit
fans out into one stream per mesh device (a :class:`~repro.core.shards.
UnitShardPlan`), each stream reading only the byte ranges of the leaf
slices its device owns, on its own simulated-device channel.  Streams
complete out of order *across shards, not just units* — a landed shard
is immediately committed to its target devices (``jax.device_put``
inside :meth:`ShardedUnitData.add_shard`) without waiting for
siblings, and ``ready[unit]`` publishes when the unit's **last** shard
lands.  Quantized/castable leaves participate too: their shard streams
carry value slices plus per-column scale slices, and the placement
lane runs the ``weight_transform`` kernel on each landed slice before
its commit — the weight-application *compute* phase is itself
pipelined per shard (Cicada's decoupling, pushed one level down).
Without a mesh the seed's unit-granular path is unchanged.

In the PISeL baseline the two phases are fused and strictly ordered;
``fetch_sync`` provides that path.

With a node-local :class:`~repro.store.cache.WeightCache` attached,
every stream consults the cache before issuing I/O: a hit publishes
its bytes immediately (a ~zero-cost "R" trace event, marked
``cached``), a miss single-flights the store read node-wide — cache
keys are ``(model, unit, shard)``, so concurrent scale-out onto the
same mesh stays zero-read per shard.  Cached entries stay pinned from
retrieval until weight application (released via :meth:`checkin`), so
eviction pressure can never reclaim bytes an in-flight — possibly
Algorithm-1-critical — load is about to apply.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro import analysis
from repro.core.pipeline import PipelineTrace
from repro.core.scheduler import PriorityAwareScheduler
from repro.core.shards import ShardedUnitData, UnitShardPlan
from repro.core.units import PipelineState
from repro.store.cache import LOAD, WeightCache
from repro.store.store import WeightStore

PyTree = Any
Leaves = Dict[str, Tuple[np.ndarray, Optional[np.ndarray]]]


class ShardSource:
    """Where a stream's bytes come from when the local cache misses.

    The default source is the origin store: :meth:`fetch` just invokes
    the ``read_origin`` thunk the decoupler hands it.  A cluster tier
    (``repro.cluster.peer.ClusterShardSource``) overrides it to consult
    a cluster-wide placement table first and serve the payload from a
    peer node's cache over the fast intra-cluster link — the origin
    thunk then runs only when this node is elected the *cluster-wide*
    single-flight leader for the key.

    Contract with the decoupler (mirrors the WeightCache protocol):
    ``fetch`` returns ``(payload, src)`` with ``src`` in {"origin",
    "peer"}; after the payload is published to the local cache the
    decoupler calls :meth:`publish`, and on any failure between fetch
    and publish it calls :meth:`abort` (both no-ops here)."""

    def fetch(self, model: str, unit: str, skey: Hashable, nbytes: int,
              read_origin: Callable[[], Any], *,
              gate=None, on_chunk: Optional[Callable[[int], None]] = None
              ) -> Tuple[Any, str]:
        return read_origin(), "origin"

    def publish(self, model: str, unit: str, skey: Hashable):
        pass

    def abort(self, model: str, unit: str, skey: Hashable):
        pass


class WeightDecoupler:
    def __init__(self, store: WeightStore, model_name: str,
                 scheduler: PriorityAwareScheduler, trace: PipelineTrace,
                 *, io_workers: int = 4, chunk_bytes: int = 1 << 20,
                 state: Optional[PipelineState] = None,
                 cache: Optional[WeightCache] = None,
                 plan_fn: Optional[Callable[[str], UnitShardPlan]] = None,
                 source: Optional[ShardSource] = None):
        """``state``: a PipelineState whose condition variable this
        decoupler shares — stream completions then directly wake
        pipeline units blocked on that state (single-CV signaling, no
        cross-lock polling).  Standalone use gets a private CV.

        ``cache``: optional node-local WeightCache consulted before any
        I/O is issued (shared across engines/instances for scale-out
        reuse and single-flight reads).

        ``plan_fn``: unit -> UnitShardPlan — enables shard-granular
        retrieval (the engine supplies plans resolved from its mesh +
        sharding rules).  None keeps the seed's unit-granular streams.

        ``source``: where a local-cache miss reads its bytes (see
        :class:`ShardSource`) — a cluster peer tier substitutes the
        fast intra-cluster link for the origin store here.  Requires a
        cache: the source's publish step is what makes this node's
        resident copy visible to peers.
        """
        if source is not None and cache is None:
            raise ValueError("a ShardSource requires a WeightCache "
                             "(peers are served from this node's cache)")
        self.store = store
        self.model_name = model_name
        self.scheduler = scheduler
        self.trace = trace
        self.chunk_bytes = chunk_bytes
        self.cache = cache
        self.source = source
        self.plan_fn = plan_fn
        self._plans: Dict[str, UnitShardPlan] = {}
        self._mesh_tag: Optional[str] = None
        self.io_workers = io_workers
        # Created at prefetch, sized to the stream count: a suspended
        # stream parks INSIDE its worker (gate.wait mid-read), so a
        # pool smaller than the stream fan-out can wedge — every worker
        # held by a suspended stream while the critical unit's streams
        # sit queued, creeping forward only on deadline wakes.
        self._pool: Optional[ThreadPoolExecutor] = None
        self._committer: Optional[ThreadPoolExecutor] = None
        self._admit: Dict[str, threading.Event] = {}
        self.state = state
        self.cv = state.cv if state is not None \
            else analysis.make_condition("WeightDecoupler.cv")
        self._unadmitted: List[str] = []              # guarded-by: cv
        self._reads_left: Dict[str, int] = {}         # guarded-by: cv
        # unit -> Leaves (unit-granular) | ShardedUnitData (complete)
        self.ready: Dict[str, Any] = {}               # guarded-by: cv
        self.errors: List[BaseException] = []         # guarded-by: cv
        # (unit, shard-key) cache refs
        self._pinned: set = set()                     # guarded-by: cv
        self._load_registered = False
        self._closed = False                          # guarded-by: cv

    # ------------------------------------------------------ async retrieval
    def prefetch(self, units: List[str]):
        """Issue every retrieval stream now (at request arrival) — this is
        what lets retrieval overlap layer construction.  With a shard
        plan, that is ``n_units x n_shards`` independent streams."""
        if self.cache is not None and not self._load_registered:
            self.cache.register_load(self.model_name)
            self._load_registered = True
        if self.plan_fn is None:
            self._pool = ThreadPoolExecutor(max_workers=self.io_workers,
                                            thread_name_prefix="cicada-io")
            for u in units:
                nbytes = self.store.unit_nbytes(self.model_name, u)
                st = self.scheduler.register(u, nbytes)
                self._pool.submit(self._fetch, u, st)
            return
        streams = []
        # Unit admission window: only ``io_workers`` units' shard
        # streams read concurrently, admitted in pipeline order and
        # advanced as units finish.  With every stream admitted at
        # once they would fair-share the channels and ALL units would
        # land near the end of the load — no early unit for the
        # pipeline to construct/apply/execute against (the seed's
        # bounded I/O pool enforced this ordering implicitly).
        self._admit = {u: threading.Event() for u in units}
        # pre-thread initialization: the stream workers that share cv
        # are submitted only at the end of this method
        self._unadmitted = list(units)      # analysis: ignore[R1]
        self._reads_left = {}               # analysis: ignore[R1]
        for u in units:
            plan = self.plan_fn(u)
            self._plans[u] = plan
            data = ShardedUnitData(plan, trace=self.trace)
            if self._mesh_tag is None:
                self._mesh_tag = plan.tag
            self._reads_left[u] = plan.n_shards     # analysis: ignore[R1]
            for s in range(plan.n_shards):
                st = self.scheduler.register(u, plan.shard_nbytes(s),
                                             shard=s)
                streams.append((u, s, st, data))
        for _ in range(min(self.io_workers, len(units))):
            self._admit[self._unadmitted.pop(0)].set()  # analysis: ignore[R1]
        self._pool = ThreadPoolExecutor(
            max_workers=max(self.io_workers, len(streams)),
            thread_name_prefix="cicada-io")
        # dedicated placement lanes — the modeled per-device DMA
        # queues: host merges + device commits run here instead of on
        # the read threads (where they'd contend with every in-flight
        # stream), and still start the moment each shard lands
        lanes = min(4, max(p.n_shards for p in self._plans.values()))
        self._committer = ThreadPoolExecutor(
            max_workers=lanes, thread_name_prefix="cicada-commit")
        for u, s, st, data in streams:
            self._pool.submit(self._fetch_shard, u, s, st, data)

    # -------------------------------------------------- unit-granular path
    @staticmethod
    def _src_meta(src: str, meta: Optional[Dict[str, Any]] = None
                  ) -> Optional[Dict[str, Any]]:
        """Trace annotation of a stream's byte source: origin reads are
        unmarked, cache hits carry ``cached``, peer-exchange transfers
        carry ``peer``."""
        if src == "cache":
            meta = dict(meta or (), cached=True)
        elif src == "peer":
            meta = dict(meta or (), peer=True)
        return meta

    def _progress_cb(self, unit: str, total: int, shard: Hashable = 0):
        """Per-chunk progress callback for source-driven transfers
        (peer link): accumulates into the scheduler's stream state the
        way _read_store / _read_shard do for origin reads."""
        done = [0]
        t = max(1, int(total))

        def cb(n):
            done[0] += n
            self.scheduler.on_progress(unit, done[0], t, shard=shard)
        return cb

    def _fetch(self, unit: str, st):
        try:
            self.scheduler.on_issue(unit)
            with self.cv:           # waiters recompute Algorithm 1 deadlines
                self.cv.notify_all()
            t0 = time.monotonic()
            leaves, src = self._retrieve(unit, st)
            self.trace.add_event("R", unit, t0, time.monotonic(),
                                 meta=self._src_meta(src))
            self.scheduler.on_complete(unit, observed=(src == "origin"))
            with self.cv:
                self.ready[unit] = leaves
                self.cv.notify_all()
        except BaseException as e:              # surfaced by the engine
            self.scheduler.on_error(unit)       # un-park suspended streams
            with self.cv:
                self.errors.append(e)
                if self.state is not None:
                    self.state.errors.append(e)
                self.cv.notify_all()

    def _retrieve(self, unit: str, st) -> Tuple[Leaves, str]:
        """One stream's bytes: cache hit / single-flight wait / leader
        read through the source (origin store, or a cluster peer's
        cache over the fast link).  Returns ``(leaves, src)`` with src
        in {"cache", "origin", "peer"}."""
        if self.cache is None:
            return self._read_store(unit, st), "origin"
        # A hit OR a wait on another load's read is "external" to this
        # pipeline's I/O: Algorithm 1 must not prioritize it (see
        # PriorityAwareScheduler.mark_external).  We cannot know which
        # before begin() may block, so flag optimistically and unflag
        # only if this stream ends up doing a genuine origin read (a
        # peer transfer is external too: suspending local device
        # streams cannot speed up another node's cache).
        self.scheduler.mark_external(unit)
        status, leaves = self.cache.begin(self.model_name, unit)
        if status == LOAD:
            def read_origin():
                self.scheduler.mark_external(unit, False)
                return self._read_store(unit, st)
            src = "origin"
            try:
                if self.source is None:
                    leaves = read_origin()
                else:
                    leaves, src = self.source.fetch(
                        self.model_name, unit, 0, st.nbytes, read_origin,
                        gate=st.gate,
                        on_chunk=self._progress_cb(unit, st.nbytes))
                self.cache.complete(self.model_name, unit, leaves,
                                    st.nbytes)
            except BaseException:
                self.cache.abort(self.model_name, unit)
                if self.source is not None:
                    self.source.abort(self.model_name, unit, 0)
                raise
            if self.source is not None:
                self.source.publish(self.model_name, unit, 0)
            self._pin(unit, 0)
            return leaves, src
        self._pin(unit, 0)
        return leaves, "cache"

    def _read_store(self, unit: str, st) -> Leaves:
        raw = self.store.read_unit(
            self.model_name, unit, chunk_bytes=self.chunk_bytes,
            gate=st.gate,
            on_progress=lambda d, t: self.scheduler.on_progress(
                unit, d, t))
        return self.store.deserialize(self.model_name, unit, raw)

    # ------------------------------------------------- shard-granular path
    def _shard_key(self, shard: int) -> Hashable:
        # cache identity: the same unit planned for a different mesh
        # shape OR different sharding rules holds different byte
        # ranges — never serve one as the other (the tag fingerprints
        # both; see shards.plan_tag)
        return (self._mesh_tag, shard)

    def _fetch_shard(self, unit: str, shard: int, st,
                     data: ShardedUnitData):
        try:
            self._admit[unit].wait()        # unit-ordered channel window
            with self.cv:
                if self._closed:            # released by shutdown
                    return
            self.scheduler.on_issue(unit, shard=shard)
            with self.cv:
                self.cv.notify_all()
            t0 = time.monotonic()
            payload, src = self._retrieve_shard(unit, shard, st, data)
            meta = self._src_meta(src, {"shard": shard})
            self.trace.add_event("R", unit, t0, time.monotonic(), meta=meta)
            self.scheduler.on_complete(unit, observed=(src == "origin"),
                                       shard=shard)
            with self.cv:                   # unit fully read: admit next
                self._reads_left[unit] -= 1
                if self._reads_left[unit] == 0 and self._unadmitted:
                    self._admit[self._unadmitted.pop(0)].set()
            # placement runs on the committer the moment the shard
            # lands — out-of-order across shards, no sibling barrier
            self._committer.submit(self._commit_shard, unit, shard,
                                   data, payload,
                                   self.cache is None)
        except BaseException as e:
            self.scheduler.on_error(unit, shard=shard)
            with self.cv:
                self.errors.append(e)
                if self.state is not None:
                    self.state.errors.append(e)
                self.cv.notify_all()

    def _commit_shard(self, unit: str, shard: int, data: ShardedUnitData,
                      payload, merged: bool):
        try:
            # host merge (cache path only) + per-shard weight_transform
            # of dequant/cast pieces + eager mesh commit; exactly one
            # lane — the unit-completing one, AFTER the compute
            # prefetch is in place — gets last=True and publishes
            last = data.add_shard(shard, payload, merged=merged)
            with self.cv:
                if last:
                    self.ready[unit] = data
                self.cv.notify_all()
        except BaseException as e:
            with self.cv:
                self.errors.append(e)
                if self.state is not None:
                    self.state.errors.append(e)
                self.cv.notify_all()

    def _retrieve_shard(self, unit: str, shard: int, st,
                        data: Optional[ShardedUnitData] = None):
        skey = self._shard_key(shard)
        if self.cache is None:
            # no cache: gather straight into the unit's full host
            # leaves (the cache path materializes standalone slices —
            # its payloads outlive this load)
            return self._read_shard(unit, shard, st, data), "origin"
        self.scheduler.mark_external(unit, shard=shard)
        status, payload = self.cache.begin(self.model_name, unit, skey)
        if status == LOAD:
            def read_origin():
                self.scheduler.mark_external(unit, False, shard=shard)
                return self._read_shard(unit, shard, st)
            src = "origin"
            try:
                if self.source is None:
                    payload = read_origin()
                else:
                    payload, src = self.source.fetch(
                        self.model_name, unit, skey, st.nbytes,
                        read_origin, gate=st.gate,
                        on_chunk=self._progress_cb(unit, st.nbytes,
                                                   shard))
                self.cache.complete(self.model_name, unit, payload,
                                    st.nbytes, skey)
            except BaseException:
                self.cache.abort(self.model_name, unit, skey)
                if self.source is not None:
                    self.source.abort(self.model_name, unit, skey)
                raise
            if self.source is not None:
                self.source.publish(self.model_name, unit, skey)
            self._pin(unit, skey)
            return payload, src
        self._pin(unit, skey)
        return payload, "cache"

    def _read_shard(self, unit: str, shard: int, st,
                    data: Optional[ShardedUnitData] = None):
        """One shard stream: byte-range reads of every leaf slice this
        shard owns, over the shard's own simulated-device channel.

        With ``data`` the gather lands directly in the unit's full host
        leaves (zero staging copies); without it (cache path) each
        slice is materialized standalone."""
        plan = self._plans[unit]
        total = max(1, plan.shard_nbytes(shard))
        done = [0]

        def on_chunk(n):
            done[0] += n
            self.scheduler.on_progress(unit, done[0], total, shard=shard)

        payload = []
        fh = self.store.open_unit(self.model_name, unit)
        try:
            for piece in plan.pieces[shard]:
                out = None
                if data is not None and piece.index is not None:
                    out = data.host_dest(piece.leaf, piece.index)
                arr, scale = self.store.read_leaf_slice(
                    self.model_name, unit, piece.leaf, piece.index,
                    fh=fh, chunk_bytes=self.chunk_bytes, gate=st.gate,
                    on_chunk=on_chunk, channel=shard, out=out)
                payload.append((piece.leaf, arr, scale, piece.index))
        finally:
            fh.close()
        return payload

    # ------------------------------------------------------ cache bookkeeping
    def _pin(self, unit: str, skey: Hashable):
        with self.cv:
            if not self._closed:
                self._pinned.add((unit, skey))
                return
        # shutdown already swept pins: release straight away
        self.cache.release(self.model_name, unit, skey)

    def checkin(self, unit: str):
        """Weight application of ``unit`` is done: drop the cache pins
        of all its shards (no-op without a cache)."""
        if self.cache is None:
            return
        with self.cv:
            mine = [(u, k) for (u, k) in self._pinned if u == unit]
            self._pinned.difference_update(mine)
        for u, k in mine:
            self.cache.release(self.model_name, u, k)

    # ------------------------------------------------------ sync (PISeL)
    def fetch_sync(self, unit: str) -> Leaves:
        """Blocking retrieval + deserialize — the fused W_i of PISeL."""
        raw = self.store.read_unit(self.model_name, unit,
                                   chunk_bytes=self.chunk_bytes)
        return self.store.deserialize(self.model_name, unit, raw)

    # -------------------------------------------------------------- waiting
    # (Waiting for ready bytes lives in DecoupledWeightUnit._next_ready:
    # it needs construction state too, and shares this decoupler's CV.)

    def shutdown(self):
        with self.cv:
            # _closed flips under cv so a shard worker passing its
            # admission gate observes it or the pin sweep sees its pin
            # — never neither (the old unlocked write raced _pin)
            self._closed = True
            pinned, self._pinned = self._pinned, set()
            self.cv.notify_all()
        for ev in self._admit.values():     # release admission waiters
            ev.set()
        if self._pool is not None:
            self._pool.shutdown(wait=False)
        if self._committer is not None:
            self._committer.shutdown(wait=False)
        if self.cache is not None:
            for u, k in pinned:              # pins left by an aborted load
                self.cache.release(self.model_name, u, k)
            if self._load_registered:
                self._load_registered = False
                self.cache.unregister_load(self.model_name)
