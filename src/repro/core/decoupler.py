"""WeightDecoupler — asynchronous file retrieval + out-of-order
application support (paper Sec. III-C / III-D).

Weight loading has two phases with a ~4:1 cost ratio (paper Fig. 5c):

  * **file retrieval** (I/O-bound): chunked extent read + deserialize +
    crc — runs on an I/O thread pool, *issued at request arrival* so it
    overlaps layer construction.  Each stream carries a suspension gate
    owned by the Priority-Aware Scheduler.
  * **weight application** (compute-bound): dequant/cast via the
    ``weight_transform`` kernel + device placement — performed by the
    Weight execution unit, *out of order*: any unit whose bytes and
    structure are both ready can be applied.

In the PISeL baseline the two phases are fused and strictly ordered;
``fetch_sync`` provides that path.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pipeline import PipelineTrace
from repro.core.scheduler import PriorityAwareScheduler
from repro.core.units import PipelineState
from repro.store.store import WeightStore

PyTree = Any
Leaves = Dict[str, Tuple[np.ndarray, Optional[np.ndarray]]]


class WeightDecoupler:
    def __init__(self, store: WeightStore, model_name: str,
                 scheduler: PriorityAwareScheduler, trace: PipelineTrace,
                 *, io_workers: int = 4, chunk_bytes: int = 1 << 20,
                 state: Optional[PipelineState] = None):
        """``state``: a PipelineState whose condition variable this
        decoupler shares — stream completions then directly wake
        pipeline units blocked on that state (single-CV signaling, no
        cross-lock polling).  Standalone use gets a private CV."""
        self.store = store
        self.model_name = model_name
        self.scheduler = scheduler
        self.trace = trace
        self.chunk_bytes = chunk_bytes
        self._pool = ThreadPoolExecutor(max_workers=io_workers,
                                        thread_name_prefix="cicada-io")
        self.ready: Dict[str, Leaves] = {}
        self.state = state
        self.cv = state.cv if state is not None else threading.Condition()
        self.errors: List[BaseException] = []

    # ------------------------------------------------------ async retrieval
    def prefetch(self, units: List[str]):
        """Issue every retrieval stream now (at request arrival) — this is
        what lets retrieval overlap layer construction."""
        for u in units:
            nbytes = self.store.unit_nbytes(self.model_name, u)
            st = self.scheduler.register(u, nbytes)
            self._pool.submit(self._fetch, u, st)

    def _fetch(self, unit: str, st):
        try:
            self.scheduler.on_issue(unit)
            with self.cv:           # waiters recompute Algorithm 1 deadlines
                self.cv.notify_all()
            t0 = time.monotonic()
            raw = self.store.read_unit(
                self.model_name, unit, chunk_bytes=self.chunk_bytes,
                gate=st.gate,
                on_progress=lambda d, t: self.scheduler.on_progress(
                    unit, d, t))
            leaves = self.store.deserialize(self.model_name, unit, raw)
            self.trace.add_event("R", unit, t0, time.monotonic())
            self.scheduler.on_complete(unit)
            with self.cv:
                self.ready[unit] = leaves
                self.cv.notify_all()
        except BaseException as e:              # surfaced by the engine
            with self.cv:
                self.errors.append(e)
                if self.state is not None:
                    self.state.errors.append(e)
                self.cv.notify_all()

    # ------------------------------------------------------ sync (PISeL)
    def fetch_sync(self, unit: str) -> Leaves:
        """Blocking retrieval + deserialize — the fused W_i of PISeL."""
        raw = self.store.read_unit(self.model_name, unit,
                                   chunk_bytes=self.chunk_bytes)
        return self.store.deserialize(self.model_name, unit, raw)

    # -------------------------------------------------------------- waiting
    # (Waiting for ready bytes lives in DecoupledWeightUnit._next_ready:
    # it needs construction state too, and shares this decoupler's CV.)

    def shutdown(self):
        self._pool.shutdown(wait=False)
