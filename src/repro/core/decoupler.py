"""WeightDecoupler — asynchronous file retrieval + out-of-order
application support (paper Sec. III-C / III-D).

Weight loading has two phases with a ~4:1 cost ratio (paper Fig. 5c):

  * **file retrieval** (I/O-bound): chunked extent read + deserialize +
    crc — runs on an I/O thread pool, *issued at request arrival* so it
    overlaps layer construction.  Each stream carries a suspension gate
    owned by the Priority-Aware Scheduler.
  * **weight application** (compute-bound): dequant/cast via the
    ``weight_transform`` kernel + device placement — performed by the
    Weight execution unit, *out of order*: any unit whose bytes and
    structure are both ready can be applied.

In the PISeL baseline the two phases are fused and strictly ordered;
``fetch_sync`` provides that path.

With a node-local :class:`~repro.store.cache.WeightCache` attached,
every stream consults the cache before issuing I/O: a hit publishes
``ready[unit]`` immediately (a ~zero-cost "R" trace event, marked
``cached``), a miss single-flights the store read node-wide — the
first loader of a unit reads, concurrent loads of the same model wait
on the shared cache and reuse the bytes.  Cached units stay pinned
from retrieval until weight application (released via
:meth:`checkin`), so eviction pressure can never reclaim a unit an
in-flight — possibly Algorithm-1-critical — load is about to apply.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pipeline import PipelineTrace
from repro.core.scheduler import PriorityAwareScheduler
from repro.core.units import PipelineState
from repro.store.cache import LOAD, WeightCache
from repro.store.store import WeightStore

PyTree = Any
Leaves = Dict[str, Tuple[np.ndarray, Optional[np.ndarray]]]


class WeightDecoupler:
    def __init__(self, store: WeightStore, model_name: str,
                 scheduler: PriorityAwareScheduler, trace: PipelineTrace,
                 *, io_workers: int = 4, chunk_bytes: int = 1 << 20,
                 state: Optional[PipelineState] = None,
                 cache: Optional[WeightCache] = None):
        """``state``: a PipelineState whose condition variable this
        decoupler shares — stream completions then directly wake
        pipeline units blocked on that state (single-CV signaling, no
        cross-lock polling).  Standalone use gets a private CV.

        ``cache``: optional node-local WeightCache consulted before any
        I/O is issued (shared across engines/instances for scale-out
        reuse and single-flight reads)."""
        self.store = store
        self.model_name = model_name
        self.scheduler = scheduler
        self.trace = trace
        self.chunk_bytes = chunk_bytes
        self.cache = cache
        self._pool = ThreadPoolExecutor(max_workers=io_workers,
                                        thread_name_prefix="cicada-io")
        self.ready: Dict[str, Leaves] = {}
        self.state = state
        self.cv = state.cv if state is not None else threading.Condition()
        self.errors: List[BaseException] = []
        self._pinned: set = set()        # units holding a cache reference
        self._load_registered = False
        self._closed = False

    # ------------------------------------------------------ async retrieval
    def prefetch(self, units: List[str]):
        """Issue every retrieval stream now (at request arrival) — this is
        what lets retrieval overlap layer construction."""
        if self.cache is not None and not self._load_registered:
            self.cache.register_load(self.model_name)
            self._load_registered = True
        for u in units:
            nbytes = self.store.unit_nbytes(self.model_name, u)
            st = self.scheduler.register(u, nbytes)
            self._pool.submit(self._fetch, u, st)

    def _fetch(self, unit: str, st):
        try:
            self.scheduler.on_issue(unit)
            with self.cv:           # waiters recompute Algorithm 1 deadlines
                self.cv.notify_all()
            t0 = time.monotonic()
            leaves, cached = self._retrieve(unit, st)
            self.trace.add_event("R", unit, t0, time.monotonic(),
                                 meta={"cached": True} if cached else None)
            self.scheduler.on_complete(unit, observed=not cached)
            with self.cv:
                self.ready[unit] = leaves
                self.cv.notify_all()
        except BaseException as e:              # surfaced by the engine
            self.scheduler.on_error(unit)       # un-park suspended streams
            with self.cv:
                self.errors.append(e)
                if self.state is not None:
                    self.state.errors.append(e)
                self.cv.notify_all()

    def _retrieve(self, unit: str, st) -> Tuple[Leaves, bool]:
        """One stream's bytes: cache hit / single-flight wait / leader
        store read.  Returns (leaves, served_from_cache)."""
        if self.cache is None:
            return self._read_store(unit, st), False
        # A hit OR a wait on another load's read is "external" to this
        # pipeline's I/O: Algorithm 1 must not prioritize it (see
        # PriorityAwareScheduler.mark_external).  We cannot know which
        # before begin() may block, so flag optimistically and unflag
        # on the LOAD outcome.
        self.scheduler.mark_external(unit)
        status, leaves = self.cache.begin(self.model_name, unit)
        if status == LOAD:
            self.scheduler.mark_external(unit, False)
            try:
                leaves = self._read_store(unit, st)
                self.cache.complete(self.model_name, unit, leaves,
                                    st.nbytes)
            except BaseException:
                self.cache.abort(self.model_name, unit)
                raise
            self._pin(unit)
            return leaves, False
        self._pin(unit)
        return leaves, True

    def _pin(self, unit: str):
        with self.cv:
            if not self._closed:
                self._pinned.add(unit)
                return
        # shutdown already swept pins: release straight away
        self.cache.release(self.model_name, unit)

    def _read_store(self, unit: str, st) -> Leaves:
        raw = self.store.read_unit(
            self.model_name, unit, chunk_bytes=self.chunk_bytes,
            gate=st.gate,
            on_progress=lambda d, t: self.scheduler.on_progress(
                unit, d, t))
        return self.store.deserialize(self.model_name, unit, raw)

    # ------------------------------------------------------ cache bookkeeping
    def checkin(self, unit: str):
        """Weight application of ``unit`` is done: drop its cache pin
        (no-op without a cache)."""
        if self.cache is None:
            return
        with self.cv:
            if unit not in self._pinned:
                return
            self._pinned.discard(unit)
        self.cache.release(self.model_name, unit)

    # ------------------------------------------------------ sync (PISeL)
    def fetch_sync(self, unit: str) -> Leaves:
        """Blocking retrieval + deserialize — the fused W_i of PISeL."""
        raw = self.store.read_unit(self.model_name, unit,
                                   chunk_bytes=self.chunk_bytes)
        return self.store.deserialize(self.model_name, unit, raw)

    # -------------------------------------------------------------- waiting
    # (Waiting for ready bytes lives in DecoupledWeightUnit._next_ready:
    # it needs construction state too, and shares this decoupler's CV.)

    def shutdown(self):
        self._pool.shutdown(wait=False)
        if self.cache is not None:
            with self.cv:
                self._closed = True
                pinned, self._pinned = self._pinned, set()
            for u in pinned:                 # pins left by an aborted load
                self.cache.release(self.model_name, u)
            if self._load_registered:
                self._load_registered = False
                self.cache.unregister_load(self.model_name)
