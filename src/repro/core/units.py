"""Event-driven pipeline execution units (paper Fig. 2 decomposition).

The cold-start pipeline is three cooperating execution units — Layer
construction, Weight handling, Compute — that the seed implementation
expressed as inline thread closures synchronized by fixed-interval
``cv.wait(0.02)`` polling.  This module turns them into first-class,
composable objects:

  * :class:`PipelineState` — a shared blackboard: per-(stage, unit)
    completion slots guarded by **one** condition variable.  Producers
    :meth:`publish`, consumers :meth:`wait_for` / :meth:`wait_until`;
    every wait is woken by notification (or an explicit Algorithm-1
    deadline), never by a polling interval.
  * :class:`PipelineUnit` — base class for an execution unit; concrete
    units are :class:`LayerConstructionUnit`,
    :class:`DecoupledWeightUnit` (async retrieval, out-of-order
    application), :class:`FusedWeightUnit` (PISeL: retrieval fused,
    strictly ordered) and :class:`ComputeUnit`.
  * :class:`PipelineRuntime` — runs a unit set as threads and
    propagates the first failure.

New unit kinds (e.g. a host-to-device transfer unit between Weight and
Compute) subclass :class:`PipelineUnit`, consume/produce stages on the
shared state, and slot into the same runtime — no engine changes.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

from repro import analysis
from repro.core import miniloader
from repro.core.pipeline import PipelineTrace
from repro.core.scheduler import PriorityAwareScheduler
from repro.core.strategies import Strategy

PyTree = Any

# Canonical stage names on the blackboard.  The letters match the
# PipelineTrace rows: L produces CONSTRUCTED, A produces APPLIED, E
# produces OUTPUT.  Under a mesh the weight units additionally publish
# SHARDED: the unit's steady-state leaves as mesh-committed
# NamedSharding arrays (stitched from the shards' eager device_puts),
# which the engine assembles into the sharded scan-stacked params.
CONSTRUCTED = "constructed"
APPLIED = "applied"
SHARDED = "sharded"
OUTPUT = "output"


class PipelineState:
    """Shared completion slots for one pipeline run, one condition
    variable for all signaling.

    The condition variable is exposed (``state.cv``) so collaborating
    components that complete work on other threads — the
    WeightDecoupler's I/O pool — can share it: their completions then
    wake any unit blocked here without a second lock or a poll loop.
    """

    def __init__(self, cv: Optional[threading.Condition] = None):
        self.cv = cv if cv is not None \
            else analysis.make_condition("PipelineState.cv")
        self._slots: Dict[str, Dict[str, Any]] = {}   # guarded-by: cv
        self.errors: List[BaseException] = []         # guarded-by: cv

    # ------------------------------------------------------------ producers
    def publish(self, stage: str, unit: str, value: Any = True):
        with self.cv:
            self._slots.setdefault(stage, {})[unit] = value
            self.cv.notify_all()

    def fail(self, exc: BaseException):
        with self.cv:
            if not any(e is exc for e in self.errors):
                self.errors.append(exc)
            self.cv.notify_all()

    # ------------------------------------------------------------ consumers
    def peek(self, stage: str) -> Dict[str, Any]:
        with self.cv:
            return dict(self._slots.get(stage, {}))

    def get(self, stage: str, unit: str) -> Any:
        with self.cv:
            return self._slots.get(stage, {}).get(unit)

    def wait_until(self, predicate: Callable[[], Any], *,
                   deadline_fn: Optional[Callable[[], Optional[float]]] = None,
                   on_deadline: Optional[Callable[[], None]] = None) -> Any:
        """Block until ``predicate()`` (evaluated under the lock) returns
        non-None; re-raises the first pipeline error.

        ``deadline_fn`` may supply a wake-up delay in seconds (None = no
        deadline).  When the deadline expires before a notification,
        ``on_deadline`` runs once and the deadline is re-asked — this is
        how Algorithm 1 fires exactly at a late stream's expected
        completion instead of on a polling grid.
        """
        with self.cv:
            while True:
                if self.errors:
                    raise self.errors[0]
                value = predicate()
                if value is not None:
                    return value
                wait_s = deadline_fn() if deadline_fn is not None else None
                if wait_s is not None and wait_s <= 0:
                    if on_deadline is not None:
                        on_deadline()
                    continue
                self.cv.wait(wait_s)

    def wait_for(self, stage: str, unit: str) -> Any:
        return self.wait_until(
            lambda: self._slots.get(stage, {}).get(unit))


@dataclasses.dataclass
class PipelineContext:
    """Everything a unit needs for one cold-start run."""
    model: Any
    units: List[str]                     # layer order
    keys: List[jax.Array]
    batch: Dict[str, jax.Array]
    strategy: Strategy
    trace: PipelineTrace
    decoupler: Any                       # WeightDecoupler
    scheduler: PriorityAwareScheduler
    state: PipelineState
    # (unit, abstract, retrieved) -> (compute_tree, mesh_tree_or_None):
    # compute_tree feeds E on the default device (bit-identical to the
    # single-device path); mesh_tree is the unit's steady-state sharded
    # leaves when a mesh is attached (None otherwise)
    apply_leaves: Callable[[str, PyTree, Any], Any]
    apply_fn: Callable[[str], Callable]
    # sharded cold start: resolved mesh + rules (None -> seed path)
    mesh: Any = None
    rules: Any = None
    # Called with the request's logits as soon as the final unit's E
    # completes them — while that E event is still open, before the
    # pipeline drains/assembles.  This is how a cold *generation*
    # request's first token is produced inside the pipeline (TTFT ~
    # E-completion, not load + separate prefill).
    on_output: Optional[Callable[[Any], None]] = None

    def index(self, unit: str) -> int:
        return self.units.index(unit)


class PipelineUnit:
    """One execution unit; runs on its own thread via PipelineRuntime."""

    name = "pipeline-unit"

    def __init__(self, ctx: PipelineContext):
        self.ctx = ctx

    def run(self):                       # pragma: no cover - interface
        raise NotImplementedError

    def thread(self) -> threading.Thread:
        def _runner():
            try:
                self.run()
            except BaseException as e:
                self.ctx.state.fail(e)
        return threading.Thread(target=_runner, name=self.name)


class LayerConstructionUnit(PipelineUnit):
    """L_i: build unit structures in order (MiniLoader or full init)."""

    name = "layer-unit"

    def run(self):
        ctx = self.ctx
        for u, k in zip(ctx.units, ctx.keys):
            if ctx.strategy.scheduler:
                # Algorithm 1 at L_i — for the layer the pipeline needs
                # NEXT (lowest un-applied), not the one being built:
                # prioritizing u_i itself would march criticality ahead
                # of the weight unit and park exactly the streams it is
                # waiting on (pathological with per-shard streams)
                applied = ctx.state.peek(APPLIED)
                needed = next((x for x in ctx.units if x not in applied),
                              u)
                ctx.scheduler.adjust_priority(needed)
            with ctx.trace.record("L", u):
                cu = miniloader.construct_unit(ctx.model, u, k,
                                               mini=ctx.strategy.mini,
                                               mesh=ctx.mesh,
                                               rules=ctx.rules)
            ctx.state.publish(CONSTRUCTED, u, cu)


class DecoupledWeightUnit(PipelineUnit):
    """A_i out of order: apply any unit whose structure is built and
    whose retrieval stream (issued at request arrival) has landed."""

    name = "weight-unit"

    def run(self):
        ctx = self.ctx
        dec = ctx.decoupler
        # bytes-ready signals must arrive on the state's CV, or waits
        # below would sleep through them (silent hang) — fail fast
        assert dec.cv is ctx.state.cv, \
            "WeightDecoupler must share the PipelineState CV (state=...)"
        pending = set(ctx.units)
        while pending:
            u = self._next_ready(pending)
            cu = ctx.state.get(CONSTRUCTED, u)
            with ctx.trace.record("A", u):
                params, mesh_tree = ctx.apply_leaves(u, cu.abstract,
                                                     dec.ready[u])
            dec.checkin(u)      # application done: drop the cache pins
            ctx.trace.record_memory(u, cu.mem_bytes, cu.t_construct_end,
                                    time.monotonic())
            if mesh_tree is not None:
                ctx.state.publish(SHARDED, u, mesh_tree)
            ctx.state.publish(APPLIED, u, params)
            pending.discard(u)

    def _next_ready(self, pending) -> str:
        """Lowest-index pending unit with structure + bytes ready.

        While blocked, wake exactly at the *critical* unit's expected
        completion (the one the compute unit needs next) and run
        Algorithm 1 so a late stream gets the full I/O bandwidth.
        """
        ctx = self.ctx
        dec = ctx.decoupler
        critical = min(pending, key=ctx.index)

        def _avail() -> Optional[str]:
            built = ctx.state._slots.get(CONSTRUCTED, {})
            got = [u for u in pending if u in built and u in dec.ready]
            return min(got, key=ctx.index) if got else None

        deadline = (ctx.scheduler.time_until_expected
                    if ctx.strategy.scheduler else None)
        return ctx.state.wait_until(
            _avail,
            deadline_fn=(lambda: deadline(critical)) if deadline else None,
            on_deadline=lambda: ctx.scheduler.adjust_priority(critical))


class FusedWeightUnit(PipelineUnit):
    """PISeL W_i: retrieval fused into the unit, strictly ordered after
    L_i — the unit idles on I/O (that idleness is the paper's point)."""

    name = "weight-unit"

    def run(self):
        ctx = self.ctx
        for u in ctx.units:
            cu = ctx.state.wait_for(CONSTRUCTED, u)
            t0 = time.monotonic()
            leaves = ctx.decoupler.fetch_sync(u)
            t_io = time.monotonic()
            params, mesh_tree = ctx.apply_leaves(u, cu.abstract, leaves)
            t1 = time.monotonic()
            ctx.trace.add_event("R", u, t0, t_io)
            ctx.trace.add_event("A", u, t_io, t1)
            ctx.trace.record_memory(u, cu.mem_bytes, cu.t_construct_end, t1)
            if mesh_tree is not None:
                ctx.state.publish(SHARDED, u, mesh_tree)
            ctx.state.publish(APPLIED, u, params)


class ComputeUnit(PipelineUnit):
    """E_i: run layer i as soon as its weights are applied — the
    triggering request is answered while the model is still loading."""

    name = "compute-unit"

    def run(self):
        ctx = self.ctx
        st: Dict[str, Any] = {"batch": ctx.batch}
        last = ctx.units[-1]
        for u in ctx.units:
            params = ctx.state.wait_for(APPLIED, u)
            with ctx.trace.record("E", u):
                st = ctx.apply_fn(u)(params, st)
                jax.block_until_ready(st["logits" if u == last else "x"])
                if u == last and ctx.on_output is not None:
                    # first token sampled inside the final E event
                    ctx.on_output(st["logits"])
        ctx.state.publish(OUTPUT, "logits", st["logits"])


class PipelineRuntime:
    """Run a set of units to completion; surface the first error."""

    def __init__(self, units: Sequence[PipelineUnit], state: PipelineState):
        self.units = list(units)
        self.state = state

    def run(self):
        threads = [u.thread() for u in self.units]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if self.state.errors:
            raise self.state.errors[0]


def standard_units(ctx: PipelineContext) -> List[PipelineUnit]:
    """The paper's three-unit pipeline for a strategy: the same runtime
    drives both the fused (PISeL) and decoupled weight paths."""
    weight_cls = (DecoupledWeightUnit if ctx.strategy.decouple
                  else FusedWeightUnit)
    return [LayerConstructionUnit(ctx), weight_cls(ctx), ComputeUnit(ctx)]
