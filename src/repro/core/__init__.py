"""Cicada core — the paper's contribution.

  pipeline     stage tracer, Gantt recorder, utilization math
  miniloader   abstract construction + 1-bit placeholders (Sec. III-B)
  decoupler    async retrieval + out-of-order application (Sec. III-C/D)
  scheduler    Priority-Aware Scheduler, Algorithm 1 (Sec. III-E)
  strategies   traditional | pisel | mini | preload | cicada
  units        PipelineUnit runtime: event-driven execution units
  coldstart    ColdStartEngine: request -> live model via the pipeline
"""
from repro.core.coldstart import ColdStartEngine, LoadResult  # noqa: F401
from repro.core.pipeline import PipelineTrace, StageEvent  # noqa: F401
from repro.core.scheduler import PriorityAwareScheduler  # noqa: F401
from repro.core.strategies import STRATEGIES, Strategy, get_strategy  # noqa: F401
from repro.core.units import (PipelineContext, PipelineRuntime,  # noqa: F401
                              PipelineState, PipelineUnit)
