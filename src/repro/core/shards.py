"""Shard-granular retrieval plans: (layer-unit, shard) is the unit of
pipelined work.

λScale and HydraServe/ParaServe show that parallelizing the *load*
across workers/devices is the dominant lever for serverless LLM cold
starts.  This module brings that into Cicada's pipeline: a
:class:`UnitShardPlan` splits one layer unit's weight extent into one
retrieval stream per mesh device, each stream reading only the byte
ranges of the leaf slices its device owns (``WeightStore.
read_leaf_slice``).  Streams are independent — they draw from separate
simulated-device channels, carry their own Priority-Aware-Scheduler
gates/deadlines, and are cached per ``(model, unit, shard)``.

Placement is *eager*: the moment a shard stream lands, its leaf slices
are committed to their target devices with ``jax.device_put`` —
host-to-device transfer overlaps the remaining shards' I/O instead of
serializing after the full unit (":ref:`stream weights straight onto
the mesh`").  A unit's weight-application event fires when its *last*
shard lands: the host-side leaves are merged for the in-pipeline
compute (bit-identical to the single-device path — the E units never
run sharded collectives), and the steady-state leaf is assembled from
the already-committed per-device buffers with
``jax.make_array_from_single_device_arrays`` (a metadata stitch, no
data movement).

Leaves the apply path *transforms* — int8-quantized (per-column scales
live at the tail of the payload) and floating leaves under an
``apply_dtype`` cast — are sharded like any other leaf: each stream
reads its value slice (plus, for quantized leaves, the f32 scale
entries of its columns) and its placement lane runs the
``weight_transform`` kernel on the slice *before* the commit, so the
compute-bound weight-application phase is pipelined per shard instead
of serialized at the unit's apply event.  Bit-identity with the
whole-read dequant path holds because the transform is elementwise
(value = f32(w)·f32(scale[col]) cast once, independent of tiling).

Leaves whose resolved spec is replication (including any axis that
does not divide its dimension — ``_guarded_spec``'s fallback) are read
whole by exactly one stream, round-robined across shards for balance.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import analysis
from repro.distributed.sharding import ShardingRules, leaf_specs
from repro.kernels import ops
from repro.store.store import slice_byte_runs

# Shard slices whose contiguous runs would fall below this floor are
# read whole by one stream instead (still *committed* sharded): a
# strided sub-KB run pattern pays more in seeks than parallel links
# save.  Real deployments with head-sharded attention leaves stay well
# above it (32 heads x 128 dims x 4B = 16 KiB runs).
RUN_FLOOR_BYTES = 1024

# Leaves below this per-device size skip the eager in-stream commit and
# are placed in one batched device_put at weight application: the
# per-dispatch overhead of committing dozens of small buffers from I/O
# threads costs more wall time than the transfer overlap saves.  The
# heavy leaves (embeddings, FFN matrices) — where overlap matters — are
# far above it.
COMMIT_FLOOR_BYTES = 256 * 1024

PyTree = Any
Mesh = Any           # jax.sharding.Mesh
Index = Tuple[Any, ...]          # per-dim slices into a leaf
# one retrieved piece: (leaf, array, scale_or_None, index_or_None)
ShardPayload = List[Tuple[str, np.ndarray, Optional[np.ndarray],
                          Optional[Index]]]


@dataclasses.dataclass
class LeafPiece:
    """One shard stream's share of one leaf."""
    leaf: str
    index: Optional[Index]       # None -> whole payload (replicated/quant)
    nbytes: int                  # bytes this stream reads for the piece
    devices: Tuple[Any, ...]     # eager-commit targets


@dataclasses.dataclass
class UnitShardPlan:
    """Static per-(model, unit) retrieval plan for a mesh."""
    unit: str
    mesh: Mesh
    specs: Dict[str, Any]        # leaf -> NamedSharding
    pieces: List[List[LeafPiece]]          # per shard
    shapes: Dict[str, Tuple[int, ...]]     # leaf -> full shape
    dtypes: Dict[str, str]                 # leaf -> stored dtype
    quant: Dict[str, bool]                 # leaf -> int8-stored
    commit: Dict[str, bool]                # leaf -> eager device commit
    transformed: Dict[str, bool]           # leaf -> dequant/cast at apply
    out_dtype: Dict[str, Any]              # leaf -> transform target (or None)
    tag: str                               # mesh-shape + rules fingerprint

    @property
    def n_shards(self) -> int:
        return len(self.pieces)

    def shard_nbytes(self, shard: int) -> int:
        return sum(p.nbytes for p in self.pieces[shard])


def _normalize(index: Index, shape: Tuple[int, ...]) -> Tuple:
    out = []
    for s, dim in zip(index, shape):
        out.append((0 if s.start is None else int(s.start),
                    dim if s.stop is None else int(s.stop)))
    return tuple(out)


def plan_tag(mesh, rules: ShardingRules) -> str:
    """Deterministic identity of a (mesh shape, rules) combination —
    part of the shard cache key: the same unit planned under different
    rules (or mesh shape) holds different byte ranges, and a shared
    WeightCache must never serve one as the other."""
    import zlib
    desc = repr(sorted(rules.mapping.items())).encode()
    return "%s#%08x" % ("x".join(str(s) for s in mesh.devices.shape),
                        zlib.crc32(desc) & 0xFFFFFFFF)


def plan_unit(store, model_name: str, unit: str, abstract_unit: PyTree,
              mesh, rules: ShardingRules,
              apply_dtype=None) -> UnitShardPlan:
    """One retrieval stream per mesh device; each distinct leaf slice is
    owned by the first device that holds it (replicas commit without
    re-reading), whole-payload leaves round-robin across streams.

    apply_dtype: the engine's weight-application cast target.  Leaves
    the apply path transforms — quantized, or floating under a cast —
    record their ``out_dtype`` so the placement lane can run the
    per-shard ``weight_transform`` before committing; their device
    buffers hold the *transformed* dtype."""
    devices = list(mesh.devices.flatten())
    pos = {d: i for i, d in enumerate(devices)}
    n = len(devices)
    specs = leaf_specs(abstract_unit, mesh, rules)
    recs = store.manifest(model_name)["units"][unit]["extents"]
    pieces: List[List[LeafPiece]] = [[] for _ in range(n)]
    shapes: Dict[str, Tuple[int, ...]] = {}
    dtypes: Dict[str, str] = {}
    quant: Dict[str, bool] = {}
    commit: Dict[str, bool] = {}
    transformed: Dict[str, bool] = {}
    out_dtype: Dict[str, Any] = {}
    rr = 0
    for rec in recs:
        leaf = rec["path"]
        shape = tuple(rec["shape"])
        shapes[leaf] = shape
        dtypes[leaf] = rec["dtype"]
        quant[leaf] = rec.get("quant") == "int8"
        if quant[leaf]:
            out_dtype[leaf] = apply_dtype or jnp.float32
        elif apply_dtype is not None and \
                np.issubdtype(np.dtype(rec["dtype"]), np.floating):
            out_dtype[leaf] = apply_dtype
        else:
            out_dtype[leaf] = None
        transformed[leaf] = out_dtype[leaf] is not None
        sharding = specs[leaf]
        replicated = all(ax is None for ax in tuple(sharding.spec))
        per_device = rec["nbytes"] if replicated else rec["nbytes"] // n
        commit[leaf] = per_device >= COMMIT_FLOOR_BYTES
        whole = replicated
        groups: Dict[Tuple, Tuple[Index, List[Any]]] = {}
        if not whole:
            imap = sharding.devices_indices_map(shape)
            itemsize = 1 if quant[leaf] else np.dtype(rec["dtype"]).itemsize
            for d in devices:
                idx = imap[d]
                key = _normalize(idx, shape)
                groups.setdefault(key, (idx, []))[1].append(d)
            for idx, _ds in groups.values():
                runs = slice_byte_runs(shape, itemsize, idx)
                if runs and min(nb for _, nb in runs) < RUN_FLOOR_BYTES:
                    whole = True        # strided fine-grained slices:
                    break               # read once, commit sharded
        if whole:
            pieces[rr % n].append(
                LeafPiece(leaf, None, rec["nbytes"], tuple(devices)))
            rr += 1
            continue
        for idx, ds in groups.values():
            owner = min(pos[d] for d in ds)
            nb = store.leaf_slice_nbytes(model_name, unit, leaf, idx)
            pieces[owner].append(LeafPiece(leaf, idx, nb, tuple(ds)))
    return UnitShardPlan(unit, mesh, specs, pieces, shapes, dtypes, quant,
                         commit, transformed, out_dtype,
                         plan_tag(mesh, rules))


class ShardedUnitData:
    """Per-load accumulation of one unit's arriving shards.

    ``add_shard`` (called on placement lanes, one call per shard)
    merges the host-side slices into full leaves for the pipeline's
    compute units, runs the per-shard ``weight_transform`` (dequant /
    cast) on transformed leaves, and eagerly commits each — possibly
    transformed — slice to its target devices.  When the last shard has
    landed, :meth:`host_leaves` feeds the standard weight-application
    path and :meth:`global_array` stitches the committed buffers into
    the steady-state sharded leaf.
    """

    def __init__(self, plan: UnitShardPlan, *, trace=None):
        """trace: a PipelineTrace — each shard whose placement lane ran
        the fused ``weight_transform`` emits a per-shard ``T`` event
        (``meta={"shard": i}``) so the transform work that previously
        hid inside the retrieval lanes shows up as its own Gantt
        sub-row."""
        self.plan = plan
        self.trace = trace
        self._lock = analysis.make_lock("ShardedUnitData._lock")
        self._host: Dict[str, np.ndarray] = {}        # guarded-by: _lock
        # transformed leaves also merge their *dequantized/cast* shard
        # outputs host-side, so the compute prefetch reuses the work the
        # placement lanes already did instead of re-transforming the
        # full leaf (the transform is elementwise: merged slices ==
        # whole-leaf transform, bit for bit)
        self._host_t: Dict[str, np.ndarray] = {}      # guarded-by: _lock
        self._scales: Dict[str, Optional[np.ndarray]] = {}  # guarded-by: _lock
        self._bufs: Dict[Tuple[str, int], jax.Array] = {}   # guarded-by: _lock
        self._compute: Optional[Dict[str, jax.Array]] = None  # guarded-by: _lock
        self._arrived = 0                             # guarded-by: _lock

    def _host_alloc_locked(self, leaf: str) -> np.ndarray:
        full = self._host.get(leaf)
        if full is None:
            dt = np.int8 if self.plan.quant[leaf] \
                else np.dtype(self.plan.dtypes[leaf])
            full = np.empty(self.plan.shapes[leaf], dt)
            self._host[leaf] = full
            # quantized leaves assemble their scale vector from the
            # per-shard column slices; shards with overlapping columns
            # write identical values
            self._scales[leaf] = (
                np.empty(self.plan.shapes[leaf][-1], np.float32)
                if self.plan.quant[leaf] else None)
        return full

    def host_dest(self, leaf: str, index: Index) -> np.ndarray:
        """A writable view of ``leaf[index]`` in the preassembled full
        host leaf — shard reads gather straight into it (zero staging
        copies on the cache-less path).  Quantized leaves expose the
        int8 value region at the leaf's logical shape; the scale slice
        travels in the payload and is merged by :meth:`add_shard`."""
        with self._lock:
            full = self._host_alloc_locked(leaf)
        return full[tuple(index)]

    def _transform(self, arr: np.ndarray, scale: Optional[np.ndarray],
                   leaf: str) -> jax.Array:
        """The fused apply stage for one piece: dequant/cast ``arr``
        (any shape; columns = its last dim) via the ``weight_transform``
        kernel, tiled for the piece's size."""
        a2 = jnp.asarray(arr).reshape(-1, arr.shape[-1]) \
            if arr.ndim >= 2 else jnp.asarray(arr)[None]
        bn, bm = ops.wt_shard_blocks(arr.nbytes)
        t = ops.weight_transform(
            a2, None if scale is None else jnp.asarray(scale),
            out_dtype=self.plan.out_dtype[leaf], bn=bn, bm=bm)
        return t.reshape(arr.shape)

    def _merge_transformed(self, leaf: str, index: Index, t: jax.Array):
        """Gather one ranged piece's transformed output into the full
        transformed host leaf the compute prefetch ships (whole-payload
        pieces write ``_host_t`` directly in :meth:`add_shard`)."""
        with self._lock:
            full = self._host_t.get(leaf)
            if full is None:
                full = np.empty(self.plan.shapes[leaf],
                                self.plan.out_dtype[leaf])
                self._host_t[leaf] = full
        full[tuple(index)] = np.asarray(t)

    def add_shard(self, shard: int, payload: ShardPayload,
                  merged: bool = False) -> bool:
        """``merged=True``: ranged pieces' *values* were gathered
        straight into the full host leaves via :meth:`host_dest` —
        scale merging, the per-shard transform and device placement
        remain here.  Returns True for exactly one caller: the one
        whose shard completed the unit (after the compute prefetch
        below is in place — the publish signal)."""
        plan = self.plan
        # all of this shard's device commits go out as ONE batched
        # device_put (per-piece dispatch overhead would rival the I/O
        # it overlaps at higher shard counts); transformed pieces run
        # the weight_transform kernel here — on the placement lane, the
        # moment the shard lands — and commit the transformed dtype
        put_keys: List[Tuple[str, int]] = []
        put_arrs: List[Any] = []
        put_devs: List[Any] = []
        t_t0 = t_t1 = None          # this shard's transform-work span
        for (leaf, arr, scale, index), piece in zip(payload,
                                                    plan.pieces[shard]):
            if index is None:                        # whole-payload leaf
                with self._lock:
                    self._host[leaf] = arr
                    self._scales[leaf] = scale
                src = arr
                if plan.transformed[leaf]:
                    if t_t0 is None:
                        t_t0 = time.monotonic()
                    src = np.asarray(self._transform(arr, scale, leaf)
                                     ).reshape(plan.shapes[leaf])
                    t_t1 = time.monotonic()
                    with self._lock:
                        self._host_t[leaf] = src
                if plan.commit[leaf]:
                    sharding = plan.specs[leaf]
                    replicated = all(
                        ax is None for ax in tuple(sharding.spec))
                    imap = None if replicated else \
                        sharding.devices_indices_map(plan.shapes[leaf])
                    for d in piece.devices:
                        put_keys.append((leaf, d.id))
                        put_arrs.append(src if replicated
                                        else src[imap[d]])
                        put_devs.append(d)
                continue
            if plan.quant[leaf] and scale is not None:
                with self._lock:                     # merge scale columns
                    self._host_alloc_locked(leaf)
                    lo = 0 if index[-1].start is None else \
                        int(index[-1].start)
                    self._scales[leaf][lo:lo + scale.shape[0]] = scale
            if not merged:
                with self._lock:
                    full = self._host_alloc_locked(leaf)
                full[tuple(index)] = arr             # disjoint per shard
            src = None
            if plan.transformed[leaf]:               # fused per-shard apply
                if t_t0 is None:
                    t_t0 = time.monotonic()
                src = self._transform(arr, scale, leaf)
                self._merge_transformed(leaf, index, src)
                t_t1 = time.monotonic()
            if plan.commit[leaf]:
                for d in piece.devices:              # eager mesh commit
                    put_keys.append((leaf, d.id))
                    put_arrs.append(src if src is not None else arr)
                    put_devs.append(d)
        if put_arrs:
            bufs = jax.device_put(put_arrs, put_devs)
            with self._lock:
                self._bufs.update(zip(put_keys, bufs))
        if t_t0 is not None and self.trace is not None:
            self.trace.add_event("T", plan.unit, t_t0, t_t1,
                                 meta={"shard": shard})
        with self._lock:
            self._arrived += 1
            last = self._arrived >= plan.n_shards
        if last:
            # the unit is complete: issue the (async) default-device
            # placement of the merged full leaves here, so the weight
            # unit's A is a metadata stitch + transfer wait instead of
            # a critical-path host-to-device copy of the whole unit.
            # Transformed leaves ship the merged per-shard
            # weight_transform outputs — the dequant/cast compute phase
            # already ran on the placement lanes, so A just waits
            with self._lock:
                names = [leaf for leaf in self._host
                         if not plan.transformed[leaf]]
                srcs = [self._host[n] for n in names] + \
                    [self._host_t[n] for n in self._host_t]
                t_names = list(self._host_t)
            bufs = jax.device_put(srcs)                 # async; outside lock
            with self._lock:
                # R1 (real finding): this publish raced the compute_bufs
                # reader before it moved under the lock
                self._compute = dict(zip(names + t_names, bufs))
        return last

    @property
    def complete(self) -> bool:
        """All shards merged AND committed (including the compute
        prefetch): True only after some add_shard returned last=True."""
        with self._lock:
            return self._arrived >= self.plan.n_shards and \
                self._compute is not None

    def host_leaves(self) -> Dict[str, Tuple[np.ndarray,
                                             Optional[np.ndarray]]]:
        """The merged {leaf: (array, scale)} dict — byte-identical to
        ``WeightStore.deserialize`` of the whole unit (quantized leaves
        merged from ranged shards carry the leaf's *logical* shape
        rather than deserialize's 2-D view; consumers reshape)."""
        with self._lock:
            return {k: (v, self._scales[k]) for k, v in self._host.items()}

    @property
    def compute_bufs(self) -> Dict[str, jax.Array]:
        """Default-device placements of the merged full leaves (issued
        by the last shard's commit).  Covers every leaf: transformed
        ones ship their merged per-shard ``weight_transform`` outputs,
        so the weight unit's A never recomputes the apply phase."""
        with self._lock:
            return self._compute or {}

    def global_array(self, leaf: str) -> jax.Array:
        """Stitch the eagerly-committed per-device buffers into the
        leaf's global sharded array (metadata only — no transfer)."""
        sharding = self.plan.specs[leaf]
        shape = self.plan.shapes[leaf]
        with self._lock:
            bufs = [self._bufs[(leaf, d.id)]
                    for d in sharding.devices_indices_map(shape)]
        return jax.make_array_from_single_device_arrays(
            shape, sharding, bufs)
