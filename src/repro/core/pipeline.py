"""Pipeline instrumentation: stage events, Gantt rows, utilization math.

The paper's metrics (Sec. IV-C) all derive from per-stage timestamps:

  * working time  — duration of each stage event;
  * waiting time  — start(current stage) - end(predecessor stage), per
    layer (Q3 / Fig. 11);
  * pipeline utilization — union of busy intervals (overlaps merged)
    divided by total pipeline time (Q4 / Fig. 12-13);
  * Gantt timeline — events grouped by execution-unit row
    (Layer / Retrieve / Weight / Compute, Fig. 14).

Stages: L = layer construction, R = weight file retrieval (its own row
only under the WeightDecoupler), A = weight application, E = inference
execution, T = per-shard weight transform (dequant/cast fused into the
shard committer's placement lane under a mesh — previously invisible to
the trace because it happens inside R's landing path, before A).
Thread-safe; timestamps are ``time.monotonic()``.

T events carry ``meta={"shard": <device index>}`` and live on their own
Gantt row; they are *excluded* from the default busy/utilization stage
set, matching R: transform work rides the retrieval lanes, so counting
it would double-book intervals the utilization metric already treats as
overlap-eligible I/O time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Tuple

from repro import analysis

STAGE_ROW = {"L": "Layer", "R": "Retrieve", "T": "Transform",
             "A": "Weight", "E": "Compute"}
PRED = {"A": "L", "E": "A"}       # waiting-time predecessor (paper Sec IV-C)


@dataclasses.dataclass
class StageEvent:
    stage: str                    # "L" | "R" | "T" | "A" | "E"
    layer: str                    # unit name, e.g. "block_003"
    t_start: float
    t_end: float
    meta: Optional[dict] = None

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    @property
    def row(self) -> str:
        return STAGE_ROW[self.stage]


class PipelineTrace:
    def __init__(self):
        self._lock = analysis.make_lock("PipelineTrace._lock")
        # append-only while pipeline threads run; queries read after
        # the join, so only writes need the lock
        self.events: List[StageEvent] = []    # guarded-by[writes]: _lock
        self.t0: Optional[float] = None
        self.t_end: Optional[float] = None
        # (layer, placeholder_bytes, t_construct_end, t_apply_end)
        self.memory: List[Tuple[str, int, float, float]] = []  # guarded-by[writes]: _lock

    # ------------------------------------------------------------- recording
    def start(self):
        self.t0 = time.monotonic()

    def finish(self):
        self.t_end = time.monotonic()

    def record(self, stage: str, layer: str):
        """Context manager timing one stage event."""
        trace = self

        class _Ctx:
            def __enter__(self):
                self.ts = time.monotonic()
                return self

            def __exit__(self, *exc):
                te = time.monotonic()
                with trace._lock:
                    trace.events.append(StageEvent(stage, layer, self.ts, te))
                return False

        return _Ctx()

    def add_event(self, stage: str, layer: str, t_start: float, t_end: float,
                  meta: Optional[dict] = None):
        with self._lock:
            self.events.append(StageEvent(stage, layer, t_start, t_end, meta))

    def record_memory(self, layer: str, nbytes: int, t_construct_end: float,
                      t_apply_end: float):
        with self._lock:
            self.memory.append((layer, nbytes, t_construct_end, t_apply_end))

    # --------------------------------------------------------------- queries
    def _bounds(self) -> Tuple[float, float]:
        ts = self.t0 if self.t0 is not None else \
            min(e.t_start for e in self.events)
        te = self.t_end if self.t_end is not None else \
            max(e.t_end for e in self.events)
        return ts, te

    def total_time(self) -> float:
        ts, te = self._bounds()
        return te - ts

    @staticmethod
    def merge_intervals(iv: Iterable[Tuple[float, float]]
                        ) -> List[Tuple[float, float]]:
        ivs = sorted(iv)
        out: List[Tuple[float, float]] = []
        for s, e in ivs:
            if out and s <= out[-1][1]:
                out[-1] = (out[-1][0], max(out[-1][1], e))
            else:
                out.append((s, e))
        return out

    def busy_time(self, stages: Optional[Iterable[str]] = ("L", "A", "E")
                  ) -> float:
        """Union of busy intervals.  The default stage set counts only
        *execution-unit work* — retrieval (R) is kernel/DMA time during
        which the issuing unit idles (the paper's Fig. 5c framing), so
        it is excluded: under PISeL that I/O sits on the critical path
        and shows up as idle, under the WeightDecoupler it overlaps
        construction and utilization approaches 100%."""
        evs = [e for e in self.events
               if stages is None or e.stage in stages]
        merged = self.merge_intervals((e.t_start, e.t_end) for e in evs)
        return sum(e - s for s, e in merged)

    def utilization(self) -> float:
        """Merged busy time / total pipeline time (paper Q4)."""
        t = self.total_time()
        return self.busy_time() / t if t > 0 else 0.0

    def work_by_stage(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.events:
            out[e.stage] = out.get(e.stage, 0.0) + e.duration
        return out

    def events_for(self, stage: str) -> Dict[str, StageEvent]:
        return {e.layer: e for e in self.events if e.stage == stage}

    def wait_by_stage(self) -> Dict[str, float]:
        """Per-layer waiting time: start(stage_i) - end(pred_i), summed.

        A's predecessor is L (the paper's "weight wait"); E's is A
        ("compute wait").  Negative gaps (stage started before its
        logical predecessor ended — impossible by construction) clamp
        to 0.
        """
        out: Dict[str, float] = {}
        for stage, pred in PRED.items():
            cur = self.events_for(stage)
            prev = self.events_for(pred)
            w = 0.0
            for layer, e in cur.items():
                if layer in prev:
                    w += max(0.0, e.t_start - prev[layer].t_end)
            out[stage] = w
        return out

    # ------------------------------------------------------- memory metrics
    def memory_overhead_bytes(self) -> int:
        """Peak construction-placeholder residency (paper Fig. 10 left)."""
        points = []
        for _, nbytes, t0, t1 in self.memory:
            points.append((t0, nbytes))
            points.append((t1, -nbytes))
        points.sort()
        cur = peak = 0
        for _, d in points:
            cur += d
            peak = max(peak, cur)
        return peak

    def memory_total_bytes(self) -> int:
        return sum(n for _, n, _, _ in self.memory)

    def memory_usage_time(self) -> float:
        """Cumulative placeholder-residency duration over all layers
        (paper Fig. 10 right)."""
        return sum(t1 - t0 for _, _, t0, t1 in self.memory)

    # ----------------------------------------------------------------- gantt
    def gantt_rows(self) -> List[dict]:
        ts, _ = self._bounds()
        return [{"row": e.row, "stage": e.stage, "layer": e.layer,
                 "start": e.t_start - ts, "end": e.t_end - ts}
                for e in sorted(self.events, key=lambda e: e.t_start)]

    def render_gantt(self, width: int = 100) -> str:
        """ASCII Gantt chart (Fig. 14 analogue)."""
        if not self.events:
            return "(empty trace)"
        ts, te = self._bounds()
        span = max(te - ts, 1e-9)
        lines = []
        for row in ("Layer", "Retrieve", "Transform", "Weight", "Compute"):
            evs = [e for e in self.events if e.row == row]
            if not evs:
                continue
            buf = [" "] * width
            for e in evs:
                a = int((e.t_start - ts) / span * (width - 1))
                b = max(a + 1, int((e.t_end - ts) / span * (width - 1)) + 1)
                ch = e.layer[-1] if e.layer else "#"
                for i in range(a, min(b, width)):
                    buf[i] = ch
            lines.append(f"{row:9s}|{''.join(buf)}|")
        lines.append(f"{'':9s} 0{'':{width - 8}s}{span * 1e3:.0f} ms")
        return "\n".join(lines)

    def summary(self) -> dict:
        work = self.work_by_stage()
        wait = self.wait_by_stage()
        return {
            "total_s": self.total_time(),
            "utilization": self.utilization(),
            "work_L": work.get("L", 0.0),
            "work_R": work.get("R", 0.0),
            "work_T": work.get("T", 0.0),
            "work_A": work.get("A", 0.0),
            "work_E": work.get("E", 0.0),
            "wait_A": wait.get("A", 0.0),
            "wait_E": wait.get("E", 0.0),
            "mem_overhead_bytes": self.memory_overhead_bytes(),
            "mem_usage_time_s": self.memory_usage_time(),
        }
