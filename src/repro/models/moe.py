"""Mixture-of-Experts FFN (Mixtral 8x7B, Arctic 128e + dense residual).

TPU-idiomatic *gather-based* dispatch, routed **per batch row** so the
token axis never crosses data-parallel shards:

  1. router logits -> softmax -> top-k experts per token (token choice);
  2. per (row, expert): take the top-C tokens by routing weight
     (C = ceil(k*S/E * capacity_factor)) — capacity overflow drops the
     *lowest-weight* tokens (vs GShard's latest-token drop; documented
     deviation, strictly no worse for quality);
  3. gather token activations (B, E, C, d) — local to each data shard;
  4. expert einsum with E sharded over the `expert` logical axis (EP);
  5. weighted scatter-add back — the only cross-shard collective is the
     all-reduce over the expert axis that XLA inserts here.

Unassigned capacity slots carry routing weight exactly 0 so their
contribution vanishes; no masking pass is needed after the gather.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import quant
from repro.distributed.sharding import constrain
from repro.models import layers

PyTree = Any


def moe_params(cfg, key: jax.Array) -> PyTree:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(ks[0], (d, E), jnp.float32),
        "experts": {
            "wg": layers.dense_init(ks[1], (E, d, f), cfg.param_dtype,
                                    fan_in=d),
            "wu": layers.dense_init(ks[2], (E, d, f), cfg.param_dtype,
                                    fan_in=d),
            "wd": layers.dense_init(ks[3], (E, f, d), cfg.param_dtype,
                                    fan_in=f),
        },
    }
    if cfg.dense_residual:
        p["dense"] = layers.mlp_params(cfg, ks[4])
    return p


def capacity(cfg, seq: int) -> int:
    c = math.ceil(cfg.top_k * seq / cfg.n_experts * cfg.capacity_factor)
    return max(1, min(c, seq))


def route(cfg, router_w: jax.Array, x: jax.Array
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> (weights (B,S,E) sparse top-k, probs (B,S,E),
    topk_mask (B,S,E))."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    vals, eidx = jax.lax.top_k(probs, cfg.top_k)          # (B, S, k)
    vals = vals / jnp.sum(vals, axis=-1, keepdims=True)   # renormalize
    oh = jax.nn.one_hot(eidx, cfg.n_experts, dtype=jnp.float32)
    w_te = jnp.einsum("bsk,bske->bse", vals, oh)          # sparse weights
    mask = jnp.sum(oh, axis=2)                            # (B, S, E) 0/1
    return w_te, probs, mask


def load_balance_loss(probs: jax.Array, mask: jax.Array, n_experts: int
                      ) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e."""
    f_e = jnp.mean(mask, axis=(0, 1))                     # dispatch fraction
    p_e = jnp.mean(probs, axis=(0, 1))                    # mean router prob
    return n_experts * jnp.sum(f_e * p_e)


def moe_block(cfg, p: PyTree, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d).  Returns (y (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E = cfg.n_experts
    C = capacity(cfg, S)
    cd = cfg.compute_dtype

    w_te, probs, mask = route(cfg, p["router"], x)
    aux = load_balance_loss(probs, mask, E)

    # per (row, expert) pick top-C tokens by weight
    w_et = jnp.swapaxes(w_te, 1, 2)                       # (B, E, S)
    g, idx = jax.lax.top_k(w_et, C)                       # (B, E, C)

    x_e = jnp.take_along_axis(x[:, None], idx[..., None], axis=2)
    x_e = constrain(x_e, "batch", "expert_act", None, None)  # (B, E, C, d)

    we = p["experts"]
    h_g = quant.expert_einsum("becd,edf->becf", x_e, we["wg"], cd)
    h_u = quant.expert_einsum("becd,edf->becf", x_e, we["wu"], cd)
    h = jax.nn.silu(h_g) * h_u
    # shard the expert hidden axis over `model` (the E axis cannot shard
    # when n_experts < mesh width): the wd contraction then runs locally
    # with a bf16 partial-sum reduce instead of XLA's f32 all-gather of
    # h to full width — the dominant collective in MoE training (§Perf)
    h = constrain(h, "batch", "expert_act", None, "ff")
    y_e = quant.expert_einsum("becf,efd->becd", h, we["wd"], cd)
    y_e = y_e * g[..., None].astype(cd)                   # zero for unassigned

    # scatter-add back to token positions (combine)
    out = jnp.zeros((B, S, d), cd)
    b_idx = jnp.arange(B)[:, None, None]
    out = out.at[b_idx, idx].add(y_e)
    out = constrain(out, "batch", "seq", "embed")

    if cfg.dense_residual:
        out = out + layers.mlp_block(cfg, p["dense"], x)
    return out, aux.astype(jnp.float32)


def moe_block_dense_ref(cfg, p: PyTree, x: jax.Array) -> jax.Array:
    """Oracle: compute every expert on every token, combine with the exact
    top-k weights, no capacity limit.  O(E/k) more FLOPs — tests only."""
    cd = cfg.compute_dtype
    w_te, _, _ = route(cfg, p["router"], x)               # (B, S, E)
    we = p["experts"]
    h_g = quant.expert_einsum("bsd,edf->besf", x, we["wg"], cd,
                              shared_x=True)
    h_u = quant.expert_einsum("bsd,edf->besf", x, we["wu"], cd,
                              shared_x=True)
    h = jax.nn.silu(h_g) * h_u
    y_e = quant.expert_einsum("besf,efd->besd", h, we["wd"], cd)
    out = jnp.einsum("bse,besd->bsd", w_te.astype(cd), y_e)
    if cfg.dense_residual:
        out = out + layers.mlp_block(cfg, p["dense"], x)
    return out
