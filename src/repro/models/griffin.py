"""Griffin / RecurrentGemma blocks: RG-LRU recurrent mixer + local attention.

Layer pattern is (rglru, rglru, attn) cyclic (1 attention per 2 recurrent,
as in the paper).  The recurrent block:

    px = x W_x        (value branch, causal conv width-4, then RG-LRU)
    pg = gelu(x W_g)  (gate branch)
    r  = sigmoid(px * w_a + b_a)       (diagonal recurrence gate)
    i  = sigmoid(px * w_i + b_i)       (diagonal input gate)
    a  = exp(-c * softplus(lam) * r)   (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * px_t)
    y  = (h * pg) W_y

Local attention layers are plain GQA blocks with
``window = cfg.local_attn_window`` (MQA for recurrentgemma: kv = 1) —
their ring KV cache is what keeps ``long_500k`` decode O(window).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import quant
from repro.distributed.sharding import constrain
from repro.models import layers

PyTree = Any
RGLRU_C = 8.0


def rglru_params(cfg, key: jax.Array) -> PyTree:
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 5)
    return {
        "wx": layers.dense_init(ks[0], (d, w), cfg.param_dtype),
        "wgate": layers.dense_init(ks[1], (d, w), cfg.param_dtype),
        "conv": layers.conv_params(ks[2], cfg.conv_width, w, cfg.param_dtype),
        # diagonal recurrence/input gates + recurrence rate
        "wa": jnp.zeros((w,), jnp.float32),
        "ba": jnp.zeros((w,), jnp.float32),
        "wi": jnp.zeros((w,), jnp.float32),
        "bi": jnp.zeros((w,), jnp.float32),
        # lam init so a ~ 0.9..0.999 at r=0.5 (standard griffin init range)
        "lam": jnp.full((w,), 0.65, jnp.float32),
        "wy": layers.dense_init(ks[3], (w, d), cfg.param_dtype, fan_in=w),
    }


def _gates(p: PyTree, px: jax.Array):
    pxf = px.astype(jnp.float32)
    r = jax.nn.sigmoid(pxf * p["wa"] + p["ba"])
    i = jax.nn.sigmoid(pxf * p["wi"] + p["bi"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    # 1 - a^2 computed stably via expm1
    b_scale = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = b_scale * (i * pxf)
    return a, b


def rglru_block(cfg, p: PyTree, x: jax.Array,
                conv_state: Optional[jax.Array] = None,
                h_state: Optional[jax.Array] = None,
                *, return_state: bool = False):
    """x: (B, S, d) -> y (B, S, d) [, (conv_state, h_state)]."""
    from repro.kernels import ops
    cd = cfg.compute_dtype
    px = quant.einsum("bsd,dw->bsw", x, p["wx"], cd)
    pg = jax.nn.gelu(quant.einsum("bsd,dw->bsw", x, p["wgate"], cd)
                     .astype(jnp.float32)).astype(cd)
    px, new_conv_state = layers.causal_conv1d(px, p["conv"], conv_state)
    px = constrain(px, "batch", "seq", "ff")

    a, b = _gates(p, px)
    if return_state:
        h0 = h_state if h_state is not None else \
            jnp.zeros((x.shape[0], a.shape[-1]), jnp.float32)
        from repro.kernels import ref
        h, hS = ref.rglru(a, b, h0=h0, return_state=True)
    else:
        h = ops.rglru_scan(a, b)
        hS = None
    y = (h.astype(cd) * pg)
    out = quant.einsum("bsw,wd->bsd", y, p["wy"], cd)
    out = constrain(out, "batch", "seq", "embed")
    if return_state:
        return out, (new_conv_state, hS)
    return out


def rglru_decode(cfg, p: PyTree, x: jax.Array, conv_state: jax.Array,
                 h_state: jax.Array):
    """Single-token step.  x: (B, 1, d); h_state (B, W)."""
    from repro.kernels import ops
    cd = cfg.compute_dtype
    px = quant.einsum("bsd,dw->bsw", x, p["wx"], cd)
    pg = jax.nn.gelu(quant.einsum("bsd,dw->bsw", x, p["wgate"], cd)
                     .astype(jnp.float32)).astype(cd)
    px, conv_state = layers.causal_conv1d(px, p["conv"], conv_state)
    a, b = _gates(p, px)                                  # (B, 1, W)
    h_state = ops.rglru_step(h_state, a[:, 0], b[:, 0])
    y = h_state[:, None].astype(cd) * pg
    out = quant.einsum("bsw,wd->bsd", y, p["wy"], cd)
    return out, conv_state, h_state


def init_states(cfg, batch: int):
    """Zeroed decode states for one RG-LRU layer."""
    w = cfg.rglru_width or cfg.d_model
    conv = jnp.zeros((batch, cfg.conv_width - 1, w), cfg.compute_dtype)
    h = jnp.zeros((batch, w), jnp.float32)
    return conv, h
