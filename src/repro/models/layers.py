"""Building blocks: norms, RoPE, GQA attention (full / sliding-window),
SwiGLU / GELU MLPs, embeddings.

All parameters are plain dicts; every function is pure.  Activation tensors
are annotated with *logical axis names* via :func:`repro.distributed.sharding
.constrain` so the same model code runs single-device (no-op) and under any
mesh/rule set (pjit constraints).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import quant
from repro.distributed.sharding import constrain

PyTree = Any


# ---------------------------------------------------------------------------
# initializers (the "PISeL-faithful" expensive construction path)
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype,
               fan_in: Optional[int] = None) -> jax.Array:
    """He/Kaiming-style normal init — deliberately the *real* numerical
    initialization the paper's MiniLoader elides (Sec. II-B)."""
    fan = fan_in if fan_in is not None else shape[0]
    std = math.sqrt(2.0 / max(fan, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...], dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_params(cfg, key) -> PyTree:
    d = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), cfg.param_dtype)}
    return {"scale": jnp.ones((d,), cfg.param_dtype),
            "bias": jnp.zeros((d,), cfg.param_dtype)}


def apply_norm(cfg, p: PyTree, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                             # (..., S, 1, dh/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention block (GQA, optional sliding window)
# ---------------------------------------------------------------------------

def attn_params(cfg, key: jax.Array) -> PyTree:
    d, h, k, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h, dh), cfg.param_dtype, fan_in=d),
        "wk": dense_init(ks[1], (d, k, dh), cfg.param_dtype, fan_in=d),
        "wv": dense_init(ks[2], (d, k, dh), cfg.param_dtype, fan_in=d),
        "wo": dense_init(ks[3], (h, dh, d), cfg.param_dtype, fan_in=h * dh),
    }


def qkv_project(cfg, p: PyTree, x: jax.Array, positions: jax.Array,
                *, rope: bool = True):
    """x: (B, S, D) -> q (B,S,H,dh), k/v (B,S,K,dh)."""
    cd = cfg.compute_dtype
    q = quant.einsum("bsd,dhk->bshk", x, p["wq"], cd)
    k = quant.einsum("bsd,dhk->bshk", x, p["wk"], cd)
    v = quant.einsum("bsd,dhk->bshk", x, p["wv"], cd)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(cfg, p: PyTree, o: jax.Array) -> jax.Array:
    """o: (B, S, H, dh) -> (B, S, D)."""
    y = quant.einsum("bshk,hkd->bsd", o, p["wo"], cfg.compute_dtype,
                     n_contract=2)
    return constrain(y, "batch", "seq", "embed")


def attention_block(cfg, p: PyTree, x: jax.Array, positions: jax.Array,
                    *, window: int = -1, return_kv: bool = False):
    """Self-attention sub-block (no residual, no norm).

    window: -1 -> use cfg.sliding_window; 0 -> full; >0 -> that window.
    return_kv: also return the rotated (k, v) for prefill cache writes.
    """
    from repro.kernels import ops  # local import: avoid import cycle
    if window < 0:
        window = cfg.sliding_window
    q, k, v = qkv_project(cfg, p, x, positions)
    o = ops.flash_attention(q, k, v, causal=cfg.causal, window=window)
    o = constrain(o, "batch", "seq", "heads", None)
    y = attn_out(cfg, p, o)
    if return_kv:
        return y, k, v
    return y


def attention_decode(cfg, p: PyTree, x: jax.Array, pos: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     *, window: int = -1):
    """Single-token decode.  x: (B, 1, D); caches: (B, K, S_max, dh)
    kv-head-major (dot-friendly, no transposes — §Perf iteration 2);
    pos: (B,) current position.  Returns (y, k_cache, v_cache)."""
    from repro.kernels import ops
    if window < 0:
        window = cfg.sliding_window
    q, k, v = qkv_project(cfg, p, x, pos[:, None])
    s_max = k_cache.shape[2]
    slot = (pos % s_max) if window > 0 else pos          # ring buffer for SWA
    # mask-select write (one fused pass over the cache) instead of an
    # advanced-indexing scatter, whose lowering materializes transpose +
    # copy chains of the full cache (§Perf iteration 2c)
    hit = (jnp.arange(s_max)[None, :] == slot[:, None])[:, None, :, None]
    k_cache = jnp.where(hit, k[:, 0][:, :, None, :].astype(k_cache.dtype),
                        k_cache)
    v_cache = jnp.where(hit, v[:, 0][:, :, None, :].astype(v_cache.dtype),
                        v_cache)
    o = ops.decode_attention(q[:, 0], k_cache, v_cache, pos, window=window)
    y = attn_out(cfg, p, o[:, None])
    return y, k_cache, v_cache


def attention_decode_paged(cfg, p: PyTree, x: jax.Array, pos: jax.Array,
                           k_pages: jax.Array, v_pages: jax.Array,
                           tables: jax.Array):
    """Single-token decode through a block-paged KV pool.

    x: (B, 1, D); k_pages/v_pages: (P, K, pt, dh) physical pools shared
    across the batch; tables: (B, NP) int32 page ids per row; pos: (B,)
    current position.  Row b's token is written at physical page
    ``tables[b, pos // pt]``, row ``pos % pt``.  Inactive batch rows
    carry tables full of the scratch page id, so their writes land in
    scratch and their (discarded) outputs attend only scratch garbage.
    Returns (y, k_pages, v_pages).
    """
    from repro.kernels import ops
    q, k, v = qkv_project(cfg, p, x, pos[:, None])
    P, pt = k_pages.shape[0], k_pages.shape[2]
    pg = jnp.take_along_axis(tables, (pos // pt)[:, None], axis=1)[:, 0]
    row = pos % pt
    # B-row scatter onto the addressed (page, row) cells — unlike the
    # slotted arena (attention_decode's one-hot einsum, §Perf iteration
    # 2c), a mask-select here would rewrite the *whole* pool every step
    # and its cost would scale with the page budget, not the batch.
    # Distinct live rows never collide (each owns its write page);
    # inactive rows all land in the scratch page, where a duplicate-
    # index scatter keeps an arbitrary writer — garbage either way,
    # never read (the kernel masks positions > pos exactly and live
    # tables never reference another row's pages)
    k_pages = k_pages.at[pg, :, row].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[pg, :, row].set(v[:, 0].astype(v_pages.dtype))
    o = ops.decode_attention_paged(q[:, 0], k_pages, v_pages, tables, pos)
    y = attn_out(cfg, p, o[:, None])
    return y, k_pages, v_pages


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_params(cfg, key: jax.Array, d_ff: Optional[int] = None) -> PyTree:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.act in ("silu", "geglu"):                     # gated: 3 matrices
        ks = jax.random.split(key, 3)
        return {"wg": dense_init(ks[0], (d, f), cfg.param_dtype),
                "wu": dense_init(ks[1], (d, f), cfg.param_dtype),
                "wd": dense_init(ks[2], (f, d), cfg.param_dtype, fan_in=f)}
    ks = jax.random.split(key, 2)
    return {"wu": dense_init(ks[0], (d, f), cfg.param_dtype),
            "wd": dense_init(ks[1], (f, d), cfg.param_dtype, fan_in=f)}


def mlp_block(cfg, p: PyTree, x: jax.Array) -> jax.Array:
    cd = cfg.compute_dtype
    if cfg.act in ("silu", "geglu"):
        g = quant.einsum("bsd,df->bsf", x, p["wg"], cd)
        u = quant.einsum("bsd,df->bsf", x, p["wu"], cd)
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(g) * u
    else:
        u = quant.einsum("bsd,df->bsf", x, p["wu"], cd)
        h = jax.nn.gelu(u)
    h = constrain(h, "batch", "seq", "ff")
    y = quant.einsum("bsf,fd->bsd", h, p["wd"], cd)
    return constrain(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# causal depthwise conv (Mamba-2 / Griffin temporal conv)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, kernel: jax.Array,
                  state: Optional[jax.Array] = None):
    """Depthwise causal conv.  x: (B, S, C); kernel: (W, C);
    state: (B, W-1, C) prefix carried across calls (None -> zeros).
    Returns (y (B, S, C), new_state (B, W-1, C))."""
    B, S, C = x.shape
    if quant.is_quant(kernel):           # conv taps are tiny: dequant whole
        kernel = kernel.astype(jnp.float32)
    W = kernel.shape[0]
    if state is None:
        state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # (B, S+W-1, C)
    y = jnp.zeros((B, S, C), jnp.float32)
    for i in range(W):
        y = y + xp[:, i:i + S].astype(jnp.float32) \
            * kernel[i].astype(jnp.float32)
    new_state = xp[:, -(W - 1):] if W > 1 else jnp.zeros((B, 0, C), x.dtype)
    return y.astype(x.dtype), new_state


def conv_params(key: jax.Array, width: int, channels: int, dtype) -> jax.Array:
    return dense_init(key, (width, channels), dtype, fan_in=width)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_params(cfg, key: jax.Array) -> PyTree:
    return {"tok": embed_init(key, (cfg.vocab_size, cfg.d_model),
                              cfg.param_dtype)}


def embed_lookup(cfg, p: PyTree, tokens: jax.Array) -> jax.Array:
    x = quant.gather_rows(p["tok"], tokens, cfg.compute_dtype)
    return constrain(x, "batch", "seq", "embed")


def head_params(cfg, key: jax.Array) -> PyTree:
    if cfg.tie_embeddings:
        return {}
    return {"w": dense_init(key, (cfg.d_model, cfg.vocab_size),
                            cfg.param_dtype)}


def head_logits(cfg, params: PyTree, x: jax.Array) -> jax.Array:
    cd = cfg.compute_dtype
    if cfg.tie_embeddings:
        # tied head contracts the table's *scaled* axis: not a
        # per-column-scale matmul — dequant fallback
        w = params["embed"]["tok"].astype(cd).T
        logits = jnp.einsum("bsd,dv->bsv", x, w)
    else:
        logits = quant.einsum("bsd,dv->bsv", x, params["head"]["w"], cd)
    logits = constrain(logits, "batch", "seq", "vocab")
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits
