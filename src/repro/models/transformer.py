"""LM assembly: scan-stacked steady-state views + streaming unit view.

Every assigned architecture (dense / MoE / SSM / hybrid / audio-encoder /
VLM) is an :class:`LM` instance.  Layers are grouped into *pattern units*
(length-1 pattern for uniform stacks; ``(rglru, rglru, attn)`` for
Griffin) and parameters are stored stacked ``(n_units, ...)`` per pattern
slot, so the forward pass is a single ``jax.lax.scan`` regardless of
depth — this keeps HLO size ~constant for the 40-cell dry-run matrix.

The *streaming* view (``unit_names`` / ``init_unit`` / ``abstract_unit``
/ ``unit_apply`` / ``assemble``) exposes per-layer granularity for the
cold-start pipeline: the paper's L_i / W_i+A_i / E_i execution units map
to one unit here, and ``assemble`` stacks the applied units back into
the steady-state representation once the model is fully live.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import quant
from repro.distributed.sharding import constrain
from repro.models import griffin, layers, moe, ssm
from repro.models.api import ArchConfig, Family

PyTree = Any
AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# per-kind block param/apply/cache/decode dispatch
# ---------------------------------------------------------------------------

def _kind_window(cfg, kind: str) -> int:
    if kind == "local_attn":
        return cfg.local_attn_window
    return cfg.sliding_window


def block_params(cfg, kind: str, key: jax.Array) -> PyTree:
    ks = jax.random.split(key, 4)
    if kind in ("attn", "local_attn"):
        return {"norm1": layers.norm_params(cfg, ks[0]),
                "attn": layers.attn_params(cfg, ks[1]),
                "norm2": layers.norm_params(cfg, ks[2]),
                "mlp": layers.mlp_params(cfg, ks[3])}
    if kind == "moe":
        return {"norm1": layers.norm_params(cfg, ks[0]),
                "attn": layers.attn_params(cfg, ks[1]),
                "norm2": layers.norm_params(cfg, ks[2]),
                "moe": moe.moe_params(cfg, ks[3])}
    if kind == "ssd":
        return {"norm1": layers.norm_params(cfg, ks[0]),
                "ssd": ssm.ssd_params(cfg, ks[1])}
    if kind == "rglru":
        return {"norm1": layers.norm_params(cfg, ks[0]),
                "rglru": griffin.rglru_params(cfg, ks[1]),
                "norm2": layers.norm_params(cfg, ks[2]),
                "mlp": layers.mlp_params(cfg, ks[3])}
    raise ValueError(kind)


def block_apply(cfg, kind: str, p: PyTree, x: jax.Array,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local_attn"):
        h = layers.apply_norm(cfg, p["norm1"], x)
        x = x + layers.attention_block(cfg, p["attn"], h, positions,
                                       window=_kind_window(cfg, kind))
        h = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.mlp_block(cfg, p["mlp"], h)
    elif kind == "moe":
        h = layers.apply_norm(cfg, p["norm1"], x)
        x = x + layers.attention_block(cfg, p["attn"], h, positions,
                                       window=cfg.sliding_window)
        h = layers.apply_norm(cfg, p["norm2"], x)
        y, aux = moe.moe_block(cfg, p["moe"], h)
        x = x + y
    elif kind == "ssd":
        h = layers.apply_norm(cfg, p["norm1"], x)
        x = x + ssm.ssd_block(cfg, p["ssd"], h)
    elif kind == "rglru":
        h = layers.apply_norm(cfg, p["norm1"], x)
        x = x + griffin.rglru_block(cfg, p["rglru"], h)
        h = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.mlp_block(cfg, p["mlp"], h)
    else:
        raise ValueError(kind)
    return x, aux


def kind_cache(cfg, kind: str, batch: int, cache_len: int) -> PyTree:
    """Zeroed decode cache for one layer of this kind."""
    if kind in ("attn", "local_attn", "moe"):
        w = _kind_window(cfg, kind)
        n = min(cache_len, w) if w > 0 else cache_len
        # kv-head-major: dh is the minor dim for both attention dots
        shape = (batch, cfg.n_kv_heads, n, cfg.dh)
        return {"k": jnp.zeros(shape, cfg.compute_dtype),
                "v": jnp.zeros(shape, cfg.compute_dtype)}
    if kind == "ssd":
        conv, state = ssm.init_states(cfg, batch)
        return {"conv": conv, "ssm": state}
    if kind == "rglru":
        conv, h = griffin.init_states(cfg, batch)
        return {"conv": conv, "h": h}
    raise ValueError(kind)


def block_decode(cfg, kind: str, p: PyTree, x: jax.Array, pos: jax.Array,
                 cache: PyTree) -> Tuple[jax.Array, PyTree]:
    """Single-token decode.  x: (B, 1, d); pos: (B,)."""
    if kind in ("attn", "local_attn", "moe"):
        w = _kind_window(cfg, kind)
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, kc, vc = layers.attention_decode(cfg, p["attn"], h, pos,
                                            cache["k"], cache["v"], window=w)
        x = x + y
        cache = {"k": kc, "v": vc}
        h = layers.apply_norm(cfg, p["norm2"], x)
        if kind == "moe":
            y, _ = moe.moe_block(cfg, p["moe"], h)
            x = x + y
        else:
            x = x + layers.mlp_block(cfg, p["mlp"], h)
    elif kind == "ssd":
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, conv, state = ssm.ssd_decode(cfg, p["ssd"], h, cache["conv"],
                                        cache["ssm"])
        x = x + y
        cache = {"conv": conv, "ssm": state}
    elif kind == "rglru":
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, conv, hs = griffin.rglru_decode(cfg, p["rglru"], h, cache["conv"],
                                           cache["h"])
        x = x + y
        cache = {"conv": conv, "h": hs}
        h = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.mlp_block(cfg, p["mlp"], h)
    else:
        raise ValueError(kind)
    return x, cache


def _kind_paged(cfg, kind: str) -> bool:
    """True when this layer kind's KV can live in a shared page pool.

    Only full-attention KV caches page: a sliding-window ring reuses
    physical slots for rolling positions (page identity would change
    under it), and SSM / RG-LRU states are O(1) per sequence — both
    stay slot-resident alongside the paged layers.
    """
    return kind in ("attn", "local_attn", "moe") and _kind_window(cfg, kind) == 0


def block_decode_paged(cfg, kind: str, p: PyTree, x: jax.Array,
                       pos: jax.Array, cache: PyTree, pages: PyTree,
                       tables: jax.Array):
    """Single-token decode with full-attention KV served from a page pool.

    ``pages`` is ``{"k", "v"}`` of ``(P, K, pt, dh)`` for paged kinds and
    ``None`` for kinds whose state stays slot-resident (then ``cache``
    is the real per-slot state and this defers to :func:`block_decode`).
    Returns (x, cache, pages).
    """
    if pages is None:
        x, cache = block_decode(cfg, kind, p, x, pos, cache)
        return x, cache, None
    h = layers.apply_norm(cfg, p["norm1"], x)
    y, kp, vp = layers.attention_decode_paged(cfg, p["attn"], h, pos,
                                              pages["k"], pages["v"], tables)
    x = x + y
    h = layers.apply_norm(cfg, p["norm2"], x)
    if kind == "moe":
        y, _ = moe.moe_block(cfg, p["moe"], h)
        x = x + y
    else:
        x = x + layers.mlp_block(cfg, p["mlp"], h)
    return x, cache, {"k": kp, "v": vp}


def block_chunk(cfg, kind: str, p: PyTree, x: jax.Array, cache_l: PyTree,
                off: int, cs: int) -> Tuple[jax.Array, PyTree]:
    """One full-attention block over a ``cs``-token segment starting at
    absolute position ``off``, attending to the cache prefix + itself.
    Shared by :meth:`LM.prefill_chunked` (static chunk sweep) and
    :meth:`LM.prefill_continue` (prefix-cache resume)."""
    positions = (off + jnp.arange(cs))[None, :]
    h = layers.apply_norm(cfg, p["norm1"], x)
    q, k, v = layers.qkv_project(cfg, p["attn"], h, positions)
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache_l["k"], jnp.swapaxes(k, 1, 2).astype(
            cache_l["k"].dtype), off, axis=2)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache_l["v"], jnp.swapaxes(v, 1, 2).astype(
            cache_l["v"].dtype), off, axis=2)
    k_ctx = jax.lax.slice_in_dim(kc, 0, off + cs, axis=2)
    v_ctx = jax.lax.slice_in_dim(vc, 0, off + cs, axis=2)
    from repro.kernels import ops
    o = ops.flash_attention_kvmajor(q, k_ctx, v_ctx, causal=True)
    x = x + layers.attn_out(cfg, p["attn"], o)
    h = layers.apply_norm(cfg, p["norm2"], x)
    if kind == "moe":
        y, _ = moe.moe_block(cfg, p["moe"], h)
        x = x + y
    else:
        x = x + layers.mlp_block(cfg, p["mlp"], h)
    return x, {"k": kc, "v": vc}


def block_prefill(cfg, kind: str, p: PyTree, x: jax.Array,
                  positions: jax.Array, cache: PyTree
                  ) -> Tuple[jax.Array, PyTree]:
    """Full-sequence forward that also fills this layer's decode cache."""
    if kind in ("attn", "local_attn", "moe"):
        w = _kind_window(cfg, kind)
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, k, v = layers.attention_block(cfg, p["attn"], h, positions,
                                         window=w, return_kv=True)
        x = x + y
        S = k.shape[1]
        W_c = cache["k"].shape[2]
        n = min(S, W_c)
        slots = (S - n + jnp.arange(n)) % W_c
        k_t = jnp.swapaxes(k[:, S - n:], 1, 2)       # one-time (B,K,n,dh)
        v_t = jnp.swapaxes(v[:, S - n:], 1, 2)
        cache = {"k": cache["k"].at[:, :, slots].set(
                     k_t.astype(cache["k"].dtype)),
                 "v": cache["v"].at[:, :, slots].set(
                     v_t.astype(cache["v"].dtype))}
        h = layers.apply_norm(cfg, p["norm2"], x)
        if kind == "moe":
            y, _ = moe.moe_block(cfg, p["moe"], h)
            x = x + y
        else:
            x = x + layers.mlp_block(cfg, p["mlp"], h)
    elif kind == "ssd":
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, (conv, state) = ssm.ssd_block(cfg, p["ssd"], h,
                                         return_state=True)
        x = x + y
        cache = {"conv": conv.astype(cache["conv"].dtype), "ssm": state}
    elif kind == "rglru":
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, (conv, hs) = griffin.rglru_block(cfg, p["rglru"], h,
                                            return_state=True)
        x = x + y
        cache = {"conv": conv.astype(cache["conv"].dtype), "h": hs}
        h = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.mlp_block(cfg, p["mlp"], h)
    else:
        raise ValueError(kind)
    return x, cache


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class LM:
    """One architecture = config + pure functions over a param pytree."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.pattern, self.n_units, self.tail_kinds = self._groups(cfg)
        self._abstract_units: Dict[str, PyTree] = {}

    @staticmethod
    def _groups(cfg) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
        kinds = cfg.layer_kinds()
        if cfg.family == Family.HYBRID:
            pat = tuple(cfg.block_pattern or ("rglru", "rglru", "attn"))
        else:
            pat = (kinds[0],)
        u = len(pat)
        n_units = len(kinds) // u
        tail = tuple(kinds[n_units * u:])
        return pat, n_units, tail

    # -- layer index helpers ------------------------------------------------
    def layer_kind(self, j: int) -> str:
        u = len(self.pattern)
        if j < self.n_units * u:
            return self.pattern[j % u]
        return self.tail_kinds[j - self.n_units * u]

    # ------------------------------------------------------------------ init
    def _embed_params(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        if cfg.family == Family.AUDIO:
            return {"proj": layers.dense_init(
                key, (cfg.frontend_dim, cfg.d_model), cfg.param_dtype,
                fan_in=cfg.frontend_dim)}
        if cfg.family == Family.VLM:
            k1, k2 = jax.random.split(key)
            return {"tok": layers.embed_init(
                        k1, (cfg.vocab_size, cfg.d_model), cfg.param_dtype),
                    "mm_proj": layers.dense_init(
                        k2, (cfg.frontend_dim, cfg.d_model), cfg.param_dtype,
                        fan_in=cfg.frontend_dim)}
        return layers.embed_params(cfg, key)

    def _final_params(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        p = {"norm": layers.norm_params(cfg, key)}
        if cfg.is_encoder:
            p["head"] = {"w": layers.dense_init(
                key, (cfg.d_model, cfg.vocab_size), cfg.param_dtype)}
        elif not cfg.tie_embeddings:
            p["head"] = {"w": layers.dense_init(
                key, (cfg.d_model, cfg.vocab_size), cfg.param_dtype)}
        return p

    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        u = len(self.pattern)
        keys = jax.random.split(key, cfg.n_layers + 2)
        blocks: Dict[str, PyTree] = {}
        for slot, kind in enumerate(self.pattern):
            per = [block_params(cfg, kind, keys[i * u + slot])
                   for i in range(self.n_units)]
            blocks[f"s{slot}"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *per)
        for t, kind in enumerate(self.tail_kinds):
            blocks[f"t{t}"] = block_params(cfg, kind,
                                           keys[self.n_units * u + t])
        return {"embed": self._embed_params(keys[-2]),
                "blocks": blocks,
                "final": self._final_params(keys[-1])}

    def abstract(self) -> PyTree:
        return jax.eval_shape(
            lambda: self.init(jax.random.key(0)))

    # --------------------------------------------------------------- embed
    def embed(self, params: PyTree, batch: Dict[str, jax.Array]
              ) -> jax.Array:
        cfg = self.cfg
        p = params["embed"]
        cd = cfg.compute_dtype
        if cfg.family == Family.AUDIO:
            x = quant.einsum("bsf,fd->bsd", batch["frames"].astype(cd),
                             p["proj"], cd)
        elif cfg.family == Family.VLM:
            img = quant.einsum("bnf,fd->bnd", batch["img"].astype(cd),
                               p["mm_proj"], cd)
            tok = quant.gather_rows(p["tok"], batch["tokens"], cd)
            x = jnp.concatenate([img, tok], axis=1)
        else:
            x = quant.gather_rows(p["tok"], batch["tokens"], cd)
        return constrain(x, "batch", "seq", "embed")

    def _head(self, params: PyTree, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = layers.apply_norm(cfg, params["final"]["norm"], x)
        cd = cfg.compute_dtype
        if cfg.tie_embeddings and not cfg.is_encoder:
            # tied head contracts the table's *scaled* axis — a per-vocab
            # scale cannot ride a (d, v) matmul, so dequant (QuantLeaf
            # .astype is the transparent fallback) and transpose.
            w = params["embed"]["tok"].astype(cd).T
            logits = jnp.einsum("bsd,dv->bsv", x, w)
        else:
            logits = quant.einsum("bsd,dv->bsv", x,
                                  params["final"]["head"]["w"], cd)
        logits = constrain(logits, "batch", "seq", "vocab")
        if cfg.logit_softcap > 0:
            c = cfg.logit_softcap
            logits = jnp.tanh(logits / c) * c
        return logits

    # -------------------------------------------------------------- forward
    def forward(self, params: PyTree, batch: Dict[str, jax.Array],
                *, remat: bool = False, unroll: bool = False
                ) -> Tuple[jax.Array, jax.Array]:
        """Full forward.  Returns (logits (B, S, V), aux_loss).

        unroll=True replaces the layer scan with a Python loop — used by
        the roofline dry-run (XLA's cost analysis visits a while body
        once, so scanned costs would undercount by the trip count).
        """
        cfg = self.cfg
        x = self.embed(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        pat = self.pattern

        def body(carry, slices):
            x, aux = carry
            for slot, kind in enumerate(pat):
                x, a = block_apply(cfg, kind, slices[slot], x, positions)
                aux = aux + a
            return (x, aux), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)

        xs = tuple(params["blocks"][f"s{i}"] for i in range(len(pat)))
        carry = (x, jnp.zeros((), jnp.float32))
        if unroll:
            for i in range(self.n_units):
                carry, _ = body(carry, jax.tree.map(lambda a: a[i], xs))
        else:
            carry, _ = jax.lax.scan(body, carry, xs)
        x, aux = carry
        for t, kind in enumerate(self.tail_kinds):
            x, a = block_apply(cfg, kind, params["blocks"][f"t{t}"], x,
                               positions)
            aux = aux + a
        return self._head(params, x), aux

    def loss(self, params: PyTree, batch: Dict[str, jax.Array],
             *, remat: bool = True, unroll: bool = False
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.forward(params, batch, remat=remat, unroll=unroll)
        labels = batch["labels"]
        V = logits.shape[-1]
        lg = logits.astype(jnp.float32)
        valid = labels >= 0
        lbl = jnp.where(valid, labels, 0)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lbl[..., None], axis=-1)[..., 0]
        nll = jnp.where(valid, logz - gold, 0.0)
        denom = jnp.maximum(jnp.sum(valid), 1)
        ce = jnp.sum(nll) / denom
        total = ce + AUX_LOSS_WEIGHT * aux
        return total, {"ce": ce, "aux": aux,
                       "accuracy": jnp.sum(
                           (jnp.argmax(lg, -1) == lbl) & valid) / denom}

    # ------------------------------------------------------- decode + cache
    def init_cache(self, batch: int, cache_len: int) -> PyTree:
        cfg = self.cfg
        caches: Dict[str, PyTree] = {}
        for slot, kind in enumerate(self.pattern):
            per = [kind_cache(cfg, kind, batch, cache_len)
                   for _ in range(self.n_units)]
            caches[f"s{slot}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        for t, kind in enumerate(self.tail_kinds):
            caches[f"t{t}"] = kind_cache(cfg, kind, batch, cache_len)
        return caches

    def abstract_cache(self, batch: int, cache_len: int) -> PyTree:
        return jax.eval_shape(lambda: self.init_cache(batch, cache_len))

    def prefill(self, params: PyTree, batch: Dict[str, jax.Array],
                cache: PyTree, *, unroll: bool = False
                ) -> Tuple[jax.Array, PyTree]:
        """Run the full prompt, fill the cache.  Returns (logits, cache)."""
        cfg = self.cfg
        x = self.embed(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        pat = self.pattern

        def body(x, inp):
            slices, csl = inp
            new_c = []
            for slot, kind in enumerate(pat):
                x, c2 = block_prefill(cfg, kind, slices[slot], x, positions,
                                      csl[slot])
                new_c.append(c2)
            return x, tuple(new_c)

        xs = tuple(params["blocks"][f"s{i}"] for i in range(len(pat)))
        cs = tuple(cache[f"s{i}"] for i in range(len(pat)))
        x, new_caches = self._scan_units(body, x, (xs, cs), unroll)
        out_cache = {f"s{i}": new_caches[i] for i in range(len(pat))}
        for t, kind in enumerate(self.tail_kinds):
            x, c2 = block_prefill(cfg, kind, params["blocks"][f"t{t}"], x,
                                  positions, cache[f"t{t}"])
            out_cache[f"t{t}"] = c2
        return self._head(params, x), out_cache

    def _scan_units(self, body, carry, xs, unroll: bool):
        """scan over the stacked pattern units, or a Python loop when
        unrolled (roofline lowering); ys are re-stacked to match."""
        if not unroll:
            return jax.lax.scan(body, carry, xs)
        ys = []
        for i in range(self.n_units):
            carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
        return carry, stacked

    def prefill_chunked(self, params: PyTree, batch: Dict[str, jax.Array],
                        cache: PyTree, *, chunk: int = 2048,
                        unroll: bool = False) -> Tuple[jax.Array, PyTree]:
        """Chunked prefill for full-attention decoder LMs (§Perf): the
        prompt is processed in ``chunk``-token segments, each attending
        to the cache prefix + itself.  Peak attention memory falls from
        O(S^2) to O(chunk * S) and MoE dispatch capacity scales with the
        chunk — the difference between a 480B MoE prefill fitting HBM
        or not.  Segment offsets are static (Python loop), so every
        cache read is a static slice.
        """
        cfg = self.cfg
        assert cfg.sliding_window == 0 and not cfg.is_encoder and \
            cfg.family not in (Family.SSM, Family.HYBRID), \
            "chunked prefill: full-attention decoder LMs only"
        tokens = batch["tokens"]
        if cfg.family == Family.VLM:
            x_all = self.embed(params, batch)
        else:
            x_all = self.embed(params, {"tokens": tokens})
        S = x_all.shape[1]
        chunk = min(chunk, S)
        assert S % chunk == 0, (S, chunk)
        pat = self.pattern

        xs = tuple(params["blocks"][f"s{i}"] for i in range(len(pat)))
        logits = None
        for ci in range(S // chunk):
            off = ci * chunk
            x = jax.lax.slice_in_dim(x_all, off, off + chunk, axis=1)

            def body(x, inp, _off=off):
                slices, csl = inp
                new_c = []
                for slot, kind in enumerate(pat):
                    x, c2 = block_chunk(cfg, kind, slices[slot], x,
                                        csl[slot], _off, chunk)
                    new_c.append(c2)
                return x, tuple(new_c)

            cs_in = tuple(cache[f"s{i}"] for i in range(len(pat)))
            x, new_caches = self._scan_units(body, x, (xs, cs_in), unroll)
            cache = dict(cache)
            for i in range(len(pat)):
                cache[f"s{i}"] = new_caches[i]
            for t, kind in enumerate(self.tail_kinds):
                x, c2 = block_chunk(cfg, kind, params["blocks"][f"t{t}"], x,
                                    cache[f"t{t}"], off, chunk)
                cache[f"t{t}"] = c2
            if ci == S // chunk - 1:
                logits = self._head(params, x)
        return logits, cache

    def prefill_continue(self, params: PyTree, batch: Dict[str, jax.Array],
                         cache: PyTree, *, off: int, unroll: bool = False
                         ) -> Tuple[jax.Array, PyTree]:
        """Resume a full-attention prefill at absolute position ``off``:
        cache rows ``[0, off)`` already hold valid K/V (gathered from
        shared prefix pages) and ``batch["tokens"]`` is the *suffix*.
        Returns (logits for the suffix, cache filled through
        ``off + suffix_len``).  This is the prefix-cache fast path —
        TTFT work is proportional to the unshared suffix only.
        """
        cfg = self.cfg
        assert cfg.sliding_window == 0 and not cfg.is_encoder and \
            cfg.family not in (Family.SSM, Family.HYBRID), \
            "prefill_continue: full-attention decoder LMs only"
        x = self.embed(params, batch)
        cs = x.shape[1]
        pat = self.pattern

        def body(x, inp):
            slices, csl = inp
            new_c = []
            for slot, kind in enumerate(pat):
                x, c2 = block_chunk(cfg, kind, slices[slot], x, csl[slot],
                                    off, cs)
                new_c.append(c2)
            return x, tuple(new_c)

        xs = tuple(params["blocks"][f"s{i}"] for i in range(len(pat)))
        cs_in = tuple(cache[f"s{i}"] for i in range(len(pat)))
        x, new_caches = self._scan_units(body, x, (xs, cs_in), unroll)
        out_cache = {f"s{i}": new_caches[i] for i in range(len(pat))}
        for t, kind in enumerate(self.tail_kinds):
            x, c2 = block_chunk(cfg, kind, params["blocks"][f"t{t}"], x,
                                cache[f"t{t}"], off, cs)
            out_cache[f"t{t}"] = c2
        return self._head(params, x), out_cache

    def decode_step(self, params: PyTree, cache: PyTree, tokens: jax.Array,
                    pos: jax.Array, *, unroll: bool = False
                    ) -> Tuple[jax.Array, PyTree]:
        """tokens: (B, 1); pos: (B,) absolute position of this token.
        Returns (logits (B, 1, V), cache)."""
        cfg = self.cfg
        if cfg.family == Family.VLM:
            batch = {"tokens": tokens,
                     "img": jnp.zeros((tokens.shape[0], 0, cfg.frontend_dim),
                                      cfg.compute_dtype)}
        else:
            batch = {"tokens": tokens}
        x = self.embed(params, batch)
        pat = self.pattern

        def body(x, inp):
            slices, csl = inp
            new_c = []
            for slot, kind in enumerate(pat):
                x, c2 = block_decode(cfg, kind, slices[slot], x, pos, csl[slot])
                new_c.append(c2)
            return x, tuple(new_c)

        xs = tuple(params["blocks"][f"s{i}"] for i in range(len(pat)))
        cs = tuple(cache[f"s{i}"] for i in range(len(pat)))
        x, new_caches = self._scan_units(body, x, (xs, cs), unroll)
        out_cache = {f"s{i}": new_caches[i] for i in range(len(pat))}
        for t, kind in enumerate(self.tail_kinds):
            x, c2 = block_decode(cfg, kind, params["blocks"][f"t{t}"], x,
                                 pos, cache[f"t{t}"])
            out_cache[f"t{t}"] = c2
        return self._head(params, x), out_cache

    # ----------------------------------------------------- paged KV decode
    def _cache_groups(self) -> List[Tuple[str, str, bool]]:
        """(key, kind, stacked) for every block cache group."""
        out = [(f"s{i}", k, True) for i, k in enumerate(self.pattern)]
        out += [(f"t{t}", k, False) for t, k in enumerate(self.tail_kinds)]
        return out

    def paged_kinds(self) -> List[str]:
        return [k for k in set(self.pattern) | set(self.tail_kinds)
                if _kind_paged(self.cfg, k)]

    @property
    def supports_prefix_cache(self) -> bool:
        """Prefix reuse needs every layer's sequence state to live in
        pages (an unshared SSM/ring state would silently diverge) and a
        token-only prompt identity (no image/audio side inputs)."""
        cfg = self.cfg
        return (not cfg.is_encoder
                and cfg.family not in (Family.AUDIO, Family.VLM, Family.VISION)
                and all(_kind_paged(cfg, k)
                        for k in set(self.pattern) | set(self.tail_kinds)))

    def kv_page_bytes(self, page_tokens: int) -> int:
        """Device bytes one page id costs across *all* paged layers
        (K and V).  0 when no layer pages (pure-SSM / ring models)."""
        cfg = self.cfg
        per = (2 * cfg.n_kv_heads * page_tokens * cfg.dh
               * jnp.dtype(cfg.compute_dtype).itemsize)
        n = sum(self.n_units if stacked else 1
                for _, kind, stacked in self._cache_groups()
                if _kind_paged(cfg, kind))
        return n * per

    def init_kv_pages(self, n_pages: int, page_tokens: int) -> PyTree:
        """Physical page pools: per paged group, ``{"k","v"}`` arrays of
        ``(n_units, n_pages, K, pt, dh)`` (stacked) / ``(n_pages, K, pt,
        dh)`` (tail).  Non-paged groups map to ``None``.  The caller
        sizes ``n_pages`` to budget + 1 (the trailing scratch page)."""
        cfg = self.cfg
        pools: Dict[str, PyTree] = {}
        for key, kind, stacked in self._cache_groups():
            if not _kind_paged(cfg, kind):
                pools[key] = None
                continue
            shape = (n_pages, cfg.n_kv_heads, page_tokens, cfg.dh)
            if stacked:
                shape = (self.n_units,) + shape
            pools[key] = {"k": jnp.zeros(shape, cfg.compute_dtype),
                          "v": jnp.zeros(shape, cfg.compute_dtype)}
        return pools

    def init_cache_paged(self, batch: int, cache_len: int) -> PyTree:
        """Slot-resident decode state with paged kinds' K/V leaves left
        as ``None`` (they live in the page pool): same tree structure as
        :meth:`init_cache`, so the per-slot join machinery applies."""
        cfg = self.cfg
        caches: Dict[str, PyTree] = {}
        for key, kind, stacked in self._cache_groups():
            if _kind_paged(cfg, kind):
                one: PyTree = {"k": None, "v": None}
            else:
                one = kind_cache(cfg, kind, batch, cache_len)
            if stacked:
                per = [one if _kind_paged(cfg, kind) else
                       kind_cache(cfg, kind, batch, cache_len)
                       for _ in range(self.n_units)]
                caches[key] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
            else:
                caches[key] = one
        return caches

    def init_request_cache(self, paged_len: int, state_len: int) -> PyTree:
        """B=1 prefill cache for paged admission: paged kinds sized to
        the request's page span (``paged_len`` rows feed
        :meth:`pack_pages`), slot-resident kinds sized to the
        scheduler's state length so the slot-join shapes match."""
        cfg = self.cfg
        caches: Dict[str, PyTree] = {}
        for key, kind, stacked in self._cache_groups():
            n = paged_len if _kind_paged(cfg, kind) else state_len
            if stacked:
                per = [kind_cache(cfg, kind, 1, n)
                       for _ in range(self.n_units)]
                caches[key] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
            else:
                caches[key] = kind_cache(cfg, kind, 1, n)
        return caches

    def strip_paged(self, cache: PyTree) -> PyTree:
        """Project a contiguous per-request cache onto the paged state
        structure: paged kinds' K/V leaves become ``None`` (their
        content transfers via :meth:`pack_pages` instead)."""
        out = dict(cache)
        for key, kind, _ in self._cache_groups():
            if _kind_paged(self.cfg, kind):
                out[key] = {"k": None, "v": None}
        return out

    def gather_pages(self, cache: PyTree, pools: PyTree,
                     ids: jax.Array) -> PyTree:
        """Copy physical pages ``ids`` (in logical order) into rows
        ``[0, len(ids) * pt)`` of a contiguous single-request cache —
        the device half of a prefix-cache hit."""
        m = ids.shape[0]
        out = dict(cache)
        for key, kind, stacked in self._cache_groups():
            if not _kind_paged(self.cfg, kind):
                continue
            dst = {}
            for n in ("k", "v"):
                pool, c = pools[key][n], cache[key][n]
                pt = pool.shape[-2]
                if stacked:
                    seg = jnp.swapaxes(pool[:, ids], 1, 2)     # (u,K,m,pt,dh)
                    seg = seg.reshape(pool.shape[0], 1, pool.shape[2],
                                      m * pt, pool.shape[4])
                    dst[n] = jax.lax.dynamic_update_slice(
                        c, seg.astype(c.dtype), (0, 0, 0, 0, 0))
                else:
                    seg = jnp.swapaxes(pool[ids], 0, 1)        # (K,m,pt,dh)
                    seg = seg.reshape(1, pool.shape[1], m * pt, pool.shape[3])
                    dst[n] = jax.lax.dynamic_update_slice(
                        c, seg.astype(c.dtype), (0, 0, 0, 0))
            out[key] = dst
        return out

    def pack_pages(self, pools: PyTree, cache: PyTree, ids: jax.Array,
                   first_page: int) -> PyTree:
        """Copy contiguous cache rows ``[first_page * pt, (first_page +
        len(ids)) * pt)`` out into physical pages ``ids`` — the device
        half of admission (prompt K/V moves from the per-request prefill
        cache into the shared pool)."""
        m = int(ids.shape[0])
        out = dict(pools)
        for key, kind, stacked in self._cache_groups():
            if not _kind_paged(self.cfg, kind):
                continue
            dst = {}
            for n in ("k", "v"):
                pool, c = pools[key][n], cache[key][n]
                pt = pool.shape[-2]
                lo = first_page * pt
                if stacked:
                    seg = jax.lax.slice_in_dim(c[:, 0], lo, lo + m * pt,
                                               axis=2)          # (u,K,m*pt,dh)
                    seg = seg.reshape(c.shape[0], c.shape[2], m, pt,
                                      c.shape[4])
                    seg = jnp.swapaxes(seg, 1, 2)               # (u,m,K,pt,dh)
                    dst[n] = pool.at[:, ids].set(seg.astype(pool.dtype))
                else:
                    seg = jax.lax.slice_in_dim(c[0], lo, lo + m * pt, axis=1)
                    seg = seg.reshape(c.shape[1], m, pt, c.shape[3])
                    seg = jnp.swapaxes(seg, 0, 1)               # (m,K,pt,dh)
                    dst[n] = pool.at[ids].set(seg.astype(pool.dtype))
            out[key] = dst
        return out

    def copy_page(self, pools: PyTree, src: int, dst: int) -> PyTree:
        """Device half of a copy-on-write fork: duplicate physical page
        ``src`` into ``dst`` across every paged layer."""
        out = dict(pools)
        for key, kind, stacked in self._cache_groups():
            if not _kind_paged(self.cfg, kind):
                continue
            if stacked:
                out[key] = {n: pools[key][n].at[:, dst].set(
                    pools[key][n][:, src]) for n in ("k", "v")}
            else:
                out[key] = {n: pools[key][n].at[dst].set(
                    pools[key][n][src]) for n in ("k", "v")}
        return out

    def decode_step_paged(self, params: PyTree, cache: PyTree,
                          pools: PyTree, tables: jax.Array,
                          tokens: jax.Array, pos: jax.Array,
                          *, unroll: bool = False):
        """Batched single-token decode over the shared page pool.

        cache: :meth:`init_cache_paged` state (non-paged layers only);
        pools: :meth:`init_kv_pages` arrays; tables: (B, NP) int32 page
        ids per batch row.  Returns (logits, cache, pools).
        """
        cfg = self.cfg
        if cfg.family == Family.VLM:
            batch = {"tokens": tokens,
                     "img": jnp.zeros((tokens.shape[0], 0, cfg.frontend_dim),
                                      cfg.compute_dtype)}
        else:
            batch = {"tokens": tokens}
        x = self.embed(params, batch)
        pat = self.pattern

        def body(x, inp):
            slices, csl, psl = inp
            new_c, new_p = [], []
            for slot, kind in enumerate(pat):
                x, c2, p2 = block_decode_paged(cfg, kind, slices[slot], x,
                                               pos, csl[slot], psl[slot],
                                               tables)
                new_c.append(c2)
                new_p.append(p2)
            return x, (tuple(new_c), tuple(new_p))

        xs = tuple(params["blocks"][f"s{i}"] for i in range(len(pat)))
        cs = tuple(cache[f"s{i}"] for i in range(len(pat)))
        ps = tuple(pools[f"s{i}"] for i in range(len(pat)))
        x, (new_c, new_p) = self._scan_units(body, x, (xs, cs, ps), unroll)
        out_cache = {f"s{i}": new_c[i] for i in range(len(pat))}
        out_pools = {f"s{i}": new_p[i] for i in range(len(pat))}
        for t, kind in enumerate(self.tail_kinds):
            x, c2, p2 = block_decode_paged(
                cfg, kind, params["blocks"][f"t{t}"], x, pos,
                cache[f"t{t}"], pools[f"t{t}"], tables)
            out_cache[f"t{t}"] = c2
            out_pools[f"t{t}"] = p2
        return self._head(params, x), out_cache, out_pools

    # ------------------------------------------------------- streaming view
    def unit_names(self) -> List[str]:
        return (["embed"]
                + [f"block_{j:03d}" for j in range(self.cfg.n_layers)]
                + ["final"])

    def init_unit(self, name: str, key: jax.Array) -> PyTree:
        """PISeL-faithful construction: full numerical initialization."""
        if name == "embed":
            return self._embed_params(key)
        if name == "final":
            return self._final_params(key)
        j = int(name.split("_")[1])
        return block_params(self.cfg, self.layer_kind(j), key)

    def abstract_unit(self, name: str) -> PyTree:
        """MiniLoader construction: shape/dtype structure only.

        Cached: unit structure is static per model spec, so the
        serverless platform precomputes it at deploy time (the
        eval_shape trace never sits on the cold-start critical path —
        only placeholder allocation does)."""
        if name not in self._abstract_units:
            self._abstract_units[name] = jax.eval_shape(
                lambda: self.init_unit(name, jax.random.key(0)))
        return self._abstract_units[name]

    def assemble(self, units: Dict[str, PyTree]) -> PyTree:
        u = len(self.pattern)
        blocks: Dict[str, PyTree] = {}
        for slot in range(u):
            per = [units[f"block_{i * u + slot:03d}"]
                   for i in range(self.n_units)]
            blocks[f"s{slot}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        for t in range(len(self.tail_kinds)):
            blocks[f"t{t}"] = units[f"block_{self.n_units * u + t:03d}"]
        return {"embed": units["embed"], "blocks": blocks,
                "final": units["final"]}

    def unit_apply(self, name: str, uparams: PyTree,
                   state: Dict[str, Any]) -> Dict[str, Any]:
        """Layer-wise cold-start execution (the pipeline's E_i).

        state: {"batch": inputs} before embed; {"x": activations} after.
        After the final unit, state["logits"] holds the output.
        """
        cfg = self.cfg
        if name == "embed":
            x = self.embed({"embed": uparams}, state["batch"])
            out = dict(state)
            out["x"] = x
            out["positions"] = jnp.arange(x.shape[1])[None, :]
            if cfg.tie_embeddings and not cfg.is_encoder:
                out["embed_tok"] = uparams["tok"]
            return out
        if name == "final":
            params = {"final": uparams}
            if cfg.tie_embeddings and not cfg.is_encoder:
                params["embed"] = {"tok": state["embed_tok"]}
            out = dict(state)
            out["logits"] = self._head(params, state["x"])
            return out
        j = int(name.split("_")[1])
        kind = self.layer_kind(j)
        x, _ = block_apply(cfg, kind, uparams, state["x"],
                           state["positions"])
        out = dict(state)
        out["x"] = x
        return out

    # ----------------------------------------------------------- input specs
    def input_specs(self, kind: str, seq: int, batch: int
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of a shape cell.

        kind: "train" | "prefill" | "decode".
        """
        cfg = self.cfg
        f32 = jnp.float32
        i32 = jnp.int32
        sd = jax.ShapeDtypeStruct
        if kind == "decode":
            specs = {"tokens": sd((batch, 1), i32),
                     "pos": sd((batch,), i32)}
            return specs
        if cfg.family == Family.AUDIO:
            specs = {"frames": sd((batch, seq, cfg.frontend_dim),
                                  cfg.compute_dtype)}
        elif cfg.family == Family.VLM:
            n_img = min(256, seq // 2)
            specs = {"tokens": sd((batch, seq - n_img), i32),
                     "img": sd((batch, n_img, cfg.frontend_dim),
                               cfg.compute_dtype)}
        else:
            specs = {"tokens": sd((batch, seq), i32)}
        if kind == "train":
            specs["labels"] = sd((batch, seq), i32)
        return specs


@functools.lru_cache(maxsize=None)
def _model_cache(cfg: ArchConfig) -> LM:
    return LM(cfg)


def build(cfg: ArchConfig) -> LM:
    """Build (cached) the model for a config."""
    if cfg.family == Family.VISION:
        from repro.models import vision
        return vision.build(cfg)
    return _model_cache(cfg)
