"""Model protocol and architecture config for the repro framework.

Every model in the zoo is a *pure pytree* model: parameters are plain nested
dicts of jnp arrays, and the forward pass is a pure function.  No flax/haiku.

Two views of every LM:

  * **stacked view** — every per-layer leaf is stored stacked along a leading
    ``(L, ...)`` axis and the forward pass is a ``jax.lax.scan`` over layers.
    This keeps HLO size ~constant in depth (essential for the 40-cell dry-run
    compile matrix) and is the steady-state serving/training representation.

  * **streaming view** — the cold-start pipeline (the paper's contribution)
    constructs / retrieves / applies weights *one layer at a time*.  The
    ``layer_names`` / ``init_layer`` / ``abstract_layer`` methods expose the
    per-layer granularity; ``assemble`` stacks the per-layer trees back into
    the stacked view once the model is fully live.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    AUDIO = "audio"      # encoder-only transformer backbone, stub frontend
    VLM = "vlm"          # decoder backbone, stub vision frontend
    VISION = "vision"    # paper's own eval family (ResNet/VGG/ViT)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Architecture hyper-parameters.

    One instance per assigned architecture (``src/repro/configs/<id>.py``)
    plus reduced variants for CPU smoke tests.
    """

    name: str
    family: Family

    # transformer backbone
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # attention flavour
    causal: bool = True               # False for encoder-only (hubert)
    sliding_window: int = 0           # 0 -> full attention
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"             # "rmsnorm" | "layernorm"
    act: str = "silu"                 # "silu" (SwiGLU) | "gelu" (plain MLP)
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # MoE
    n_experts: int = 0                # 0 -> dense FFN
    top_k: int = 0
    moe_d_ff: int = 0                 # expert hidden dim (d_ff used if 0)
    dense_residual: bool = False      # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25

    # SSM (mamba-2 SSD)
    ssm_state: int = 0                # N, state dim per head
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # hybrid (griffin / recurrentgemma): block pattern unit, e.g.
    # ("rglru", "rglru", "attn") repeated; remainder truncates the unit.
    block_pattern: Tuple[str, ...] = ()
    rglru_width: int = 0              # RG-LRU recurrence width (d_model if 0)
    local_attn_window: int = 0

    # modality frontend stubs
    frontend_dim: int = 0             # audio frame / vision patch embed dim

    # vision (paper's own eval family: ResNet / VGG / ViT)
    vision_variant: str = ""          # e.g. "resnet50", "vgg16", "vit_b_16"
    img_res: int = 224

    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    # --- derived -----------------------------------------------------------
    @property
    def dh(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def q_rep(self) -> int:
        """GQA repetition factor (query heads per KV head)."""
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def attention_free(self) -> bool:
        return self.family == Family.SSM

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode (500k) is feasible: the per-token
        state is O(window) or O(1), not O(seq)."""
        if self.family in (Family.SSM, Family.HYBRID):
            return True
        return self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                                    # token embedding
        if not self.tie_embeddings and not self.is_encoder:
            total += d * v                               # lm head
        if self.is_encoder:
            total += d * v                               # classifier head
        per_layer = self._per_layer_params()
        total += sum(per_layer)
        total += d                                       # final norm
        return total

    def _per_layer_params(self) -> List[int]:
        d = self.d_model
        dh = self.dh
        out: List[int] = []
        for kind in self.layer_kinds():
            p = 2 * d                                    # two norms
            if kind == "attn":
                p += d * self.n_heads * dh               # wq
                p += 2 * d * self.n_kv_heads * dh        # wk, wv
                p += self.n_heads * dh * d               # wo
                p += self._ffn_params()
            elif kind == "moe":
                p += d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
                     + self.n_heads * dh * d
                f = self.moe_d_ff or self.d_ff
                p += d * self.n_experts                  # router
                p += self.n_experts * 3 * d * f          # experts (SwiGLU)
                if self.dense_residual:
                    p += 3 * d * self.d_ff
            elif kind == "ssd":
                p += self._ssd_params()
            elif kind == "rglru":
                w = self.rglru_width or d
                p += 2 * d * w + w * d                   # gates + out
                p += 2 * w                               # lambda, gate bias
                p += self.conv_width * w                 # temporal conv
                p += self._ffn_params()
            elif kind == "local_attn":
                p += d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
                     + self.n_heads * dh * d
                p += self._ffn_params()
            out.append(p)
        return out

    def _ffn_params(self) -> int:
        if self.act in ("silu", "geglu"):
            return 3 * self.d_model * self.d_ff          # gated: 3 matrices
        return 2 * self.d_model * self.d_ff              # plain MLP

    def _ssd_params(self) -> int:
        d = self.d_model
        d_inner = self.ssm_expand * d
        nh = self.ssm_heads or (d_inner // max(self.ssm_head_dim, 1))
        n = self.ssm_state
        # ngroups = 1: B and C are shared across heads (mamba-2 default)
        p = d * (2 * d_inner + 2 * n + nh)               # in_proj (z,x,B,C,dt)
        p += self.conv_width * (d_inner + 2 * n)         # conv over x,B,C
        p += nh + nh                                     # A_log, D
        p += d_inner                                     # pre-out norm
        p += d_inner * d                                 # out_proj
        return p

    def layer_kinds(self) -> List[str]:
        """Per-layer block kind, length ``n_layers``."""
        if self.family == Family.SSM:
            return ["ssd"] * self.n_layers
        if self.family == Family.HYBRID:
            pat = self.block_pattern or ("rglru", "rglru", "attn")
            return [pat[i % len(pat)] for i in range(self.n_layers)]
        if self.family == Family.MOE:
            return ["moe"] * self.n_layers
        return ["attn"] * self.n_layers

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.family != Family.MOE:
            return self.param_count()
        d = self.d_model
        f = self.moe_d_ff or self.d_ff
        total = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * d * f
        return total - inactive


# ---------------------------------------------------------------------------
# model registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}
_SMOKE_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(name: str, full: Callable[[], ArchConfig],
             smoke: Callable[[], ArchConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE_REGISTRY[name] = smoke


def get_config(name: str, *, smoke: bool = False) -> ArchConfig:
    import repro.configs  # noqa: F401  (triggers registration)
    reg = _SMOKE_REGISTRY if smoke else _REGISTRY
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; have {sorted(reg)}")
    return reg[name]()


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
