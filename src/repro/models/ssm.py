"""Mamba-2 (SSD — state-space duality) block.

Follows the reference Mamba-2 layer: a single input projection produces
(z, x, B, C, dt); (x, B, C) pass through a causal depthwise conv + SiLU;
the SSD recurrence runs per head with scalar decay A; output goes
through a gated RMSNorm and the output projection.  ngroups = 1 (B and C
shared across heads), matching the 780m config.

Decode keeps two states per layer: the conv ring (B, W-1, d_conv) and
the SSD state (B, nh, dp, N) — O(1) in sequence length, which is what
makes the ``long_500k`` cell feasible for this family.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers

PyTree = Any


def dims(cfg) -> Tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim, state)"""
    d_inner = cfg.ssm_expand * cfg.d_model
    if cfg.ssm_heads:
        nh = cfg.ssm_heads
        dp = d_inner // nh
    else:
        dp = cfg.ssm_head_dim or 64
        nh = d_inner // dp
    return d_inner, nh, dp, cfg.ssm_state


def ssd_params(cfg, key: jax.Array) -> PyTree:
    d = cfg.d_model
    d_inner, nh, dp, N = dims(cfg)
    proj_out = 2 * d_inner + 2 * N + nh                  # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    return {
        "in_proj": layers.dense_init(ks[0], (d, proj_out), cfg.param_dtype),
        "conv": layers.conv_params(ks[1], cfg.conv_width, d_inner + 2 * N,
                                   cfg.param_dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(a_log)
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "ssd_norm": {"scale": jnp.zeros((d_inner,), cfg.param_dtype)},
        "out_proj": layers.dense_init(ks[2], (d_inner, d), cfg.param_dtype,
                                      fan_in=d_inner),
    }


def _split_proj(cfg, zxbcdt: jax.Array):
    d_inner, nh, dp, N = dims(cfg)
    z, xs, B, C, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    return z, xs, B, C, dt


def ssd_block(cfg, p: PyTree, x: jax.Array,
              conv_state: Optional[jax.Array] = None,
              ssm_state: Optional[jax.Array] = None,
              *, return_state: bool = False):
    """x: (B, S, d) -> y (B, S, d) [, (conv_state, ssm_state)]."""
    from repro.kernels import ops
    Bsz, S, d = x.shape
    d_inner, nh, dp, N = dims(cfg)
    cd = cfg.compute_dtype

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(cd))
    z, xs, Bm, Cm, dt = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, new_conv_state = layers.causal_conv1d(conv_in, p["conv"],
                                                    conv_state)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(cd)
    xs = conv_out[..., :d_inner]
    Bm = conv_out[..., d_inner:d_inner + N].astype(jnp.float32)
    Cm = conv_out[..., d_inner + N:].astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])   # (B, S, nh)
    A = -jnp.exp(p["a_log"])                              # (nh,)

    xh = xs.reshape(Bsz, S, nh, dp)
    xh = constrain(xh, "batch", "seq", "heads", None)
    x_t = jnp.transpose(xh, (0, 2, 1, 3))                 # (B, nh, S, dp)
    dt_t = jnp.transpose(dt, (0, 2, 1))                   # (B, nh, S)

    if return_state:
        # sequential reference path that also yields the final state
        y_t, hS = _ssd_with_state(x_t, dt_t, A, Bm, Cm, ssm_state)
    else:
        y_t = ops.ssd_scan(x_t, dt_t, A, Bm, Cm, bc=min(cfg.ssm_chunk, S))
        hS = None
    y = jnp.transpose(y_t, (0, 2, 1, 3))                  # (B, S, nh, dp)
    y = y + x_t.transpose(0, 2, 1, 3) * p["d_skip"][None, None, :, None]
    y = y.reshape(Bsz, S, d_inner).astype(cd)

    # gated RMSNorm (mamba-2: norm(y * silu(z)))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
    y = layers.rmsnorm(y, p["ssd_norm"]["scale"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(cd))
    out = constrain(out, "batch", "seq", "embed")
    if return_state:
        return out, (new_conv_state, hS)
    return out


def _ssd_with_state(x_t, dt_t, A, Bm, Cm, h0):
    from repro.kernels import ref
    b, nh, S, dp = x_t.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, nh, dp, N), jnp.float32)
    return ref.ssd(x_t, dt_t, A, Bm, Cm, h0=h0, return_state=True)


def ssd_decode(cfg, p: PyTree, x: jax.Array, conv_state: jax.Array,
               ssm_state: jax.Array):
    """Single-token step.  x: (B, 1, d); conv_state (B, W-1, ch);
    ssm_state (B, nh, dp, N).  Returns (y (B,1,d), conv_state, ssm_state)."""
    from repro.kernels import ops
    Bsz, _, d = x.shape
    d_inner, nh, dp, N = dims(cfg)
    cd = cfg.compute_dtype

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(cd))
    z, xs, Bm, Cm, dt = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)      # (B, 1, ch)
    conv_out, conv_state = layers.causal_conv1d(conv_in, p["conv"],
                                                conv_state)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(cd)
    xs = conv_out[..., :d_inner]
    Bm = conv_out[..., d_inner:d_inner + N].astype(jnp.float32)
    Cm = conv_out[..., d_inner + N:].astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])[:, 0]   # (B, nh)
    A = -jnp.exp(p["a_log"])

    xh = xs[:, 0].reshape(Bsz, nh, dp)
    ssm_state, y = ops.ssd_step(ssm_state, xh, dt, A, Bm[:, 0], Cm[:, 0])
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(Bsz, 1, d_inner).astype(cd)

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
    y = layers.rmsnorm(y, p["ssd_norm"]["scale"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(cd))
    return out, conv_state, ssm_state


def init_states(cfg, batch: int):
    """Zeroed decode states for one SSD layer."""
    d_inner, nh, dp, N = dims(cfg)
    conv = jnp.zeros((batch, cfg.conv_width - 1, d_inner + 2 * N),
                     cfg.compute_dtype)
    ssm = jnp.zeros((batch, nh, dp, N), jnp.float32)
    return conv, ssm
