"""The paper's own evaluation families — ResNet, VGG, ViT — as
streaming-unit models (same protocol as :class:`repro.models.transformer.LM`)
so the cold-start pipeline benchmarks (Figs 9-14) run against the exact
model families the paper measured.

Unit granularity follows the PyTorch top-level-module decomposition the
paper pipelines over (stem / stages / head for CNNs; patch-embed /
encoder blocks / head for ViT).  Inference only — the paper's pipeline
optimizes loading, and its workload is a single `1x3x224x224` tensor.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.api import ArchConfig

PyTree = Any

RESNET_BLOCKS = {"resnet50": (3, 4, 6, 3), "resnet101": (3, 4, 23, 3),
                 "resnet152": (3, 8, 36, 3)}
VGG_STAGES = {"vgg11": (1, 1, 2, 2, 2), "vgg13": (2, 2, 2, 2, 2),
              "vgg16": (2, 2, 3, 3, 3), "vgg19": (2, 2, 4, 4, 4)}
VGG_CH = (64, 128, 256, 512, 512)
VIT = {"vit_b_16": (12, 768, 12, 3072, 16),
       "vit_b_32": (12, 768, 12, 3072, 32),
       "vit_l_16": (24, 1024, 16, 4096, 16)}


# ---------------------------------------------------------------------------
# primitive layers
# ---------------------------------------------------------------------------

def conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    return layers.dense_init(key, (kh, kw, cin, cout), dtype,
                             fan_in=kh * kw * cin)


def conv2d(x, kernel, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def bn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32),
            "mean": jnp.zeros((c,), jnp.float32),
            "var": jnp.ones((c,), jnp.float32)}


def bn_apply(p, x, eps=1e-5):
    inv = jax.lax.rsqrt(p["var"] + eps)
    return (x - p["mean"]) * (inv * p["scale"]) + p["bias"]


def fc_init(key, cin, cout):
    return {"w": layers.dense_init(key, (cin, cout), jnp.float32,
                                   fan_in=cin),
            "b": jnp.zeros((cout,), jnp.float32)}


def fc_apply(p, x):
    return x @ p["w"] + p["b"]


def maxpool(x, window=3, stride=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "SAME")


# ---------------------------------------------------------------------------
# ResNet
# ---------------------------------------------------------------------------

def _bottleneck_init(key, cin, cmid, stride):
    ks = jax.random.split(key, 4)
    p = {"conv1": conv_init(ks[0], 1, 1, cin, cmid),
         "bn1": bn_init(cmid),
         "conv2": conv_init(ks[1], 3, 3, cmid, cmid),
         "bn2": bn_init(cmid),
         "conv3": conv_init(ks[2], 1, 1, cmid, cmid * 4),
         "bn3": bn_init(cmid * 4)}
    if stride != 1 or cin != cmid * 4:
        p["down"] = {"conv": conv_init(ks[3], 1, 1, cin, cmid * 4),
                     "bn": bn_init(cmid * 4)}
    return p


def _bottleneck_apply(p, x, stride):
    y = jax.nn.relu(bn_apply(p["bn1"], conv2d(x, p["conv1"])))
    y = jax.nn.relu(bn_apply(p["bn2"], conv2d(y, p["conv2"], stride)))
    y = bn_apply(p["bn3"], conv2d(y, p["conv3"]))
    if "down" in p:
        x = bn_apply(p["down"]["bn"], conv2d(x, p["down"]["conv"], stride))
    return jax.nn.relu(x + y)


def _resnet_units(cfg: ArchConfig):
    n_blocks = RESNET_BLOCKS[cfg.vision_variant]
    units: List[Tuple[str, Callable, Callable]] = []

    def stem_init(key):
        return {"conv": conv_init(key, 7, 7, 3, 64), "bn": bn_init(64)}

    def stem_apply(p, x):
        x = jax.nn.relu(bn_apply(p["bn"], conv2d(x, p["conv"], 2)))
        return maxpool(x)

    units.append(("stem", stem_init, stem_apply))

    cin = 64
    for si, nb in enumerate(n_blocks):
        cmid = 64 * (2 ** si)
        stride = 1 if si == 0 else 2
        cin_s = cin

        def mk_init(nb=nb, cin_s=cin_s, cmid=cmid, stride=stride):
            def f(key):
                ks = jax.random.split(key, nb)
                blocks = []
                ci = cin_s
                for b in range(nb):
                    blocks.append(_bottleneck_init(
                        ks[b], ci, cmid, stride if b == 0 else 1))
                    ci = cmid * 4
                return {"blocks": blocks}
            return f

        def mk_apply(nb=nb, stride=stride):
            def f(p, x):
                for b in range(nb):
                    x = _bottleneck_apply(p["blocks"][b], x,
                                          stride if b == 0 else 1)
                return x
            return f

        units.append((f"stage{si + 1}", mk_init(), mk_apply()))
        cin = cmid * 4

    def head_init(key):
        return {"fc": fc_init(key, cin, cfg.vocab_size)}

    def head_apply(p, x):
        return fc_apply(p["fc"], jnp.mean(x, axis=(1, 2)))

    units.append(("head", head_init, head_apply))
    return units


# ---------------------------------------------------------------------------
# VGG
# ---------------------------------------------------------------------------

def _vgg_units(cfg: ArchConfig):
    stages = VGG_STAGES[cfg.vision_variant]
    units: List[Tuple[str, Callable, Callable]] = []
    cin = 3
    for si, (nc, ch) in enumerate(zip(stages, VGG_CH)):
        cin_s = cin

        def mk_init(nc=nc, ch=ch, cin_s=cin_s):
            def f(key):
                ks = jax.random.split(key, nc)
                convs, ci = [], cin_s
                for c in range(nc):
                    convs.append(conv_init(ks[c], 3, 3, ci, ch))
                    ci = ch
                return {"convs": convs}
            return f

        def mk_apply(nc=nc):
            def f(p, x):
                for c in range(nc):
                    x = jax.nn.relu(conv2d(x, p["convs"][c]))
                return maxpool(x, 2, 2)
            return f

        units.append((f"stage{si + 1}", mk_init(), mk_apply()))
        cin = ch

    def head_init(key):
        ks = jax.random.split(key, 3)
        spatial = max(cfg.img_res // 32, 1)              # 5 maxpools of 2
        return {"fc1": fc_init(ks[0], 512 * spatial * spatial, 4096),
                "fc2": fc_init(ks[1], 4096, 4096),
                "fc3": fc_init(ks[2], 4096, cfg.vocab_size)}

    def head_apply(p, x):
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(fc_apply(p["fc1"], x))
        x = jax.nn.relu(fc_apply(p["fc2"], x))
        return fc_apply(p["fc3"], x)

    units.append(("head", head_init, head_apply))
    return units


# ---------------------------------------------------------------------------
# ViT
# ---------------------------------------------------------------------------

def _vit_units(cfg: ArchConfig):
    L, d, h, ff, patch = VIT[cfg.vision_variant]
    n_patch = (cfg.img_res // patch) ** 2
    units: List[Tuple[str, Callable, Callable]] = []

    def patch_init(key):
        k1, k2 = jax.random.split(key)
        return {"proj": conv_init(k1, patch, patch, 3, d),
                "pos": layers.embed_init(k2, (n_patch, d), jnp.float32)}

    def patch_apply(p, x):
        x = conv2d(x, p["proj"], stride=patch, padding="VALID")
        x = x.reshape(x.shape[0], -1, d)
        return x + p["pos"][None]

    units.append(("patch", patch_init, patch_apply))

    def blk_init(key):
        ks = jax.random.split(key, 6)
        dh = d // h
        return {
            "ln1": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
            "wq": layers.dense_init(ks[0], (d, h, dh), jnp.float32),
            "wk": layers.dense_init(ks[1], (d, h, dh), jnp.float32),
            "wv": layers.dense_init(ks[2], (d, h, dh), jnp.float32),
            "wo": layers.dense_init(ks[3], (h, dh, d), jnp.float32),
            "ln2": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
            "fc1": fc_init(ks[4], d, ff),
            "fc2": fc_init(ks[5], ff, d),
        }

    def blk_apply(p, x):
        y = layers.layernorm(x, p["ln1"]["scale"], p["ln1"]["bias"])
        q = jnp.einsum("bsd,dhk->bshk", y, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", y, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", y, p["wv"])
        s = jnp.einsum("bshk,bthk->bhst", q, k) / math.sqrt(q.shape[-1])
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhst,bthk->bshk", a, v)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        y = layers.layernorm(x, p["ln2"]["scale"], p["ln2"]["bias"])
        return x + fc_apply(p["fc2"], jax.nn.gelu(fc_apply(p["fc1"], y)))

    for j in range(L):
        units.append((f"block_{j:02d}", blk_init, blk_apply))

    def head_init(key):
        return {"ln": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
                "fc": fc_init(key, d, cfg.vocab_size)}

    def head_apply(p, x):
        x = layers.layernorm(x, p["ln"]["scale"], p["ln"]["bias"])
        return fc_apply(p["fc"], jnp.mean(x, axis=1))

    units.append(("head", head_init, head_apply))
    return units


# ---------------------------------------------------------------------------
# model wrapper (streaming protocol)
# ---------------------------------------------------------------------------

class VisionModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        v = cfg.vision_variant
        if v in RESNET_BLOCKS:
            self._units = _resnet_units(cfg)
        elif v in VGG_STAGES:
            self._units = _vgg_units(cfg)
        elif v in VIT:
            self._units = _vit_units(cfg)
        else:
            raise ValueError(v)
        self._by_name = {n: (i, a) for n, i, a in self._units}
        self._abstract_units = {}

    def unit_names(self) -> List[str]:
        return [n for n, _, _ in self._units]

    def init_unit(self, name: str, key: jax.Array) -> PyTree:
        return self._by_name[name][0](key)

    def abstract_unit(self, name: str) -> PyTree:
        if name not in self._abstract_units:          # static per spec
            self._abstract_units[name] = jax.eval_shape(
                lambda: self.init_unit(name, jax.random.key(0)))
        return self._abstract_units[name]

    def assemble(self, units: Dict[str, PyTree]) -> PyTree:
        return dict(units)

    def init(self, key: jax.Array) -> PyTree:
        names = self.unit_names()
        ks = jax.random.split(key, len(names))
        return {n: self.init_unit(n, k) for n, k in zip(names, ks)}

    def abstract(self) -> PyTree:
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    def unit_apply(self, name: str, uparams: PyTree,
                   state: Dict[str, Any]) -> Dict[str, Any]:
        apply = self._by_name[name][1]
        out = dict(state)
        if name == self._units[0][0]:
            x = state["batch"]["image"]
            x = jnp.transpose(x, (0, 2, 3, 1))        # NCHW -> NHWC
            out["x"] = apply(uparams, x)
        else:
            out["x"] = apply(uparams, state["x"])
        if name == self._units[-1][0]:
            out["logits"] = out["x"]
        return out

    def forward(self, params: PyTree, batch: Dict[str, jax.Array]):
        state: Dict[str, Any] = {"batch": batch}
        for name in self.unit_names():
            state = self.unit_apply(name, params[name], state)
        return state["logits"], jnp.zeros((), jnp.float32)

    def input_specs(self, kind: str, seq: int, batch: int):
        r = self.cfg.img_res
        return {"image": jax.ShapeDtypeStruct((batch, 3, r, r),
                                              jnp.float32)}


@functools.lru_cache(maxsize=None)
def build(cfg: ArchConfig) -> VisionModel:
    return VisionModel(cfg)
