"""The assigned input-shape cells and per-(arch x shape) applicability.

LM shapes are seq_len x global_batch.  ``decode_*`` / ``long_*`` lower
``serve_step`` (one new token against a seq_len KV cache), not
``train_step``.  Skips (recorded per cell in the roofline table):

  * ``long_500k`` needs sub-quadratic attention -> skipped for pure
    full-attention archs (O(S) ring caches / O(1) states run it);
  * encoder-only archs (hubert) have no autoregressive step -> decode
    shapes are skipped; ``prefill`` for an encoder is a plain forward.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.api import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def supported(cfg: ArchConfig, cell: ShapeCell) -> Tuple[bool, Optional[str]]:
    """(runnable, skip_reason)."""
    if cell.kind == "decode":
        if cfg.is_encoder:
            return False, "encoder-only: no autoregressive decode step"
        if cell.name == "long_500k" and not cfg.subquadratic:
            return False, ("full attention: 500k-token KV decode is "
                           "infeasible (O(S) cache per token)")
    return True, None


def smoke_cell(kind: str) -> ShapeCell:
    """Reduced cells for CPU smoke tests."""
    return {"train": ShapeCell("smoke_train", "train", 32, 2),
            "prefill": ShapeCell("smoke_prefill", "prefill", 32, 2),
            "decode": ShapeCell("smoke_decode", "decode", 64, 2)}[kind]


# ---------------------------------------------------------------------------
# kernel block sizes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelBlocks:
    """Tile sizes for every Pallas kernel — the single source the
    dispatch registry (:mod:`repro.kernels.ops`) derives its block
    shapes from, instead of per-call literals scattered through the
    callers.

    The TPU profile is MXU/VPU-aligned (multiples of 128 on the lane
    dim, tiles sized to keep the working set inside ~16 MB VMEM); the
    interpret profile shrinks every tile so the Python-level interpret
    loop stays tractable on CPU correctness runs.
    """
    flash_bq: int = 256          # flash attention query tile
    flash_bk: int = 256          # flash attention key/value tile
    flash_ref_bk: int = 1024     # jnp-fallback KV chunk (trace-time loop)
    decode_bs: int = 512         # decode attention cache-sequence tile
    ssd_bc: int = 128            # SSD chunk length
    rglru_bc: int = 256          # RG-LRU sequence chunk
    wt_bn: int = 256             # weight transform row tile
    wt_bm: int = 512             # weight transform column (lane) tile
    qm_bm: int = 256             # quant matmul activation-row tile
    qm_bk: int = 512             # quant matmul contraction tile
    qm_bn: int = 256             # quant matmul output-column (lane) tile


_KERNEL_BLOCKS = {
    # deployment target: real TPU lowering
    "tpu": KernelBlocks(),
    # interpret mode executes the kernel body per grid cell in Python —
    # big grids are fine (cheap cells), big *tiles* are fine (vectorized
    # cells); the defaults hold, minus the decode tile (whose split-K
    # scratch merge dominates interpret cost) and the quant-matmul tiles
    # (its K-accumulation loop is the same hazard)
    "interpret": KernelBlocks(decode_bs=128, qm_bm=128, qm_bk=256,
                              qm_bn=128),
}

# Autotune overlay: benchmarks/kernels_micro.py --autotune sweeps block
# candidates per (kernel shape x backend) and persists the winner into
# BENCH_kernels.json; ``load_autotuned`` re-applies it here so dispatch
# (and the capability probes, which lower at these shapes) pick up the
# measured tiles instead of the static defaults.
_TUNABLE = frozenset(f.name for f in dataclasses.fields(KernelBlocks))
_AUTOTUNED: dict = {}               # profile -> {field: value}


def set_autotuned(profile: str, overrides: dict) -> None:
    """Overlay measured block winners onto one profile's defaults."""
    if profile not in _KERNEL_BLOCKS:
        raise ValueError(f"unknown profile {profile!r} "
                         f"(one of {sorted(_KERNEL_BLOCKS)})")
    bad = set(overrides) - _TUNABLE
    if bad:
        raise ValueError(f"unknown KernelBlocks fields {sorted(bad)}")
    cur = dict(_AUTOTUNED.get(profile, {}))
    cur.update({k: int(v) for k, v in overrides.items()})
    _AUTOTUNED[profile] = cur


def clear_autotuned(profile: Optional[str] = None) -> None:
    if profile is None:
        _AUTOTUNED.clear()
    else:
        _AUTOTUNED.pop(profile, None)


def load_autotuned(artifact: dict, *, backend: str,
                   profile: str = "tpu") -> dict:
    """Apply the persisted winners from a BENCH_kernels.json object.

    Winners are keyed ``{kernel: {"backend": ..., "winner": {...}}}``
    under the artifact's ``autotune`` key; entries recorded on a
    different backend are skipped (a CPU sweep must not retune the TPU
    profile).  Returns the fields actually applied.
    """
    applied: dict = {}
    for kern, entry in (artifact.get("autotune") or {}).items():
        if entry.get("backend") != backend:
            continue
        winner = entry.get("winner") or {}
        picks = {k: v for k, v in winner.items() if k in _TUNABLE}
        if picks:
            set_autotuned(profile, picks)
            applied.update(picks)
    return applied


def kernel_blocks(profile: str = "tpu") -> KernelBlocks:
    """Block-size profile for a dispatch mode ('tpu' | 'interpret'),
    with any autotuned winners overlaid."""
    kb = _KERNEL_BLOCKS[profile]
    over = _AUTOTUNED.get(profile)
    return dataclasses.replace(kb, **over) if over else kb


def wt_shard_tiles(nbytes: int) -> Tuple[int, int]:
    """Weight-transform tile for a *per-shard* extent of ``nbytes`` —
    small shard slices (a unit split 4+ ways) shrink the row tile so
    the grid still has >= ~4 cells to parallelize over."""
    kb = kernel_blocks()
    if nbytes >= 4 << 20:
        return kb.wt_bn, kb.wt_bm
    if nbytes >= 256 << 10:
        return kb.wt_bn // 2, kb.wt_bm
    return max(8, kb.wt_bn // 8), kb.wt_bm
