"""The assigned input-shape cells and per-(arch x shape) applicability.

LM shapes are seq_len x global_batch.  ``decode_*`` / ``long_*`` lower
``serve_step`` (one new token against a seq_len KV cache), not
``train_step``.  Skips (recorded per cell in the roofline table):

  * ``long_500k`` needs sub-quadratic attention -> skipped for pure
    full-attention archs (O(S) ring caches / O(1) states run it);
  * encoder-only archs (hubert) have no autoregressive step -> decode
    shapes are skipped; ``prefill`` for an encoder is a plain forward.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.api import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def supported(cfg: ArchConfig, cell: ShapeCell) -> Tuple[bool, Optional[str]]:
    """(runnable, skip_reason)."""
    if cell.kind == "decode":
        if cfg.is_encoder:
            return False, "encoder-only: no autoregressive decode step"
        if cell.name == "long_500k" and not cfg.subquadratic:
            return False, ("full attention: 500k-token KV decode is "
                           "infeasible (O(S) cache per token)")
    return True, None


def smoke_cell(kind: str) -> ShapeCell:
    """Reduced cells for CPU smoke tests."""
    return {"train": ShapeCell("smoke_train", "train", 32, 2),
            "prefill": ShapeCell("smoke_prefill", "prefill", 32, 2),
            "decode": ShapeCell("smoke_decode", "decode", 64, 2)}[kind]
