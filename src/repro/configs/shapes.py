"""The assigned input-shape cells and per-(arch x shape) applicability.

LM shapes are seq_len x global_batch.  ``decode_*`` / ``long_*`` lower
``serve_step`` (one new token against a seq_len KV cache), not
``train_step``.  Skips (recorded per cell in the roofline table):

  * ``long_500k`` needs sub-quadratic attention -> skipped for pure
    full-attention archs (O(S) ring caches / O(1) states run it);
  * encoder-only archs (hubert) have no autoregressive step -> decode
    shapes are skipped; ``prefill`` for an encoder is a plain forward.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.api import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def supported(cfg: ArchConfig, cell: ShapeCell) -> Tuple[bool, Optional[str]]:
    """(runnable, skip_reason)."""
    if cell.kind == "decode":
        if cfg.is_encoder:
            return False, "encoder-only: no autoregressive decode step"
        if cell.name == "long_500k" and not cfg.subquadratic:
            return False, ("full attention: 500k-token KV decode is "
                           "infeasible (O(S) cache per token)")
    return True, None


def smoke_cell(kind: str) -> ShapeCell:
    """Reduced cells for CPU smoke tests."""
    return {"train": ShapeCell("smoke_train", "train", 32, 2),
            "prefill": ShapeCell("smoke_prefill", "prefill", 32, 2),
            "decode": ShapeCell("smoke_decode", "decode", 64, 2)}[kind]


# ---------------------------------------------------------------------------
# kernel block sizes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelBlocks:
    """Tile sizes for every Pallas kernel — the single source the
    dispatch registry (:mod:`repro.kernels.ops`) derives its block
    shapes from, instead of per-call literals scattered through the
    callers.

    The TPU profile is MXU/VPU-aligned (multiples of 128 on the lane
    dim, tiles sized to keep the working set inside ~16 MB VMEM); the
    interpret profile shrinks every tile so the Python-level interpret
    loop stays tractable on CPU correctness runs.
    """
    flash_bq: int = 256          # flash attention query tile
    flash_bk: int = 256          # flash attention key/value tile
    flash_ref_bk: int = 1024     # jnp-fallback KV chunk (trace-time loop)
    decode_bs: int = 512         # decode attention cache-sequence tile
    ssd_bc: int = 128            # SSD chunk length
    rglru_bc: int = 256          # RG-LRU sequence chunk
    wt_bn: int = 256             # weight transform row tile
    wt_bm: int = 512             # weight transform column (lane) tile


_KERNEL_BLOCKS = {
    # deployment target: real TPU lowering
    "tpu": KernelBlocks(),
    # interpret mode executes the kernel body per grid cell in Python —
    # big grids are fine (cheap cells), big *tiles* are fine (vectorized
    # cells); the defaults hold, minus the decode tile (whose split-K
    # scratch merge dominates interpret cost)
    "interpret": KernelBlocks(decode_bs=128),
}


def kernel_blocks(profile: str = "tpu") -> KernelBlocks:
    """Block-size profile for a dispatch mode ('tpu' | 'interpret')."""
    return _KERNEL_BLOCKS[profile]


def wt_shard_tiles(nbytes: int) -> Tuple[int, int]:
    """Weight-transform tile for a *per-shard* extent of ``nbytes`` —
    small shard slices (a unit split 4+ ways) shrink the row tile so
    the grid still has >= ~4 cells to parallelize over."""
    kb = kernel_blocks()
    if nbytes >= 4 << 20:
        return kb.wt_bn, kb.wt_bm
    if nbytes >= 256 << 10:
        return kb.wt_bn // 2, kb.wt_bm
    return max(8, kb.wt_bn // 8), kb.wt_bm
