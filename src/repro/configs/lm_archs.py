"""The 10 assigned architectures — exact published configs + reduced
smoke variants (same family, tiny dims) for CPU tests.

Sources are noted per config ([arXiv / hf] per the assignment).  Smoke
variants keep every structural feature (GQA ratio shape, SWA, MoE top-k,
dense residual, hybrid pattern incl. a tail remainder, tied embeddings)
so the smoke tests exercise the same code paths as the full configs.
"""
from __future__ import annotations

from repro.models.api import ArchConfig, Family, register


# ---------------------------------------------------------------------------
# dense llama-family
# ---------------------------------------------------------------------------

def yi_9b() -> ArchConfig:
    # [arXiv:2403.04652] llama-arch GQA
    return ArchConfig(
        name="yi-9b", family=Family.DENSE, n_layers=48, d_model=4096,
        n_heads=32, n_kv_heads=4, d_ff=11008, vocab_size=64000,
        rope_theta=5_000_000.0)


def yi_9b_smoke() -> ArchConfig:
    return ArchConfig(
        name="yi-9b-smoke", family=Family.DENSE, n_layers=3, d_model=64,
        n_heads=8, n_kv_heads=2, d_ff=160, vocab_size=512,
        rope_theta=5_000_000.0)


def codeqwen15_7b() -> ArchConfig:
    # [hf:Qwen/CodeQwen1.5-7B] qwen1.5-arch (MHA: kv == heads)
    return ArchConfig(
        name="codeqwen1.5-7b", family=Family.DENSE, n_layers=32,
        d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
        vocab_size=92416, rope_theta=1_000_000.0)


def codeqwen15_7b_smoke() -> ArchConfig:
    return ArchConfig(
        name="codeqwen1.5-7b-smoke", family=Family.DENSE, n_layers=3,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=192, vocab_size=512,
        rope_theta=1_000_000.0)


def h2o_danube3_4b() -> ArchConfig:
    # [arXiv:2401.16818] llama+mistral mix, sliding-window attention
    return ArchConfig(
        name="h2o-danube-3-4b", family=Family.DENSE, n_layers=24,
        d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
        vocab_size=32000, sliding_window=4096, rope_theta=10_000.0)


def h2o_danube3_4b_smoke() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b-smoke", family=Family.DENSE, n_layers=3,
        d_model=64, n_heads=8, n_kv_heads=2, d_ff=160, vocab_size=512,
        sliding_window=16)


def smollm_360m() -> ArchConfig:
    # [hf:HuggingFaceTB/SmolLM-360M] llama-arch small; 15 heads (dh=64)
    return ArchConfig(
        name="smollm-360m", family=Family.DENSE, n_layers=32, d_model=960,
        n_heads=15, n_kv_heads=5, d_ff=2560, vocab_size=49152)


def smollm_360m_smoke() -> ArchConfig:
    return ArchConfig(
        name="smollm-360m-smoke", family=Family.DENSE, n_layers=3,
        d_model=60, n_heads=3, n_kv_heads=1, d_ff=160, vocab_size=512,
        head_dim=20)


# ---------------------------------------------------------------------------
# audio encoder
# ---------------------------------------------------------------------------

def hubert_xlarge() -> ArchConfig:
    # [arXiv:2106.07447] encoder-only; conv frontend stubbed (512-dim frames)
    return ArchConfig(
        name="hubert-xlarge", family=Family.AUDIO, n_layers=48,
        d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120, vocab_size=504,
        causal=False, norm="layernorm", act="gelu", frontend_dim=512)


def hubert_xlarge_smoke() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge-smoke", family=Family.AUDIO, n_layers=3,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64,
        causal=False, norm="layernorm", act="gelu", frontend_dim=24)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def mixtral_8x7b() -> ArchConfig:
    # [arXiv:2401.04088] 8 experts top-2, SWA
    return ArchConfig(
        name="mixtral-8x7b", family=Family.MOE, n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=32000,
        sliding_window=4096, n_experts=8, top_k=2, rope_theta=1_000_000.0)


def mixtral_8x7b_smoke() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b-smoke", family=Family.MOE, n_layers=3,
        d_model=64, n_heads=8, n_kv_heads=2, d_ff=160, vocab_size=512,
        sliding_window=16, n_experts=4, top_k=2, capacity_factor=2.0)


def arctic_480b() -> ArchConfig:
    # [hf:Snowflake/snowflake-arctic-base] 128 experts top-2 + dense residual
    return ArchConfig(
        name="arctic-480b", family=Family.MOE, n_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000,
        n_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True)


def arctic_480b_smoke() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b-smoke", family=Family.MOE, n_layers=3,
        d_model=64, n_heads=8, n_kv_heads=2, d_ff=96, vocab_size=512,
        n_experts=8, top_k=2, moe_d_ff=96, dense_residual=True,
        capacity_factor=4.0)


# ---------------------------------------------------------------------------
# VLM
# ---------------------------------------------------------------------------

def internvl2_76b() -> ArchConfig:
    # [arXiv:2404.16821] InternViT frontend (stub: 3200-dim patch embeds)
    # + llama-3-70B-style backbone
    return ArchConfig(
        name="internvl2-76b", family=Family.VLM, n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=28672, vocab_size=128256,
        rope_theta=500_000.0, frontend_dim=3200)


def internvl2_76b_smoke() -> ArchConfig:
    return ArchConfig(
        name="internvl2-76b-smoke", family=Family.VLM, n_layers=3,
        d_model=64, n_heads=8, n_kv_heads=2, d_ff=160, vocab_size=512,
        frontend_dim=48)


# ---------------------------------------------------------------------------
# hybrid (Griffin)
# ---------------------------------------------------------------------------

def recurrentgemma_2b() -> ArchConfig:
    # [arXiv:2402.19427] RG-LRU + local attention, 1 attn : 2 recurrent
    return ArchConfig(
        name="recurrentgemma-2b", family=Family.HYBRID, n_layers=26,
        d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
        vocab_size=256000, act="geglu", tie_embeddings=True,
        block_pattern=("rglru", "rglru", "attn"), rglru_width=2560,
        local_attn_window=2048, logit_softcap=30.0)


def recurrentgemma_2b_smoke() -> ArchConfig:
    # 5 layers = 1 full pattern unit + 2-layer tail (exercises tail path)
    return ArchConfig(
        name="recurrentgemma-2b-smoke", family=Family.HYBRID, n_layers=5,
        d_model=64, n_heads=4, n_kv_heads=1, d_ff=128, vocab_size=512,
        act="geglu", tie_embeddings=True,
        block_pattern=("rglru", "rglru", "attn"), rglru_width=64,
        local_attn_window=16, logit_softcap=30.0)


# ---------------------------------------------------------------------------
# SSM (Mamba-2)
# ---------------------------------------------------------------------------

def mamba2_780m() -> ArchConfig:
    # [arXiv:2405.21060] SSD; d_inner=3072, headdim=64 -> 48 ssm heads
    return ArchConfig(
        name="mamba2-780m", family=Family.SSM, n_layers=48, d_model=1536,
        vocab_size=50280, tie_embeddings=True, ssm_state=128,
        ssm_head_dim=64, ssm_expand=2, ssm_chunk=256, conv_width=4)


def mamba2_780m_smoke() -> ArchConfig:
    return ArchConfig(
        name="mamba2-780m-smoke", family=Family.SSM, n_layers=3,
        d_model=64, vocab_size=512, tie_embeddings=True, ssm_state=16,
        ssm_head_dim=16, ssm_expand=2, ssm_chunk=16, conv_width=4)


ASSIGNED = {
    "yi-9b": (yi_9b, yi_9b_smoke),
    "codeqwen1.5-7b": (codeqwen15_7b, codeqwen15_7b_smoke),
    "h2o-danube-3-4b": (h2o_danube3_4b, h2o_danube3_4b_smoke),
    "smollm-360m": (smollm_360m, smollm_360m_smoke),
    "hubert-xlarge": (hubert_xlarge, hubert_xlarge_smoke),
    "mixtral-8x7b": (mixtral_8x7b, mixtral_8x7b_smoke),
    "arctic-480b": (arctic_480b, arctic_480b_smoke),
    "internvl2-76b": (internvl2_76b, internvl2_76b_smoke),
    "recurrentgemma-2b": (recurrentgemma_2b, recurrentgemma_2b_smoke),
    "mamba2-780m": (mamba2_780m, mamba2_780m_smoke),
}

for _name, (_full, _smoke) in ASSIGNED.items():
    register(_name, _full, _smoke)
