"""The paper's own evaluation families (Sec. IV-B): ResNet-50/101/152,
VGG-11/13/16/19, ViT-B-16 / B-32 / L-16 — used by the Fig. 9-14
benchmarks.  Smoke variants shrink to the smallest member of the family
(tests); full configs match torchvision parameter counts (ResNet-50
97.49 MB fp32 ... ViT-L-16 1.16 GB fp32, the paper's Fig. 3 range).
"""
from __future__ import annotations

from repro.models.api import ArchConfig, Family, register

PAPER_MODELS = [
    "resnet50", "resnet101", "resnet152",
    "vgg11", "vgg13", "vgg16", "vgg19",
    "vit_b_16", "vit_b_32", "vit_l_16",
]


def _mk(variant: str) -> ArchConfig:
    return ArchConfig(name=variant, family=Family.VISION,
                      vocab_size=1000, vision_variant=variant, img_res=224)


def _mk_smoke(variant: str) -> ArchConfig:
    # same family topology at 32x32 input; ImageNet classes -> 10
    return ArchConfig(name=f"{variant}-smoke", family=Family.VISION,
                      vocab_size=10, vision_variant=variant, img_res=32)


for _v in PAPER_MODELS:
    register(_v, lambda v=_v: _mk(v), lambda v=_v: _mk_smoke(v))
