"""Config registry: importing this package registers every architecture.

  * the 10 assigned LM-family archs (``lm_archs``) — full + smoke,
  * the paper's own ResNet/VGG/ViT families (``vision_archs``),
  * the shape cells (``shapes``).

``repro.models.api.get_config(name, smoke=...)`` is the lookup API.
"""
from repro.configs import lm_archs  # noqa: F401
from repro.configs import vision_archs  # noqa: F401
from repro.configs.lm_archs import ASSIGNED  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeCell, smoke_cell, supported  # noqa: F401
