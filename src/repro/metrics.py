"""Live metrics surface: thread-safe counter / gauge / histogram registry.

``run_trace`` replays a fixed trace and reports afterwards; a production
serverless platform is a *closed loop* — arrival-rate and queue-depth
signals drive pre-provisioning (λScale's fast scale-out), and every perf
claim is an SLO number measured on a live system.  This module is the
signal source: one :class:`MetricsRegistry` per platform (or the process
default), holding named instruments that the serving stack updates in
place —

  * **Counter** — monotone event counts (requests submitted / rejected /
    completed, cold starts, cache hits);
  * **Gauge** — point-in-time levels with a high-water mark (router
    queue depth, in-flight requests, decode-slot occupancy, cache
    bytes, pool instance states);
  * **Histogram** — fixed log-spaced buckets + exact count/sum/min/max,
    with interpolated quantiles (per-class latency, queue wait, TTFT,
    TPOT, cold-start load time, pipeline stage waits).

Every instrument takes its lock from :func:`repro.analysis.make_lock`,
so the CI lockgraph job sees the edges and the ``REPRO_ANALYZE=1`` probe
can prove the hot-path updates cycle- and hazard-free.  Instrument locks
are *leaf* locks: no instrument method acquires any other lock, so a
component may update metrics while holding its own CV without ever
creating a cross-lock cycle.

:meth:`MetricsRegistry.snapshot` renders everything as one
JSON-serializable dict — the scrapeable surface behind
``ServerlessPlatform.metrics_snapshot()``, ``serve.py --metrics-out``
and the :class:`~repro.serving.autoscale.Autoscaler`'s decisions.

Instrument naming convention (slash-scoped, lowercase):
``router/submitted``, ``router/latency_s/inference``,
``pool/<model>/cold_starts``, ``decode/occupancy``,
``weight_cache/hits``, ``coldstart/load_s``, ``pipeline/wait_A_s``.
"""
from __future__ import annotations

import json
import math
import time
from typing import Dict, Iterable, List, Optional, Tuple

from repro import analysis

# Default histogram bounds: log-spaced seconds from 1 ms to 60 s —
# covers a warm TTFT (~ms) through a bandwidth-starved cold start
# (~seconds) in the same instrument.  The terminal +inf bucket catches
# outliers so count bookkeeping is exact.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0, math.inf)


class Counter:
    """Monotone event counter."""

    def __init__(self, name: str):
        self.name = name
        self._lock = analysis.make_lock("metrics.Counter._lock")
        self._value = 0.0                       # guarded-by: _lock

    def inc(self, n: float = 1.0):
        if n < 0:
            raise ValueError(f"counter {self.name!r}: inc({n}) < 0")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def render(self) -> float:
        return self.value


class Gauge:
    """Point-in-time level + its high-water mark since creation."""

    def __init__(self, name: str):
        self.name = name
        self._lock = analysis.make_lock("metrics.Gauge._lock")
        self._value = 0.0                       # guarded-by: _lock
        self._max = 0.0                         # guarded-by: _lock

    def set(self, v: float):
        with self._lock:
            self._value = float(v)
            self._max = max(self._max, self._value)

    def add(self, d: float):
        with self._lock:
            self._value += d
            self._max = max(self._max, self._value)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    @property
    def max(self) -> float:
        with self._lock:
            return self._max

    def render(self) -> Dict[str, float]:
        with self._lock:
            return {"value": self._value, "max": self._max}


class Histogram:
    """Fixed-bucket histogram with exact count/sum/min/max and
    interpolated quantiles.

    Buckets are cumulative-upper-bound style (``le``); the last bound
    must be +inf so every observation lands somewhere.  Quantiles
    interpolate linearly within the containing bucket (clamped to the
    observed min/max, so a single observation reports itself exactly).
    """

    def __init__(self, name: str,
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        self.name = name
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds or bounds[-1] != math.inf:
            bounds = bounds + (math.inf,)
        self.bounds = bounds
        self._lock = analysis.make_lock("metrics.Histogram._lock")
        self._counts = [0] * len(bounds)        # guarded-by: _lock
        self._count = 0                         # guarded-by: _lock
        self._sum = 0.0                         # guarded-by: _lock
        self._min = math.inf                    # guarded-by: _lock
        self._max = -math.inf                   # guarded-by: _lock

    def observe(self, v: float):
        v = float(v)
        with self._lock:
            for i, b in enumerate(self.bounds):
                if v <= b:
                    self._counts[i] += 1
                    break
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        """Interpolated q-quantile (q in [0, 1]); NaN when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            return self._quantile_locked(q)

    def _quantile_locked(self, q: float) -> float:
        if self._count == 0:
            return math.nan
        rank = q * self._count
        cum = 0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            lo = self.bounds[i - 1] if i > 0 else self._min
            hi = self.bounds[i]
            lo = max(lo, self._min)
            hi = min(hi, self._max)
            if cum + c >= rank:
                frac = (rank - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return self._max

    def render(self) -> Dict[str, object]:
        with self._lock:
            empty = self._count == 0
            return {
                "count": self._count,
                "sum": self._sum,
                "min": None if empty else self._min,
                "max": None if empty else self._max,
                "p50": None if empty else self._quantile_locked(0.50),
                "p90": None if empty else self._quantile_locked(0.90),
                "p99": None if empty else self._quantile_locked(0.99),
                "buckets": [[b, c] for b, c in
                            zip(self.bounds, self._counts) if c],
            }


class MetricsRegistry:
    """Create-or-get registry of named instruments.

    Thread-safe: the registry lock guards only the name->instrument
    dict (instrument creation); per-instrument updates take the
    instrument's own leaf lock.  Asking for an existing name with a
    different instrument type raises — one name, one meaning.
    """

    def __init__(self):
        self._lock = analysis.make_lock("MetricsRegistry._lock")
        self._instruments: Dict[str, object] = {}   # guarded-by: _lock

    def _get(self, name: str, cls, *args):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, *args)
                self._instruments[name] = inst
        if not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} is a {type(inst).__name__}, "
                f"not a {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, Histogram, buckets)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> Dict[str, object]:
        """One JSON-serializable dict of every instrument — the
        scrapeable surface.  Values are read per-instrument (each under
        its own lock): the snapshot is per-instrument consistent, not a
        global atomic cut, which is the standard scrape contract."""
        with self._lock:
            items = sorted(self._instruments.items())
        out: Dict[str, object] = {"ts_monotonic": time.monotonic(),
                                  "counters": {}, "gauges": {},
                                  "histograms": {}}
        for name, inst in items:
            if isinstance(inst, Counter):
                out["counters"][name] = inst.render()
            elif isinstance(inst, Gauge):
                out["gauges"][name] = inst.render()
            else:
                out["histograms"][name] = inst.render()
        return out

    def to_json(self, path: str):
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2)


# ---------------------------------------------------------------------------
# process default — components constructed outside a platform record here
# ---------------------------------------------------------------------------

_default: Optional[MetricsRegistry] = None
_default_lock = analysis.make_lock("metrics._default_lock")


def default_registry() -> MetricsRegistry:
    """The process-wide default registry (created on first use).
    Components accept ``metrics=None`` and fall back here, so
    standalone engines / caches / schedulers still record; a
    ServerlessPlatform owns a private registry instead, keeping its
    snapshot isolated from other platforms in the same process."""
    global _default
    with _default_lock:
        if _default is None:
            _default = MetricsRegistry()
        return _default


def resolve(metrics: Optional[MetricsRegistry]) -> MetricsRegistry:
    """``metrics`` or the process default."""
    return metrics if metrics is not None else default_registry()
