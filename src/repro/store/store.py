"""Layer-sharded weight store.

The paper reads monolithic ``.pth`` files; a pod-scale system needs one
binary *extent per pipeline unit* so that (a) retrieval parallelism and
out-of-order application are possible and (b) multi-host loads can read
disjoint byte ranges.  Layout:

    <root>/<model>/manifest.json        # per-unit extent table
    <root>/<model>/<unit>.bin           # leaves concatenated, 64B-aligned

Each leaf records path, shape, dtype, offset, nbytes and crc32.
Optional int8 storage quantizes 2-D+ leaves per-output-channel (halves
or quarters the I/O bytes — the beyond-paper storage optimization);
dequantization happens in the *weight application* compute phase
(``kernels.ops.weight_transform``), exactly the decoupled stage the
paper assigns it to.

Reads are chunked and **cooperatively suspendable**: between chunks the
reader waits on a ``threading.Event`` — the Priority-Aware Scheduler
clears the event of non-critical streams to give a late critical layer
the full I/O bandwidth (Algorithm 1's "block W" primitive).

A :class:`BandwidthModel` optionally simulates a storage device (this
container's page cache would otherwise hide the I/O phase the paper
measures); the byte copies still physically happen.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import analysis

PyTree = Any
ALIGN = 64


# ---------------------------------------------------------------------------
# storage device model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BandwidthModel:
    """Simulated storage: per-request latency + a *shared* bandwidth cap.

    The default (None) is the raw container filesystem.  Benchmarks use
    e.g. ``BandwidthModel(bandwidth_mbps=400, latency_ms=0.2)`` — a
    cloud local-NVMe envelope calibrated so construction:I/O sits in the
    paper's Fig. 5 regime — because this container's page cache would
    otherwise hide the I/O phase entirely.

    Bandwidth is one token bucket *per channel*: all streams on one
    channel split it, they do not multiply it (otherwise the
    WeightDecoupler's parallel prefetch would get free bandwidth and
    the comparison against serial PISeL retrieval would be unfair).
    ``channels`` models independent storage links — the λScale /
    HydraServe regime where every mesh device (or host) brings its own
    NIC/DMA path, which is exactly what shard-granular retrieval
    exploits.  The default (1) is the seed's single shared device.
    """
    bandwidth_mbps: float = 0.0          # 0 -> unthrottled (per channel)
    latency_ms: float = 0.0
    channels: int = 1

    def __post_init__(self):
        self._lock = analysis.make_lock("BandwidthModel._lock")
        self._next_free = [0.0] * max(1, int(self.channels))  # guarded-by: _lock

    def on_open(self):
        if self.latency_ms > 0:
            time.sleep(self.latency_ms / 1e3)

    def on_chunk(self, nbytes: int, channel: int = 0):
        if self.bandwidth_mbps <= 0:
            return
        dur = nbytes / (self.bandwidth_mbps * 1e6)
        with self._lock:
            ch = channel % len(self._next_free)
            now = time.monotonic()
            start = max(now, self._next_free[ch])
            self._next_free[ch] = start + dur
        delay = (start + dur) - time.monotonic()
        if delay > 0:
            time.sleep(delay)

    def transfer(self, nbytes: int, *, channel: int = 0,
                 chunk_bytes: int = 1 << 20,
                 gate: Optional[threading.Event] = None,
                 on_chunk: Optional[Callable[[int], None]] = None):
        """Simulate moving ``nbytes`` over one channel of this link, in
        suspendable chunks — the intra-cluster peer-exchange path: no
        file underneath, just the wire cost of bytes already resident
        on another node.  ``gate``: the stream's Algorithm-1 suspension
        event (waited between chunks, like a store read); ``on_chunk``:
        progress callback with each chunk's size."""
        self.on_open()
        done = 0
        total = max(0, int(nbytes))
        while done < total:
            if gate is not None:
                gate.wait()
            n = min(int(chunk_bytes), total - done)
            self.on_chunk(n, channel)
            done += n
            if on_chunk is not None:
                on_chunk(n)


# ---------------------------------------------------------------------------
# tree <-> flat leaves
# ---------------------------------------------------------------------------

def leaf_path_name(path) -> str:
    """Canonical flat name of a tree_flatten_with_path key path — THE
    leaf identity used by the store layout, shard plans, cache keys and
    spec lookups.  Every consumer must share this one definition."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def flatten_unit(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    """Stable (path, leaf) list for a unit's param tree."""
    import jax
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(leaf_path_name(path), np.asarray(leaf))
            for path, leaf in flat]


def unflatten_unit(abstract: PyTree, leaves: Dict[str, np.ndarray]) -> PyTree:
    """Rebuild the unit tree from named leaves (against its abstract)."""
    import jax
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract)
    vals = []
    for path, ab in flat:
        name = leaf_path_name(path)
        v = leaves[name]
        assert tuple(v.shape) == tuple(ab.shape), (name, v.shape, ab.shape)
        vals.append(v)
    return jax.tree_util.tree_unflatten(treedef, vals)


def slice_byte_runs(shape: Tuple[int, ...], itemsize: int,
                    index: Tuple[Any, ...]) -> List[Tuple[int, int]]:
    """Contiguous (offset, nbytes) runs of ``arr[index]`` within the
    row-major payload of an array of ``shape`` — the byte-range plan a
    shard stream reads instead of the whole leaf.

    ``index`` is a per-dim sequence of slices (step 1), as produced by
    ``NamedSharding.devices_indices_map``; runs are maximal: all dims
    inner to the outermost partial dim are folded into one range.
    """
    if not shape:
        return [(0, itemsize)]
    norm = []
    for s, dim in zip(index, shape):
        start = 0 if s.start is None else int(s.start)
        stop = dim if s.stop is None else int(s.stop)
        norm.append((start, stop))
    # outermost-from-the-right dim whose slice is partial: runs span it
    # plus every (full) dim inside it
    k = 0
    for j in range(len(shape) - 1, -1, -1):
        if norm[j] != (0, shape[j]):
            k = j
            break
    inner = 1
    for d in shape[k + 1:]:
        inner *= d
    run_elems = (norm[k][1] - norm[k][0]) * inner
    if run_elems <= 0:
        return []
    strides = [0] * len(shape)           # element strides
    acc = 1
    for j in range(len(shape) - 1, -1, -1):
        strides[j] = acc
        acc *= shape[j]
    outer = [range(a, b) for (a, b) in norm[:k]]
    runs: List[Tuple[int, int]] = []
    import itertools
    for coords in itertools.product(*outer):
        off = sum(c * strides[j] for j, c in enumerate(coords))
        off += norm[k][0] * inner
        runs.append((off * itemsize, run_elems * itemsize))
    return runs


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

class WeightStore:
    def __init__(self, root: str, device: Optional[BandwidthModel] = None):
        self.root = root
        self.device = device or BandwidthModel()
        os.makedirs(root, exist_ok=True)
        self._manifests: Dict[str, dict] = {}

    # ---------------------------------------------------------------- paths
    def _dir(self, model: str) -> str:
        return os.path.join(self.root, model)

    def _unit_path(self, model: str, unit: str) -> str:
        return os.path.join(self._dir(model), f"{unit}.bin")

    # --------------------------------------------------------------- deploy
    def deploy(self, model_name: str, units: Dict[str, PyTree], *,
               quant: Optional[str] = None) -> dict:
        """Write per-unit extents + manifest.  ``units``: unit -> host tree.

        quant: None (store native dtype) | "int8" (2-D+ leaves quantized
        per output channel, scales stored f32 alongside).
        """
        d = self._dir(model_name)
        os.makedirs(d, exist_ok=True)
        manifest = {"model": model_name, "version": 1,
                    "quant": quant or "none", "units": {}}
        for unit, tree in units.items():
            entries = []
            blob = bytearray()
            for name, leaf in flatten_unit(tree):
                rec: Dict[str, Any] = {"path": name,
                                       "shape": list(leaf.shape),
                                       "dtype": str(leaf.dtype)}
                if quant == "int8" and leaf.ndim >= 2 and \
                        np.issubdtype(leaf.dtype, np.floating):
                    w2 = leaf.reshape(-1, leaf.shape[-1]).astype(np.float32)
                    amax = np.abs(w2).max(axis=0)
                    scale = np.where(amax > 0, amax / 127.0, 1.0
                                     ).astype(np.float32)
                    q = np.clip(np.round(w2 / scale), -127, 127
                                ).astype(np.int8)
                    payload = q.tobytes() + scale.tobytes()
                    rec["quant"] = "int8"
                    rec["scale_nbytes"] = scale.nbytes
                else:
                    payload = np.ascontiguousarray(leaf).tobytes()
                    rec["quant"] = "none"
                pad = (-len(blob)) % ALIGN
                blob.extend(b"\0" * pad)
                rec["offset"] = len(blob)
                rec["nbytes"] = len(payload)
                rec["crc32"] = zlib.crc32(payload) & 0xFFFFFFFF
                blob.extend(payload)
                entries.append(rec)
            with open(self._unit_path(model_name, unit), "wb") as f:
                f.write(bytes(blob))
            manifest["units"][unit] = {"extents": entries,
                                       "nbytes": len(blob)}
        with open(os.path.join(d, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        self._manifests[model_name] = manifest
        return manifest

    def manifest(self, model_name: str) -> dict:
        if model_name not in self._manifests:
            with open(os.path.join(self._dir(model_name),
                                   "manifest.json")) as f:
                self._manifests[model_name] = json.load(f)
        return self._manifests[model_name]

    def unit_nbytes(self, model_name: str, unit: str) -> int:
        return self.manifest(model_name)["units"][unit]["nbytes"]

    # ----------------------------------------------------------------- read
    def read_unit(self, model_name: str, unit: str, *,
                  chunk_bytes: int = 4 << 20,
                  gate: Optional[threading.Event] = None,
                  on_progress: Optional[Callable[[int, int], None]] = None,
                  channel: int = 0) -> bytes:
        """Chunked raw read of one unit extent file.

        gate: cooperative suspension point — the reader blocks between
        chunks while the event is cleared (Priority-Aware Scheduler's
        "block W" / resume).
        on_progress(bytes_done, bytes_total) per chunk.
        channel: simulated-device link this read draws bandwidth from.
        """
        path = self._unit_path(model_name, unit)
        total = os.path.getsize(path)
        analysis.note_io("read_unit")   # flags lock-held-across-I/O
        self.device.on_open()
        out = bytearray()
        with open(path, "rb") as f:
            while len(out) < total:
                if gate is not None:
                    gate.wait()
                buf = f.read(min(chunk_bytes, total - len(out)))
                if not buf:
                    break
                self.device.on_chunk(len(buf), channel)
                out.extend(buf)
                if on_progress is not None:
                    on_progress(len(out), total)
        return bytes(out)

    def _leaf_rec(self, model_name: str, unit: str, leaf: str) -> dict:
        for rec in self.manifest(model_name)["units"][unit]["extents"]:
            if rec["path"] == leaf:
                return rec
        raise KeyError(f"{model_name}/{unit}/{leaf}")

    def leaf_slice_nbytes(self, model_name: str, unit: str, leaf: str,
                          index: Optional[Tuple[Any, ...]]) -> int:
        """Bytes a shard stream will read for ``leaf[index]`` (whole
        payload when index is None — replicated leaves).  int8-quantized
        leaves charge their value slice (1 byte/elem) plus the scale
        slice of the columns the shard owns."""
        rec = self._leaf_rec(model_name, unit, leaf)
        if index is None:
            return rec["nbytes"]
        shape = tuple(rec["shape"])
        if rec.get("quant") == "int8":
            vals = sum(n for _, n in slice_byte_runs(shape, 1, index))
            lo = 0 if index[-1].start is None else int(index[-1].start)
            hi = shape[-1] if index[-1].stop is None else int(index[-1].stop)
            return vals + (hi - lo) * 4                  # f32 scales
        return sum(n for _, n in slice_byte_runs(
            shape, np.dtype(rec["dtype"]).itemsize, index))

    def read_leaf_slice(self, model_name: str, unit: str, leaf: str,
                        index: Optional[Tuple[Any, ...]], *,
                        fh=None, chunk_bytes: int = 4 << 20,
                        gate: Optional[threading.Event] = None,
                        on_chunk: Optional[Callable[[int], None]] = None,
                        channel: int = 0, materialize: bool = True,
                        out: Optional[np.ndarray] = None
                        ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Byte-range read of one leaf's shard: ``leaf[index]`` only —
        the unit of retrieval under shard-granular cold starts.

        index None reads the whole payload; otherwise only the
        contiguous runs covering the slice are read.  For an
        int8-quantized leaf a sliced read gathers the value bytes of
        ``leaf[index]`` (the payload's int8 region viewed at the leaf's
        *logical* shape) plus the f32 scale entries of the columns the
        slice covers — the per-shard inputs of the ``weight_transform``
        apply stage.  Returns ``(array, scale_or_None)`` like
        :meth:`deserialize` does per leaf.  Slice reads skip the
        whole-payload crc (a shard never materializes the full extent);
        whole reads still verify.

        ``fh``: optional already-open unit file (one ``on_open`` per
        shard stream instead of per leaf).

        ``materialize=False`` returns the slice as a page-cache-backed
        view (the stream still charges the slice's bytes to its
        simulated channel): the caller's placement lane then performs
        the single physical gather, instead of every concurrent read
        thread contending to copy.

        ``out``: destination array for the slice (e.g. a view into the
        caller's preassembled full leaf) — the read gathers straight
        into it, eliminating a staging copy.
        """
        rec = self._leaf_rec(model_name, unit, leaf)
        analysis.note_io("read_leaf_slice")   # lock-held-across-I/O probe
        close = False
        if fh is None:
            self.device.on_open()
            fh = open(self._unit_path(model_name, unit), "rb")
            close = True
        try:
            if index is None:
                payload = self._read_runs(
                    fh, [(rec["offset"], rec["nbytes"])], chunk_bytes,
                    gate, on_chunk, channel)
                crc = zlib.crc32(payload) & 0xFFFFFFFF
                if crc != rec["crc32"]:
                    raise IOError(f"crc mismatch for "
                                  f"{model_name}/{unit}/{leaf}")
                return self._decode_leaf(rec, payload)
            # Strided slice: a single C-level gather through a mapping
            # of the extent — a per-run Python read loop would cost more
            # in interpreter/GIL overhead than the byte ranges save
            # (shard streams run ~device-count-x concurrently).  Only
            # the slice's bytes are charged to the simulated device.
            shape = tuple(rec["shape"])
            quant = rec.get("quant") == "int8"
            dt = np.dtype(np.int8) if quant else np.dtype(rec["dtype"])
            sn = rec.get("scale_nbytes", 0) if quant else 0
            mm = np.memmap(fh, dtype=np.uint8, mode="r")
            view = mm[rec["offset"]:rec["offset"] + rec["nbytes"] - sn] \
                .view(dt).reshape(shape)
            arr = view[tuple(index)]
            scale = None
            if quant:             # f32 scales of the slice's columns
                lo = 0 if index[-1].start is None else int(index[-1].start)
                hi = shape[-1] if index[-1].stop is None \
                    else int(index[-1].stop)
                scale = np.array(
                    mm[rec["offset"] + rec["nbytes"] - sn:
                       rec["offset"] + rec["nbytes"]]
                    .view(np.float32)[lo:hi])
            if out is not None:
                np.copyto(out, arr)
                arr = out
            elif materialize:
                arr = np.ascontiguousarray(arr)
            del view, mm
            total = arr.nbytes + (scale.nbytes if scale is not None else 0)
            done = 0
            while done < total:               # simulated transfer cost
                if gate is not None:
                    gate.wait()
                n = min(chunk_bytes, total - done)
                self.device.on_chunk(n, channel)
                done += n
                if on_chunk is not None:
                    on_chunk(n)
            return arr, scale
        finally:
            if close:
                fh.close()

    def open_unit(self, model_name: str, unit: str):
        """Open a unit extent for a sequence of read_leaf_slice calls
        (one simulated-device ``on_open`` for the whole shard stream)."""
        self.device.on_open()
        return open(self._unit_path(model_name, unit), "rb")

    def _read_runs(self, fh, runs, chunk_bytes, gate, on_chunk,
                   channel) -> bytes:
        # simulated cost + progress are charged per ~chunk_bytes of
        # accumulated payload, not per run: strided shard slices can be
        # thousands of small runs, and a token-bucket sleep (~50us OS
        # floor) per run would swamp the modeled transfer time
        out = bytearray()
        pending = 0

        def flush():
            nonlocal pending
            if pending:
                self.device.on_chunk(pending, channel)
                if on_chunk is not None:
                    on_chunk(pending)
                pending = 0

        for off, nbytes in runs:
            fh.seek(off)
            done = 0
            while done < nbytes:
                if gate is not None:
                    gate.wait()
                buf = fh.read(min(chunk_bytes, nbytes - done))
                if not buf:
                    raise IOError("short read")
                done += len(buf)
                out.extend(buf)
                pending += len(buf)
                if pending >= chunk_bytes:
                    flush()
        flush()
        return bytes(out)

    @staticmethod
    def _decode_leaf(rec: dict, payload: bytes
                     ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        shape = tuple(rec["shape"])
        if rec.get("quant") == "int8":
            sn = rec["scale_nbytes"]
            q = np.frombuffer(payload[:-sn], np.int8)
            scale = np.frombuffer(payload[-sn:], np.float32)
            return q.reshape(-1, shape[-1]), scale
        return np.frombuffer(payload, rec["dtype"]).reshape(shape), None

    # ---------------------------------------------------------- deserialize
    def deserialize(self, model_name: str, unit: str, raw: bytes,
                    *, verify: bool = True
                    ) -> Dict[str, Tuple[np.ndarray, Optional[np.ndarray]]]:
        """raw extent bytes -> {leaf_path: (array, scale_or_None)}.

        int8-quantized leaves come back as (int8 2-D array, f32 scales);
        the caller runs the weight-transform (dequant) compute phase.
        """
        man = self.manifest(model_name)["units"][unit]
        out: Dict[str, Tuple[np.ndarray, Optional[np.ndarray]]] = {}
        for rec in man["extents"]:
            payload = raw[rec["offset"]:rec["offset"] + rec["nbytes"]]
            if verify:
                crc = zlib.crc32(payload) & 0xFFFFFFFF
                if crc != rec["crc32"]:
                    raise IOError(
                        f"crc mismatch for {model_name}/{unit}/{rec['path']}")
            out[rec["path"]] = self._decode_leaf(rec, payload)
        return out

    def read_and_deserialize(self, model_name: str, unit: str, **kw
                             ) -> Dict[str, Tuple[np.ndarray,
                                                  Optional[np.ndarray]]]:
        return self.deserialize(model_name, unit,
                                self.read_unit(model_name, unit, **kw))

    # -------------------------------------------------------------- helpers
    def has_model(self, model_name: str) -> bool:
        return os.path.exists(os.path.join(self._dir(model_name),
                                           "manifest.json"))

    def model_nbytes(self, model_name: str) -> int:
        return sum(u["nbytes"]
                   for u in self.manifest(model_name)["units"].values())


def deploy_model(store: WeightStore, model, model_name: str,
                 key=None, *, quant: Optional[str] = None,
                 params_by_unit: Optional[Dict[str, PyTree]] = None) -> dict:
    """Deploy a model (streaming protocol) with freshly-initialized or
    provided per-unit parameters — the serverless platform's "publish
    model artifact" step."""
    import jax
    names = model.unit_names()
    if params_by_unit is None:
        if key is None:
            key = jax.random.key(0)
        keys = jax.random.split(key, len(names))
        params_by_unit = {n: model.init_unit(n, k)
                          for n, k in zip(names, keys)}
    return store.deploy(model_name, params_by_unit, quant=quant)
