"""Node-local shared WeightCache: deserialized unit leaves, reused
across cold starts.

Under scale-out (PR 1's ``InstancePool``) every instance of the same
model re-read its full weight extents from the store — the dominant
cold-start cost paid N times on one node.  Fast serverless scaling
hinges on reusing already-resident weights across instances (λScale,
HydraServe); this cache is that reuse point:

  * **keyed by (model, unit, shard)** — the store's retrieval
    granularity: under shard-granular cold starts every mesh device's
    stream caches independently, so a scale-out cold start onto the
    same mesh is zero-read *per shard* and a partially-loaded model
    already serves hits to a concurrent load (the seed's unit-granular
    path is the degenerate ``shard=0`` case);
  * **single-flight** — the first loader of a unit reads from the
    store, every concurrent loader blocks on the shared condition
    variable and receives the leader's leaves: one physical read per
    unit, node-wide, no matter how many instances cold-start at once;
  * **byte-budgeted, priority-aware eviction** — LRU over unpinned
    entries, and units of models with a load currently in flight are
    spared outright (coordinated with the cold-start pipeline: the
    WeightDecoupler registers its load and pins each unit from
    retrieval until weight application, so a unit needed by an
    in-flight — possibly Algorithm-1-critical — load is never evicted
    under pressure; the budget is re-enforced when loads retire);
  * **refcounted pins** — ``begin``/``complete`` take a reference,
    ``release`` drops it; pinned entries are never evicted (the budget
    may transiently overshoot while pins are held — pins are the
    short retrieval→application window of a load).

Entries hold the *deserialized* leaf dict exactly as
``WeightStore.deserialize`` returns it (quantized leaves stay
quantized: dequantization remains the per-load weight-application
compute phase, so a cache hit skips I/O + deserialize + crc, not the
paper's decoupled compute stage).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import (Any, Callable, Dict, Hashable, List, Optional, Tuple)

from repro import analysis, metrics as metrics_mod

# begin() outcomes
HIT = "hit"      # leaves returned, reference taken
LOAD = "load"    # caller is the leader: read the store, then complete()/abort()


@dataclasses.dataclass
class CacheStats:
    """Point-in-time + cumulative counters (thread-safe snapshot)."""
    budget_bytes: Optional[int]
    bytes_cached: int = 0
    entries: int = 0
    pinned: int = 0
    hits: int = 0            # begin() served from cache (incl. after a wait)
    misses: int = 0          # begin() promoted the caller to leader
    waits: int = 0           # hits that waited on another loader (deduped I/O)
    inserts: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class _Entry:
    __slots__ = ("leaves", "nbytes", "refs", "loading")

    def __init__(self):
        self.leaves: Any = None
        self.nbytes = 0
        self.refs = 0
        self.loading = True


class WeightCache:
    """Thread-safe node-level cache of deserialized unit leaves.

    ``budget_bytes=None`` (or ``0``) means unbounded; a positive
    integer bounds the bytes of *unpinned* residency (pinned entries
    and in-flight models may transiently overshoot).
    """

    def __init__(self, budget_bytes: Optional[int] = None, *,
                 metrics: Optional[metrics_mod.MetricsRegistry] = None,
                 on_evict: Optional[
                     Callable[[Tuple[str, str, Hashable]], None]] = None):
        """``on_evict``: called with each evicted ``(model, unit,
        shard)`` key, *outside* the cache lock (so the callback may
        take other locks — e.g. a cluster placement table — without
        creating a WeightCache._cv -> X lock-order edge).  The entry is
        already gone when the callback runs; a concurrent ``begin`` of
        the same key re-loads it, so consumers must treat the signal as
        "may be stale", not "is absent forever"."""
        if budget_bytes is not None and budget_bytes < 0:
            raise ValueError("budget_bytes must be >= 0 or None")
        # 0 -> unbounded, matching the platform's cache_budget_bytes
        # knob (a literal zero-byte cache would evict every entry on
        # insert — never what a caller wants from "enable the cache")
        self.budget_bytes = budget_bytes or None
        self.on_evict = on_evict
        self._cv = analysis.make_condition("WeightCache._cv")
        self._entries: "OrderedDict[Tuple[str, str, Hashable], _Entry]" \
            = OrderedDict()                      # guarded-by: _cv
        self._bytes = 0                          # guarded-by: _cv
        self._inflight: Dict[str, int] = {}      # guarded-by: _cv
        self._hits = 0                           # guarded-by: _cv
        self._misses = 0                         # guarded-by: _cv
        self._waits = 0                          # guarded-by: _cv
        self._inserts = 0                        # guarded-by: _cv
        self._evictions = 0                      # guarded-by: _cv
        m = metrics_mod.resolve(metrics)
        # leaf-lock instruments: safe to inc while holding _cv
        self._m_hits = m.counter("weight_cache/hits")
        self._m_misses = m.counter("weight_cache/misses")
        self._m_waits = m.counter("weight_cache/waits")
        self._m_evictions = m.counter("weight_cache/evictions")
        self._m_bytes = m.gauge("weight_cache/bytes")

    # --------------------------------------------------------- load protocol
    def begin(self, model: str, unit: str, shard: Hashable = 0
              ) -> Tuple[str, Any]:
        """Enter the single-flight protocol for one (unit, shard).

        Returns ``(HIT, leaves)`` — a reference is taken; call
        :meth:`release` after the weight-application phase — or
        ``(LOAD, None)`` — the caller is the leader and must read the
        store, then call :meth:`complete` (which also takes the
        leader's reference) or :meth:`abort` on failure.  Concurrent
        callers of a loading unit block here and are served the
        leader's result (or promoted to leader if it aborts).
        """
        key = (model, unit, shard)
        waited = False
        with self._cv:
            while True:
                e = self._entries.get(key)
                if e is None:
                    e = _Entry()
                    self._entries[key] = e
                    self._misses += 1
                    self._m_misses.inc()
                    return LOAD, None
                if e.loading:
                    waited = True
                    self._cv.wait()
                    continue
                e.refs += 1
                self._entries.move_to_end(key)
                self._hits += 1
                self._m_hits.inc()
                if waited:
                    self._waits += 1
                    self._m_waits.inc()
                return HIT, e.leaves

    def try_get(self, model: str, unit: str, shard: Hashable = 0
                ) -> Optional[Any]:
        """Non-blocking peek: the entry's leaves with a reference taken
        (pair with :meth:`release`), or None when absent *or* loading.
        Unlike :meth:`begin` this never promotes the caller to leader
        and never waits — it is the peer-serving read (a remote node
        asking "do you hold this shard right now?"): a miss must fall
        back to its own source, not start a load on *this* cache."""
        with self._cv:
            e = self._entries.get((model, unit, shard))
            if e is None or e.loading:
                return None
            e.refs += 1
            self._entries.move_to_end((model, unit, shard))
            return e.leaves

    def complete(self, model: str, unit: str, leaves: Any, nbytes: int,
                 shard: Hashable = 0):
        """Publish the leader's read; wakes all waiters.  The leader
        keeps one reference (release after application)."""
        key = (model, unit, shard)
        with self._cv:
            e = self._entries.get(key)
            if e is None or not e.loading:
                raise RuntimeError(f"complete() without begin(): {key}")
            e.leaves = leaves
            e.nbytes = int(nbytes)
            e.refs = 1
            e.loading = False
            self._bytes += e.nbytes
            self._inserts += 1
            self._entries.move_to_end(key)
            evicted = self._evict_locked()
            self._m_bytes.set(self._bytes)
            self._cv.notify_all()
        self._notify_evicted(evicted)

    def abort(self, model: str, unit: str, shard: Hashable = 0):
        """Leader failed: drop the placeholder so a waiter retries as
        the new leader."""
        with self._cv:
            e = self._entries.get((model, unit, shard))
            if e is not None and e.loading:
                del self._entries[(model, unit, shard)]
            self._cv.notify_all()

    def release(self, model: str, unit: str, shard: Hashable = 0):
        """Drop one reference taken by begin()/complete()/try_get()."""
        with self._cv:
            e = self._entries.get((model, unit, shard))
            if e is None or e.loading:
                return
            e.refs = max(0, e.refs - 1)
            evicted = self._evict_locked()
        self._notify_evicted(evicted)

    # --------------------------------------------- in-flight load registry
    def register_load(self, model: str):
        """A cold-start load of ``model`` is in flight: its cached
        units are spared by eviction until idle models' units are gone."""
        with self._cv:
            self._inflight[model] = self._inflight.get(model, 0) + 1

    def unregister_load(self, model: str):
        with self._cv:
            n = self._inflight.get(model, 0) - 1
            if n > 0:
                self._inflight[model] = n
            else:
                self._inflight.pop(model, None)
            evicted = self._evict_locked()
        self._notify_evicted(evicted)

    # -------------------------------------------------------------- eviction
    def _evict_locked(self) -> List[Tuple[str, str, Hashable]]:
        """LRU over evictable entries; returns the evicted keys (the
        caller fires ``on_evict`` after dropping the lock).  Never
        touched: loading slots, pinned entries (refs > 0), and units of
        models with a registered in-flight load — the budget may
        transiently overshoot while pins/loads are held; it is
        re-enforced on release()/unregister_load()."""
        evicted: List[Tuple[str, str, Hashable]] = []
        if self.budget_bytes is None:
            return evicted
        for key in list(self._entries):
            if self._bytes <= self.budget_bytes:
                return evicted
            e = self._entries[key]
            if e.loading or e.refs > 0 or key[0] in self._inflight:
                continue
            del self._entries[key]
            self._bytes -= e.nbytes
            self._evictions += 1
            self._m_evictions.inc()
            self._m_bytes.set(self._bytes)
            evicted.append(key)
        return evicted

    def _notify_evicted(self, keys: List[Tuple[str, str, Hashable]]):
        if self.on_evict is None:
            return
        for key in keys:
            self.on_evict(key)

    # --------------------------------------------------------------- queries
    def __contains__(self, key: Tuple) -> bool:
        # 2-tuples address the default (unit-granular) shard 0
        if len(key) == 2:
            key = (key[0], key[1], 0)
        with self._cv:
            e = self._entries.get(key)
            return e is not None and not e.loading

    def cached_units(self, model: str) -> List[str]:
        """Unit names with at least one cached shard."""
        with self._cv:
            seen = []
            for (m, u, _s), e in self._entries.items():
                if m == model and not e.loading and u not in seen:
                    seen.append(u)
            return seen

    def stats(self) -> CacheStats:
        with self._cv:
            return CacheStats(
                budget_bytes=self.budget_bytes,
                bytes_cached=self._bytes,
                entries=sum(1 for e in self._entries.values()
                            if not e.loading),
                pinned=sum(1 for e in self._entries.values()
                           if not e.loading and e.refs > 0),
                hits=self._hits, misses=self._misses, waits=self._waits,
                inserts=self._inserts, evictions=self._evictions)

    def clear(self):
        """Drop every unpinned, non-loading entry (tests / redeploys)."""
        dropped = []
        with self._cv:
            for key in list(self._entries):
                e = self._entries[key]
                if e.loading or e.refs > 0:
                    continue
                del self._entries[key]
                self._bytes -= e.nbytes
                dropped.append(key)
        self._notify_evicted(dropped)
