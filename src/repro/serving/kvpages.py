"""Block-paged KV memory: the :class:`KVPagePool` page allocator.

The slotted decode arena (``init_cache(n_slots, cache_len)``) sized
every slot for the longest request; paged KV splits decode memory into
fixed-size **pages** of ``page_tokens`` positions and gives each
resident request a *page table* instead of a contiguous slot.  This
module is the host-side bookkeeping half — the device arrays (one
``(n_pages, K, page_tokens, dh)`` pool per attention layer) are owned
by the :class:`~repro.serving.decode.DecodeScheduler`, which consults
this pool for every allocate / share / free decision.

Disciplines (the serving-side twin of the WeightCache, now for KV):

  * **byte-budgeted** — ``n_pages`` is derived from a byte budget and
    the per-page footprint across all attention layers; admission
    reserves whole pages up front (all-or-nothing, so two half-admitted
    requests can never deadlock each other) and overflow is *blocking
    backpressure*, not an error — :class:`CacheOverflowError` is raised
    only when a request could never fit the whole budget.
  * **refcounted sharing** — pages are content-addressed by a running
    (model, token-prefix) hash over *full* prompt pages.  Requests that
    share a system prompt pin the same physical pages
    (:meth:`match_prefix`), so a prefix hit skips that span of prefill
    entirely and TTFT drops to the unshared suffix.
  * **cached free list** — a released page whose content is registered
    in the prefix index is parked in an LRU side list instead of being
    scrubbed: later requests still hit it warm, and the allocator
    evicts LRU-first only under pressure.
  * **copy-on-write append** — :meth:`ensure_writable` forks a shared
    page before a writer may touch it.  (The scheduler's layout makes
    decode writes land past every shared page, so this is a guard rail
    plus a unit-tested primitive, not a hot path.)

Locking: one condition variable guards all state (``analysis``-made so
the REPRO_ANALYZE=1 probe sees it).  The pool is a *leaf* in the lock
order — it never calls out while holding its lock.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro import analysis, metrics as metrics_mod
from repro.serving.api import CacheOverflowError


def page_hashes(key: str, tokens, page_tokens: int) -> List[str]:
    """Running content hash per *full* page of ``tokens``.

    ``hashes[i]`` commits to pages ``0..i`` inclusive (a running hash:
    page i's digest folds in page i-1's), prefixed by ``key`` — the
    model identity — so equal token prefixes under different models
    never collide.  Partial trailing pages are not hashed: only pages
    whose every position is prompt content are shareable.
    """
    toks = np.asarray(tokens, np.int32).reshape(-1)
    out: List[str] = []
    h = hashlib.sha1(key.encode())
    for p in range(len(toks) // page_tokens):
        h = h.copy()
        h.update(toks[p * page_tokens:(p + 1) * page_tokens].tobytes())
        out.append(h.hexdigest())
    return out


@dataclasses.dataclass
class KVPageStats:
    """Point-in-time pool occupancy."""
    total: int              # page budget
    used: int               # pages holding live content (pinned + cached)
    pinned: int             # pages held by >= 1 resident request
    cached: int             # released pages kept warm for prefix hits
    free: int               # immediately allocatable (excludes cached)
    prefix_hits: int        # cumulative pages served from the prefix index
    prefix_misses: int      # cumulative lookups that found no next page
    cow_copies: int         # cumulative copy-on-write forks


class KVPagePool:
    """Thread-safe refcounted allocator over ``n_pages`` logical pages.

    Page ids are ``0..n_pages-1``; the device-side pool arrays carry one
    extra *scratch* page (id :attr:`scratch_id`) that inactive decode
    rows write into — it is never handed out here.
    """

    def __init__(self, *, n_pages: int, page_tokens: int,
                 page_bytes: int = 0, model_key: str = "",
                 metrics: Optional[metrics_mod.MetricsRegistry] = None):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        if page_tokens < 1:
            raise ValueError(
                f"page_tokens must be >= 1, got {page_tokens}")
        self.n_pages = int(n_pages)
        self.page_tokens = int(page_tokens)
        self.page_bytes = int(page_bytes)
        self.model_key = model_key
        self.scratch_id = self.n_pages
        self._cv = analysis.make_condition("KVPagePool._cv")
        self._free: List[int] = list(range(self.n_pages))  # guarded-by: _cv
        self._ref: Dict[int, int] = {}                     # guarded-by: _cv
        # prefix index: running-hash -> page id, and its inverse for
        # invalidation on evict/recycle
        self._by_hash: Dict[str, int] = {}                 # guarded-by: _cv
        self._hash_of: Dict[int, str] = {}                 # guarded-by: _cv
        # released-but-registered pages, LRU order (oldest first)
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # guarded-by: _cv
        self.prefix_hits = 0                               # guarded-by: _cv
        self.prefix_misses = 0                             # guarded-by: _cv
        self.cow_copies = 0                                # guarded-by: _cv
        m = metrics_mod.resolve(metrics)
        self._m_total = m.gauge("kv/pages_total")
        self._m_used = m.gauge("kv/pages_used")
        self._m_pinned = m.gauge("kv/pages_pinned")
        self._m_hits = m.counter("kv/prefix_hits")
        self._m_misses = m.counter("kv/prefix_misses")
        self._m_total.set(self.n_pages)
        self._m_used.set(0)
        self._m_pinned.set(0)

    # ------------------------------------------------------------- internals
    def _available_locked(self) -> int:
        return len(self._free) + len(self._cached)

    def _gauges_locked(self):
        # metric instruments are leaf locks: safe to update under _cv
        self._m_used.set(self.n_pages - len(self._free))
        self._m_pinned.set(len(self._ref))

    def _forget_locked(self, pid: int):
        """Drop ``pid`` from the prefix index (content being recycled)."""
        h = self._hash_of.pop(pid, None)
        if h is not None and self._by_hash.get(h) == pid:
            del self._by_hash[h]

    def _take_locked(self, n: int) -> List[int]:
        """Pop ``n`` pages, evicting cached LRU pages as needed."""
        ids: List[int] = []
        for _ in range(n):
            if self._free:
                ids.append(self._free.pop())
            else:
                pid, _ = self._cached.popitem(last=False)   # LRU eviction
                self._forget_locked(pid)
                ids.append(pid)
        for pid in ids:
            self._ref[pid] = 1
        return ids

    # ------------------------------------------------------------ allocation
    def alloc(self, n: int, *, timeout: Optional[float] = None) -> List[int]:
        """Reserve ``n`` pages (refcount 1 each), blocking while the pool
        is under pressure.  All-or-nothing: a caller never holds a
        partial reservation while waiting, so concurrent admissions
        cannot deadlock.  Raises :class:`CacheOverflowError` if ``n``
        exceeds the whole budget (can *never* fit) and ``TimeoutError``
        if the pool stays exhausted past ``timeout`` seconds.
        """
        n = int(n)
        if n > self.n_pages:
            raise CacheOverflowError(
                f"request needs {n} KV pages but the pool budget is "
                f"{self.n_pages} pages x {self.page_tokens} tokens")
        if n <= 0:
            return []
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._available_locked() < n:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(
                            f"no free KV pages: need {n}, "
                            f"{self._available_locked()} available "
                            f"of {self.n_pages}")
                self._cv.wait(remaining)
            ids = self._take_locked(n)
            self._gauges_locked()
            return ids

    def release(self, ids: Sequence[int]):
        """Drop one reference per page.  A page reaching refcount 0 goes
        to the cached LRU if its content is registered (warm prefix
        reuse) or straight back to the free list otherwise."""
        with self._cv:
            for pid in ids:
                r = self._ref.get(pid, 0) - 1
                if r > 0:
                    self._ref[pid] = r
                    continue
                self._ref.pop(pid, None)
                if pid in self._hash_of:
                    self._cached[pid] = None
                    self._cached.move_to_end(pid)
                else:
                    self._free.append(pid)
            self._gauges_locked()
            self._cv.notify_all()

    # -------------------------------------------------------- prefix sharing
    def register(self, pid: int, h: str):
        """Publish ``pid`` as holding the prefix content ``h``.  Must be
        called only after the page's device content is final (the
        scheduler registers at the join boundary, after packing).
        First writer wins: a hash already mapped to a live page keeps
        its existing mapping (dedup point for future requests)."""
        with self._cv:
            if pid not in self._ref and pid not in self._cached:
                return                     # freed before registration landed
            if h in self._by_hash:
                return
            self._forget_locked(pid)       # one hash per page
            self._by_hash[h] = pid
            self._hash_of[pid] = h

    def match_prefix(self, hashes: Sequence[str]) -> List[int]:
        """Longest-prefix lookup: walk the running hashes in order and
        pin (incref) each page found; stop at the first miss.  Returns
        the pinned page ids — the caller owns one reference on each and
        must :meth:`release` them eventually."""
        out: List[int] = []
        with self._cv:
            for h in hashes:
                pid = self._by_hash.get(h)
                if pid is None:
                    self.prefix_misses += 1
                    self._m_misses.inc()
                    break
                if pid in self._cached:          # revive from the LRU
                    del self._cached[pid]
                self._ref[pid] = self._ref.get(pid, 0) + 1
                self.prefix_hits += 1
                self._m_hits.inc()
                out.append(pid)
            self._gauges_locked()
        return out

    def ensure_writable(self, pid: int):
        """Copy-on-write guard: returns ``(pid, False)`` when the caller
        holds the only reference, else forks — allocates a fresh page
        (non-blocking: raises :class:`CacheOverflowError` under
        exhaustion rather than waiting while the caller may hold other
        locks), drops the caller's reference on the shared page and
        returns ``(new_pid, True)``.  The caller must then copy the
        device content old -> new before writing."""
        with self._cv:
            if self._ref.get(pid, 0) <= 1:
                return pid, False
            if self._available_locked() < 1:
                raise CacheOverflowError(
                    "copy-on-write fork needs a free KV page but the "
                    f"pool is exhausted ({self.n_pages} pages, all live)")
            new = self._take_locked(1)[0]
            # drop our reference on the shared original
            self._ref[pid] -= 1
            self.cow_copies += 1
            self._gauges_locked()
            return new, True

    # ------------------------------------------------------------------ info
    def stats(self) -> KVPageStats:
        with self._cv:
            return KVPageStats(
                total=self.n_pages,
                used=self.n_pages - len(self._free),
                pinned=len(self._ref),
                cached=len(self._cached),
                free=len(self._free),
                prefix_hits=self.prefix_hits,
                prefix_misses=self.prefix_misses,
                cow_copies=self.cow_copies)

    def check_invariants(self):
        """Every page is in exactly one of {free, cached, pinned}; the
        prefix index maps only live pages.  Storm tests call this
        between operations."""
        with self._cv:
            free = set(self._free)
            cached = set(self._cached)
            pinned = set(self._ref)
            assert not (free & cached) and not (free & pinned) \
                and not (cached & pinned), (free, cached, pinned)
            assert free | cached | pinned == set(range(self.n_pages)), \
                "page leak/duplication"
            assert all(r > 0 for r in self._ref.values())
            for h, pid in self._by_hash.items():
                assert self._hash_of.get(pid) == h
                assert pid in cached or pid in pinned
