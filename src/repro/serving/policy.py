"""Keep-alive / eviction policies for instance pools.

The serverless lifecycle (paper Fig. 2) reclaims idle instances after a
keep-alive window, re-triggering cold starts.  The seed hard-wired that
rule into ``run_trace`` with an ad-hoc ``_logical_last`` attribute; here
it is one policy object the pool consults with the instance's idle time
on whatever clock the caller advances (trace replay uses the logical
trace clock, a live deployment would use wall time).
"""
from __future__ import annotations

import math
from typing import Optional


class EvictionPolicy:
    """Decides whether an *idle* instance should be reclaimed.  Busy or
    loading instances are never offered to the policy."""

    def should_evict(self, idle_s: float) -> bool:
        raise NotImplementedError


class KeepAliveTTL(EvictionPolicy):
    """Evict after ``ttl_s`` of idleness (strictly greater — matching
    the seed's ``last + keep_alive < now``).  ``ttl_s=0`` evicts as soon
    as the clock advances past the last use."""

    def __init__(self, ttl_s: float):
        if ttl_s < 0:
            raise ValueError("ttl_s must be >= 0")
        self.ttl_s = ttl_s

    def should_evict(self, idle_s: float) -> bool:
        return idle_s > self.ttl_s

    def __repr__(self):
        return f"KeepAliveTTL({self.ttl_s!r})"


class NeverEvict(EvictionPolicy):
    """Instances stay warm forever (provisioned-concurrency style)."""

    def should_evict(self, idle_s: float) -> bool:
        return False

    def __repr__(self):
        return "NeverEvict()"


def make_policy(keep_alive_s: Optional[float]) -> EvictionPolicy:
    """Seed-compatible shorthand: a TTL window, or never-evict for
    None / +inf."""
    if keep_alive_s is None or math.isinf(keep_alive_s):
        return NeverEvict()
    return KeepAliveTTL(keep_alive_s)
