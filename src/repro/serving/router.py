"""Thread-safe request router: concurrent admission, priority dispatch.

The Router is the platform's front door.  Any number of threads may
:meth:`submit` concurrently; each submission is

  1. **admitted** — rejected with :class:`AdmissionError` when the
     pending queue is at capacity (admission control keeps a saturated
     platform's queueing delay bounded instead of unbounded);
  2. **classified** — explicit ``Request.cls`` wins, otherwise
     warm-servable requests become INFERENCE and cold starts COLDSTART:
     the Priority-Aware Scheduler's "inference first" rule applied at
     the routing layer;
  3. **queued by class** — a worker pool drains the queue
     highest-priority-first (FIFO within a class) and drives the
     request through the model's :class:`InstancePool`.

``submit`` returns a ``concurrent.futures.Future[Response]``.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, Optional

import numpy as np

from concurrent.futures import InvalidStateError

from repro import analysis, metrics as metrics_mod
from repro.serving.api import (AdmissionError, Request, RequestClass,
                               Response, RouterStats, UnknownModelError)
from repro.serving.pool import InstancePool


def _resolve(fut: "Future", *, result=None, exc=None):
    """Terminal Future transition that tolerates a concurrent cancel —
    set_result/set_exception on a cancelled future raises
    InvalidStateError, which would otherwise kill the worker thread."""
    try:
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.set_result(result)
    except InvalidStateError:
        pass


class Router:
    def __init__(self, pools: Dict[str, InstancePool], *, workers: int = 4,
                 max_pending: Optional[int] = None,
                 acquire_timeout_s: float = 0.1,
                 cache: Optional[Any] = None,
                 metrics: Optional[metrics_mod.MetricsRegistry] = None,
                 autoscaler: Optional[Any] = None):
        """``acquire_timeout_s``: how long a worker may block on a
        saturated pool before requeueing the request (to the tail of
        its class) and serving other queued work — keeps a slow cold
        pool from absorbing the whole worker pool and starving
        higher-priority inference requests.

        ``cache``: the node-local WeightCache behind this router's
        pools, exposed for observability (``cache_stats``); the pools
        themselves consult it during cold starts.

        ``metrics``: registry for the live instruments
        (``router/submitted``, ``router/queue_depth``,
        ``router/latency_s/<class>``, ``router/ttft_s``, ...);
        falls back to the process default.

        ``autoscaler``: optional
        :class:`~repro.serving.autoscale.Autoscaler` — every admitted
        request is reported to it (arrival-rate signal), and it reads
        :meth:`queue_depth` back when sizing pools."""
        self.pools = pools
        self.max_pending = max_pending
        self.acquire_timeout_s = acquire_timeout_s
        self.cache = cache
        self.metrics = metrics_mod.resolve(metrics)
        self.autoscaler = autoscaler
        if autoscaler is not None:
            autoscaler.router = self
        self.stats = RouterStats()
        self._cv = analysis.make_condition("Router._cv")
        # (class, seq, Request, Future)
        self._heap: list = []              # guarded-by: _cv
        self._seq = itertools.count()
        self._stop = False                 # guarded-by: _cv
        self._in_flight = 0                # guarded-by: _cv
        self._workers = [threading.Thread(target=self._worker,
                                          name=f"router-worker-{i}",
                                          daemon=True)
                         for i in range(max(1, workers))]
        for t in self._workers:
            t.start()

    # ------------------------------------------------------------ admission
    def _classify(self, req: Request) -> RequestClass:
        pool = self.pools.get(req.model)
        if pool is not None and pool.any_live():
            return RequestClass.INFERENCE
        return RequestClass.COLDSTART

    def submit(self, req: Request) -> "Future[Response]":
        """Admit one invocation; returns a Future resolving to its
        Response (or raising the dispatch error).  Unknown models fail
        here, on the submitting thread, with a typed error — not with a
        bare KeyError surfacing from a worker."""
        if req.model not in self.pools:
            raise UnknownModelError(
                f"no pool for model {req.model!r}; deployed: "
                f"{sorted(self.pools)}")
        req.t_submit = time.monotonic()
        if req.cls is None:
            req.cls = self._classify(req)
        fut: "Future[Response]" = Future()
        with self._cv:
            if self._stop:
                raise RuntimeError("router is shut down")
            if self.max_pending is not None and \
                    len(self._heap) >= self.max_pending:
                self.stats.rejected += 1
                self.metrics.counter("router/rejected").inc()
                raise AdmissionError(
                    f"queue at capacity ({self.max_pending} pending)")
            self.stats.submitted += 1
            heapq.heappush(self._heap,
                           (int(req.cls), next(self._seq), req, fut))
            self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                             len(self._heap))
            depth = len(self._heap)
            self._cv.notify()
        self.metrics.counter("router/submitted").inc()
        self.metrics.gauge("router/queue_depth").set(depth)
        if self.autoscaler is not None:
            self.autoscaler.observe(req.model)
        return fut

    def queue_depth(self) -> int:
        """Pending (not yet dispatched) requests across all classes —
        the backlog signal the autoscaler reads."""
        with self._cv:
            return len(self._heap)

    # ------------------------------------------------------------- dispatch
    def _worker(self):
        while True:
            with self._cv:
                while not self._heap and not self._stop:
                    self._cv.wait()
                if not self._heap:
                    return                 # stopped and drained
                _, _, req, fut = heapq.heappop(self._heap)
                depth = len(self._heap)
            self.metrics.gauge("router/queue_depth").set(depth)
            self._dispatch(req, fut)

    def _requeue(self, req: Request, fut: "Future[Response]"):
        """Pool saturated: requeue at the tail of the request's class so
        this worker can serve other (higher-priority) work."""
        with self._cv:
            heapq.heappush(self._heap,
                           (int(req.cls), next(self._seq), req, fut))
            self._cv.notify()

    def _dispatch(self, req: Request, fut: "Future[Response]"):
        if req.gen is not None:
            return self._dispatch_gen(req, fut)
        pool = self.pools[req.model]
        self._serve(req, fut, pool,
                    acquire=lambda: pool.acquire(
                        timeout=self.acquire_timeout_s,
                        logical_now=req.t_logical),
                    release=pool.release,
                    service=lambda inst: inst.invoke(req.batch))

    def _dispatch_gen(self, req: Request, fut: "Future[Response]"):
        """Generation dispatch: a *shared* pool hold — concurrent
        requests join one instance's continuous-batching decode
        scheduler instead of serializing behind exclusive acquire.  A
        cold instance is held exclusively only for the pipeline load
        (its first token is produced in-pipeline); mark_live then opens
        it to joiners mid-request."""
        pool = self.pools[req.model]

        def service(inst, joinable):
            on_live = None if joinable else \
                (lambda i=inst: pool.mark_live(i))
            return inst.generate(req.gen, on_live=on_live)

        def extra(result, t_arr):
            return dict(tokens=np.asarray(result.tokens, np.int32),
                        ttft_s=result.t_first - t_arr,
                        tpot_s=result.tpot_s)

        self._serve(req, fut, pool,
                    acquire=lambda: pool.acquire_gen(
                        timeout=self.acquire_timeout_s,
                        logical_now=req.t_logical),
                    release=pool.release_gen,
                    service=service, extra=extra)

    def _serve(self, req: Request, fut: "Future[Response]", pool, *,
               acquire, release, service, extra=None):
        """The dispatch skeleton shared by the one-shot and generation
        paths: acquire with requeue-on-timeout, claim the future, track
        in-flight, serve, release, resolve.  ``acquire`` may return an
        instance or an ``(instance, ...)`` tuple whose tail is passed
        through to ``service``; ``extra(result, t_arr)`` contributes
        path-specific Response fields."""
        inst = None
        try:
            try:
                got = acquire()
            except TimeoutError:
                self._requeue(req, fut)
                return
            inst, *rest = got if isinstance(got, tuple) else (got,)
            # claim the future before doing work: a request cancelled
            # while queued is dropped here instead of being served into
            # a dead future (whose set_result would kill this worker)
            if not fut.set_running_or_notify_cancel():
                release(inst, logical_now=req.t_logical)
                return
            # service starts here: t_arrival/latency_s measure the
            # invocation itself (seed semantics) — router queueing,
            # pool waits and instance provisioning live in queue_s
            t_arr = time.monotonic()
            with self._cv:
                self._in_flight += 1
                self.stats.max_in_flight = max(self.stats.max_in_flight,
                                               self._in_flight)
            self.metrics.gauge("router/in_flight").add(1)
            try:
                result, info = service(inst, *rest)
            finally:
                with self._cv:
                    self._in_flight -= 1
                self.metrics.gauge("router/in_flight").add(-1)
            t_done = time.monotonic()
            release(inst, logical_now=req.t_logical, cold=info["cold"])
            inst = None
            with self._cv:
                self.stats.completed += 1
            resp = Response(
                req_id=req.req_id, model=req.model, cold=info["cold"],
                t_arrival=t_arr, t_done=t_done,
                load_s=info["load_s"], infer_s=info["infer_s"],
                utilization=info["utilization"],
                queue_s=t_arr - req.t_submit, cls=req.cls,
                **(extra(result, t_arr) if extra is not None else {}))
            self._record(resp)
            _resolve(fut, result=resp)
        except BaseException as e:
            if inst is not None:
                release(inst, logical_now=req.t_logical)
            self.metrics.counter("router/errors").inc()
            _resolve(fut, exc=e)

    def _record(self, resp: Response):
        """Per-completion instruments.  latency_s is keyed by request
        class (the Priority-Aware Scheduler's unit of SLO accounting);
        ttft_s here is end-to-end *from submit* — queue wait plus the
        service-side first-token time — because that is what a client's
        SLO sees, unlike ``Response.ttft_s`` which starts at service."""
        m = self.metrics
        m.counter("router/completed").inc()
        m.counter("router/cold" if resp.cold else "router/warm").inc()
        m.histogram("router/queue_s").observe(resp.queue_s)
        cls = resp.cls.name.lower() if resp.cls is not None else "unknown"
        m.histogram(f"router/latency_s/{cls}").observe(resp.latency_s)
        if resp.ttft_s is not None:
            m.histogram("router/ttft_s").observe(resp.queue_s + resp.ttft_s)
        if resp.tpot_s:
            h = m.histogram("router/tpot_s")
            for dt in resp.tpot_s:
                h.observe(dt)

    def cache_stats(self):
        """CacheStats of the attached node-local WeightCache (None when
        serving cache-less)."""
        return self.cache.stats() if self.cache is not None else None

    # ------------------------------------------------------------- shutdown
    def shutdown(self, wait: bool = True):
        """Stop accepting work; workers drain the queue, then exit."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if wait:
            for t in self._workers:
                t.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False
