"""SLO-driven autoscaling: pre-provision warm instances from live signals.

The platform's cold-start pipeline makes one cold start as cheap as the
hardware allows, but a 10x arrival burst against a scaled-in pool still
pays that pipeline once per new instance — *on the request path*, where
it lands straight in p99 TTFT.  Production serverless closes the loop
instead: arrival-rate slope and queue depth drive **pre-provisioning**
(λScale's fast scale-out regime), so the burst finds instances already
warm, and idle capacity is scaled back in to free the node.

:class:`Autoscaler` is that policy object — beside
:class:`~repro.serving.policy.EvictionPolicy`, which answers the
per-instance question "may this idle instance be reclaimed?", the
autoscaler answers the pool-level question "how many instances should
be warm *right now*?":

  * every admitted request is observed (the Router calls
    :meth:`observe`); a sliding window keeps per-model arrival times;
  * the **rate estimate** splits the window in half: the older half
    gives the base rate, the newer half minus the older gives the
    slope.  The decision rate is ``recent + max(0, slope) * horizon`` —
    a rising ramp is extrapolated ``horizon_s`` ahead (one cold-start
    latency: provisioning started now must finish before the load
    arrives), a falling one is not chased down;
  * the target warm count is ``ceil(rate / rps_per_instance)`` clamped
    to ``[min_warm, pool.max_instances]``, plus the router queue depth
    term: a backlog deeper than ``queue_per_instance`` per warm
    instance adds capacity even when the rate estimate lags;
  * **scale-out** dispatches :meth:`~repro.serving.pool.InstancePool.
    prewarm` jobs on a private worker pool — the cold-start pipeline
    runs *off* the request path, and duplicate dispatch is suppressed
    while a prewarm is in flight;
  * **scale-in** reclaims idle instances above target via
    :meth:`~repro.serving.pool.InstancePool.scale_in` once a model has
    been idle ``idle_scale_in_s``; busy instances and instances with
    resident generations are structurally out of reach (the pool only
    offers *idle* ones), so a long generation is never yanked.

Driving: call :meth:`tick` from your own loop (tests, logical-clock
replay), or :meth:`start` a background thread that ticks every
``interval_s`` (the SLO benchmark's mode).  All decision inputs can be
passed an explicit ``now`` so unit tests run on a logical clock.
"""
from __future__ import annotations

import math
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Deque, Dict, Optional

from repro import analysis, metrics as metrics_mod


class Autoscaler:
    """Arrival-rate + queue-depth driven warm-capacity controller."""

    def __init__(self, pools: Dict[str, "object"], *,
                 rps_per_instance: float = 2.0,
                 window_s: float = 10.0,
                 horizon_s: float = 5.0,
                 min_warm: int = 0,
                 queue_per_instance: int = 4,
                 idle_scale_in_s: float = 30.0,
                 interval_s: float = 0.5,
                 max_prewarm_workers: int = 2,
                 metrics: Optional[metrics_mod.MetricsRegistry] = None):
        """pools: model -> InstancePool (a ServerlessPlatform's
        ``.pools`` dict works as-is).

        rps_per_instance: serving capacity one warm instance is
        budgeted for — the knob that converts a rate into a count.
        window_s / horizon_s: sliding estimation window and how far a
        rising slope is extrapolated (set horizon to ~one cold-start
        latency so prewarms land before the load does).
        queue_per_instance: router backlog tolerated per warm instance
        before the queue term adds capacity (0 disables the term).
        idle_scale_in_s: no arrivals for this long -> scale the model
        back to min_warm.
        interval_s: background tick period (:meth:`start`).
        """
        if rps_per_instance <= 0:
            raise ValueError("rps_per_instance must be > 0")
        if window_s <= 0:
            raise ValueError("window_s must be > 0")
        self.pools = pools
        self.rps_per_instance = float(rps_per_instance)
        self.window_s = float(window_s)
        self.horizon_s = float(horizon_s)
        self.min_warm = int(min_warm)
        self.queue_per_instance = int(queue_per_instance)
        self.idle_scale_in_s = float(idle_scale_in_s)
        self.interval_s = float(interval_s)
        self.metrics = metrics_mod.resolve(metrics)
        self.router = None          # attached by the platform's Router
        self._cv = analysis.make_condition("Autoscaler._cv")
        self._arrivals: Dict[str, Deque[float]] = {}   # guarded-by: _cv
        self._inflight: Dict[str, int] = {}            # guarded-by: _cv
        self._stop = False                             # guarded-by: _cv
        self._thread: Optional[threading.Thread] = None
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, max_prewarm_workers),
            thread_name_prefix="autoscale-prewarm")

    # -------------------------------------------------------------- signals
    def observe(self, model: str, now: Optional[float] = None):
        """Record one admitted request (called by the Router on every
        submit; cheap — append + trim under the autoscaler lock)."""
        t = time.monotonic() if now is None else now
        with self._cv:
            dq = self._arrivals.get(model)
            if dq is None:
                dq = self._arrivals[model] = deque()
            dq.append(t)
            self._trim_locked(dq, t)
        self.metrics.counter("autoscaler/observed").inc()

    def _trim_locked(self, dq: Deque[float], now: float):
        horizon = now - self.window_s
        while dq and dq[0] < horizon:
            dq.popleft()

    def rate_estimate(self, model: str,
                      now: Optional[float] = None) -> float:
        """Decision rate (req/s): recent-half rate plus the positive
        slope extrapolated ``horizon_s`` ahead."""
        t = time.monotonic() if now is None else now
        with self._cv:
            dq = self._arrivals.get(model)
            if not dq:
                return 0.0
            self._trim_locked(dq, t)
            half = self.window_s / 2.0
            mid = t - half
            n_new = sum(1 for a in dq if a >= mid)
            n_old = len(dq) - n_new
        r_new = n_new / half
        r_old = n_old / half
        slope = (r_new - r_old) / half          # req/s per s
        return r_new + max(0.0, slope) * self.horizon_s

    def target_warm(self, model: str, now: Optional[float] = None) -> int:
        """Warm-instance target for ``model`` right now."""
        pool = self.pools[model]
        rate = self.rate_estimate(model, now)
        target = math.ceil(rate / self.rps_per_instance) if rate > 0 else 0
        if self.queue_per_instance > 0 and self.router is not None:
            depth = self.router.queue_depth()
            st = pool.stats()
            allowance = self.queue_per_instance * max(1, st.live)
            if depth > allowance:
                target += math.ceil(
                    (depth - allowance) / self.queue_per_instance)
        return max(self.min_warm,
                   min(int(target), pool.max_instances))

    # ------------------------------------------------------------ decisions
    def tick(self, now: Optional[float] = None) -> Dict[str, int]:
        """One control-loop iteration over every pool.  Returns
        {model: warm target} (observability / tests).  Scale-out work is
        dispatched asynchronously; scale-in is immediate (eviction is
        cheap and only ever touches idle instances)."""
        t = time.monotonic() if now is None else now
        targets: Dict[str, int] = {}
        for model, pool in self.pools.items():
            target = self.target_warm(model, t)
            targets[model] = target
            st = pool.stats()
            self.metrics.gauge(f"autoscaler/{model}/target").set(target)
            with self._cv:
                inflight = self._inflight.get(model, 0)
                dq = self._arrivals.get(model)
                last_arrival = dq[-1] if dq else None
            deficit = target - st.live - inflight
            if deficit > 0:
                for _ in range(deficit):
                    self._dispatch_prewarm(model, t)
                continue
            idle_for = math.inf if last_arrival is None \
                else t - last_arrival
            if st.live > max(target, self.min_warm) and \
                    idle_for >= self.idle_scale_in_s:
                n = pool.scale_in(max(target, self.min_warm), now=t)
                if n:
                    self.metrics.counter(
                        f"autoscaler/{model}/scale_ins").inc(n)
        return targets

    def _dispatch_prewarm(self, model: str, now: float):
        with self._cv:
            self._inflight[model] = self._inflight.get(model, 0) + 1
        self._pool.submit(self._prewarm_job, model, now)

    def _prewarm_job(self, model: str, now: float):
        try:
            ok = self.pools[model].prewarm(logical_now=now)
            if ok:
                self.metrics.counter(f"autoscaler/{model}/prewarms").inc()
        except BaseException:
            # a failed prewarm is capacity we didn't get, not a request
            # failure: count it and let the next tick retry
            self.metrics.counter(f"autoscaler/{model}/prewarm_errors").inc()
        finally:
            with self._cv:
                self._inflight[model] -= 1
                self._cv.notify_all()

    # ------------------------------------------------------------ lifecycle
    def start(self):
        """Run :meth:`tick` every ``interval_s`` on a daemon thread."""
        with self._cv:
            if self._thread is not None:
                return
            self._stop = False
            self._thread = threading.Thread(target=self._run,
                                            name="autoscaler",
                                            daemon=True)
            self._thread.start()

    def _run(self):
        while True:
            with self._cv:
                deadline = time.monotonic() + self.interval_s
                while not self._stop:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cv.wait(remaining)
                if self._stop:
                    return
            self.tick()

    def stop(self, *, wait_inflight: bool = True):
        """Stop the background thread; optionally wait for in-flight
        prewarm jobs so a shutting-down bench observes stable state."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
            t = self._thread
            self._thread = None
        if t is not None:
            t.join()
        if wait_inflight:
            with self._cv:
                while any(self._inflight.values()):
                    self._cv.wait()
        self._pool.shutdown(wait=True)

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()
        return False
