"""Function instances and per-model instance pools.

One :class:`FunctionInstance` models a container: it holds (at most) one
live model.  The first request after provisioning is a **cold start**
and goes through the Cicada pipeline (``ColdStartEngine``) — the
triggering request's inference is computed layer-by-layer *inside* the
loading pipeline.  Subsequent requests are **warm**: direct steady-state
forward, or — for generation requests — a join into the instance's
:class:`~repro.serving.decode.DecodeScheduler`, the slot-based
continuous-batching decode engine each live instance owns.

:class:`InstancePool` owns up to ``max_instances`` containers for one
model function and hands them out under two disciplines:

  * **exclusive** (:meth:`acquire`): one-shot forwards and cold-start
    pipeline loads — a cold model hit by concurrent requests either
    rides the one in-flight pipeline (followers wait and are served
    warm) or scales out onto a fresh instance, never two pipelines
    loading into the same container;
  * **shared generation** (:meth:`acquire_gen`): any number of
    generation requests up to the scheduler's slot count may hold a
    *live* instance concurrently — that co-residency is what lets them
    batch dynamically.  A cold instance is first held exclusively for
    the pipeline load; :meth:`mark_live` then opens it to joiners
    mid-request.
  * keep-alive is delegated to an :class:`~repro.serving.policy.
    EvictionPolicy`; :meth:`sweep` offers only *idle* instances to it on
    whatever clock the caller advances (logical trace time in replay);
    instances with resident generations are busy, hence never offered;
  * :meth:`stats` exposes cold/warm/eviction/generation counters.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import analysis, metrics as metrics_mod
from repro.core.coldstart import ColdStartEngine, LoadResult
from repro.serving.api import GenerateSpec, PoolStats
from repro.serving.decode import (DecodeScheduler, GenResult, sample_first,
                                  paged_page_count, validate_spec,
                                  validate_spec_paged, _as_prompt)
from repro.serving.policy import EvictionPolicy, NeverEvict
from repro.store.cache import WeightCache
from repro.store.store import WeightStore

PyTree = Any


class FunctionInstance:
    """A container with one deployed model function.

    Not internally synchronized: the owning pool guarantees at most one
    request holds an instance between acquire() and release()."""

    def __init__(self, model, model_name: str, store: WeightStore, *,
                 strategy: str = "cicada", io_workers: int = 4,
                 chunk_bytes: int = 1 << 20, warm: bool = True,
                 example_batch: Optional[Dict[str, jax.Array]] = None,
                 cache: Optional[WeightCache] = None,
                 gen_slots: int = 8, gen_cache_len: int = 256,
                 kv_page_tokens: Optional[int] = None,
                 kv_budget_bytes: Optional[int] = None,
                 mesh_shape=None, rules=None, compute_quant: bool = False,
                 metrics: Optional[metrics_mod.MetricsRegistry] = None,
                 source=None):
        """gen_slots / gen_cache_len: capacity of this container's
        continuous-batching DecodeScheduler — concurrent generation
        requests up to gen_slots share one slotted KV cache of
        gen_cache_len positions per slot.

        mesh_shape / rules: shard-granular cold starts — weights stream
        onto a ``(data, model)`` device mesh of this shape (e.g.
        ``(1, 4)`` or just ``4`` for 4-way model parallelism), one
        retrieval stream per device, and the instance serves warm
        requests from the mesh-sharded params.  rules defaults to
        ``serve_rules()``.

        compute_quant: int8-deployed models stay quantized-resident
        (QuantLeaf params + fused-dequant matmuls) instead of being
        dequantized at application — see ColdStartEngine."""
        self.model = model
        self.model_name = model_name
        self.example_batch = example_batch
        mesh = None
        if mesh_shape is not None:
            from repro.launch.mesh import make_serving_mesh
            if isinstance(mesh_shape, int):
                mesh_shape = (1, mesh_shape)
            mesh = make_serving_mesh(mesh_shape)
        self.mesh = mesh
        self.engine = ColdStartEngine(model, model_name, store,
                                      strategy=strategy,
                                      io_workers=io_workers,
                                      chunk_bytes=chunk_bytes,
                                      compute_quant=compute_quant,
                                      cache=cache, mesh=mesh, rules=rules,
                                      metrics=metrics, source=source)
        self.metrics = metrics_mod.resolve(metrics)
        self.params: Optional[PyTree] = None
        self.last_load: Optional[LoadResult] = None
        self.gen_slots = int(gen_slots)
        self.gen_cache_len = int(gen_cache_len)
        # kv_page_tokens != None switches the scheduler to block-paged
        # KV (kv_budget_bytes caps the pool; None -> slotted-equivalent)
        self.kv_page_tokens = kv_page_tokens
        self.kv_budget_bytes = kv_budget_bytes
        self.scheduler: Optional[DecodeScheduler] = None
        # guards scheduler creation: warm generation joiners are NOT
        # serialized by the pool (shared holds), so two may race here
        self._sched_lock = analysis.make_lock(
            "FunctionInstance._sched_lock")
        self._fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
        if warm and example_batch is not None:
            self.engine.warmup(example_batch)
            # warm the steady-state forward too
            ab = jax.eval_shape(lambda: model.init(jax.random.key(0)))
            zeros = jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), ab)
            jax.block_until_ready(self._fwd(zeros, example_batch))

    @property
    def live(self) -> bool:
        return self.params is not None

    def ensure_live(self) -> bool:
        """Run the cold-start pipeline proactively (autoscaler prewarm):
        load params using the warmup example batch, *off* any request.
        Returns True when a load ran, False when already live."""
        if self.live:
            return False
        if self.example_batch is None:
            raise RuntimeError(
                f"instance for {self.model_name!r} has no example_batch; "
                "cannot prewarm without a representative input")
        res = self.engine.load(self.example_batch)
        self.params = res.params
        self.last_load = res
        return True

    def evict(self):
        self.params = None
        self.scheduler = None          # slotted KV cache dies with the params

    def invoke(self, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, dict]:
        """Returns (logits, {"cold": bool, "load_s": float, "infer_s"})."""
        if not self.live:
            res = self.engine.load(batch)
            self.params = res.params
            self.last_load = res
            return res.logits, {"cold": True,
                                "load_s": res.trace.total_time(),
                                "infer_s": 0.0,
                                "utilization": res.trace.utilization()}
        t0 = time.monotonic()
        logits = jax.block_until_ready(self._fwd(self.params, batch))
        return logits, {"cold": False, "load_s": 0.0,
                        "infer_s": time.monotonic() - t0,
                        "utilization": 1.0}

    # ------------------------------------------------------------ generation
    def _ensure_scheduler(self) -> DecodeScheduler:
        if self.scheduler is None:
            with self._sched_lock:
                if self.scheduler is None:
                    self.scheduler = DecodeScheduler(
                        self.model, self.params, n_slots=self.gen_slots,
                        cache_len=self.gen_cache_len,
                        kv_page_tokens=self.kv_page_tokens,
                        kv_budget_bytes=self.kv_budget_bytes,
                        metrics=self.metrics)
        return self.scheduler

    def generate(self, spec: GenerateSpec, *,
                 on_live: Optional[Callable[[], None]] = None
                 ) -> Tuple[GenResult, dict]:
        """Serve one generation request on this container.

        Cold: the Cicada pipeline loads the model AND answers the
        prompt — the first token is sampled from the pipeline's
        in-flight logits the moment the final E completes (TTFT lands
        within the load), then the request migrates into the decode
        scheduler at position S+1.  Warm: prefill + join directly.

        on_live: called once the instance holds params and a scheduler
        (immediately when already warm) — the pool uses it to open a
        cold-held instance to concurrent joiners mid-request.
        """
        prompt = _as_prompt(spec.prompt)
        n_prompt = int(prompt.shape[1])
        # fail before the expensive load, not after
        if self.kv_page_tokens:
            n_pages = paged_page_count(
                self.model, page_tokens=self.kv_page_tokens,
                budget_bytes=self.kv_budget_bytes,
                n_slots=self.gen_slots, cache_len=self.gen_cache_len)
            # per-request ceiling mirrors DecodeScheduler's np_max
            # default (page-table width = ceil(cache_len / pt))
            np_max = max(1, min(
                n_pages, -(-self.gen_cache_len // self.kv_page_tokens)))
            sched = self.scheduler
            validate_spec_paged(
                spec, n_prompt, page_tokens=self.kv_page_tokens,
                n_pages=np_max,
                stats=sched.kvpool.stats() if sched is not None else None)
        else:
            validate_spec(spec, n_prompt, self.gen_cache_len)
        if not self.live:
            first: Dict[str, Any] = {}

            def _first_token(logits):
                first["token"] = sample_first(logits, spec, n_prompt)
                first["t"] = time.monotonic()

            res = self.engine.load({"tokens": prompt},
                                   on_logits=_first_token)
            self.params = res.params
            self.last_load = res
            self._ensure_scheduler()
            if on_live is not None:
                on_live()
            result = self.scheduler.generate(spec,
                                             first_token=first["token"],
                                             t_first=first["t"])
            return result, {"cold": True,
                            "load_s": res.trace.total_time(),
                            "infer_s": 0.0,
                            "utilization": res.trace.utilization()}
        self._ensure_scheduler()
        if on_live is not None:
            on_live()
        t0 = time.monotonic()
        result = self.scheduler.generate(spec)
        return result, {"cold": False, "load_s": 0.0,
                        "infer_s": time.monotonic() - t0,
                        "utilization": 1.0}


class InstancePool:
    """Thread-safe pool of FunctionInstances for one model function."""

    # After an exclusive acquire() times out, new generation joins stay
    # paused this long (refreshed on every timeout, cleared the moment
    # an exclusive acquire succeeds).  Covers the Router's
    # requeue-and-retry gap, during which no acquire() is parked in
    # wait(); bounded so an abandoned requester can't block generation
    # service forever.
    EXCL_STARVATION_GRACE_S = 5.0

    def __init__(self, model_name: str,
                 builder: Callable[[], Tuple[Any, Dict]],
                 store: Optional[WeightStore] = None, *,
                 strategy: str = "cicada",
                 policy: Optional[EvictionPolicy] = None,
                 max_instances: int = 1, io_workers: int = 4,
                 chunk_bytes: int = 1 << 20,
                 instance_factory: Optional[Callable[[], Any]] = None,
                 cache: Optional[WeightCache] = None,
                 gen_slots: int = 8, gen_cache_len: int = 256,
                 kv_page_tokens: Optional[int] = None,
                 kv_budget_bytes: Optional[int] = None,
                 mesh_shape=None, rules=None, compute_quant: bool = False,
                 metrics: Optional[metrics_mod.MetricsRegistry] = None,
                 source=None):
        """builder: () -> (model, example_batch).  ``instance_factory``
        overrides container provisioning (tests / future remote pools);
        the default builds a warmed FunctionInstance.  ``cache``: one
        node-local WeightCache shared by every instance of this pool
        (and, via the platform, across pools) — concurrent scale-out
        cold starts then single-flight each (unit, shard) store read.
        ``source``: ShardSource for cache-missing retrieval streams
        (the cluster peer-exchange tier; default: origin store).
        ``gen_slots``/``gen_cache_len``: per-instance DecodeScheduler
        capacity (concurrent generation residency / KV positions).
        ``mesh_shape``/``rules``: shard-granular cold starts (see
        FunctionInstance)."""
        self.model_name = model_name
        self.policy = policy if policy is not None else NeverEvict()
        self.max_instances = max(1, int(max_instances))
        self.cache = cache
        self.source = source
        self.gen_slots = int(gen_slots)
        self.gen_cache_len = int(gen_cache_len)
        self.kv_page_tokens = kv_page_tokens
        self.kv_budget_bytes = kv_budget_bytes
        self.mesh_shape = mesh_shape
        self.rules = rules
        self.compute_quant = compute_quant
        self._builder = builder
        self._store = store
        self._strategy = strategy
        self._io_workers = io_workers
        self._chunk_bytes = chunk_bytes
        self._factory = instance_factory or self._default_factory
        self._cv = analysis.make_condition("InstancePool._cv")
        self._instances: List[Any] = []            # guarded-by: _cv
        self._idle: List[Any] = []                 # guarded-by: _cv
        self._busy: List[Any] = []                 # guarded-by: _cv
        self._creating = 0                         # guarded-by: _cv
        # id(inst) -> logical t
        self._last_used: Dict[int, float] = {}     # guarded-by: _cv
        # id(inst) -> joined gens
        self._gen_count: Dict[int, int] = {}       # guarded-by: _cv
        self._gen_cold: set = set()                # guarded-by: _cv
        # acquire() calls in wait
        self._excl_waiters = 0                     # guarded-by: _cv
        # sticky join pause
        self._excl_starved_until = 0.0             # guarded-by: _cv
        self._cold_starts = 0                      # guarded-by: _cv
        self._warm_hits = 0                        # guarded-by: _cv
        self._evictions = 0                        # guarded-by: _cv
        self._prewarms = 0                         # guarded-by: _cv
        self.metrics = metrics_mod.resolve(metrics)
        # metric instruments are leaf locks: incrementing under _cv
        # adds only a _cv -> instrument edge, never a cycle
        self._m_cold = self.metrics.counter(f"pool/{model_name}/cold_starts")
        self._m_warm = self.metrics.counter(f"pool/{model_name}/warm_hits")
        self._m_evict = self.metrics.counter(f"pool/{model_name}/evictions")
        self._m_prewarm = self.metrics.counter(f"pool/{model_name}/prewarms")

    def _default_factory(self):
        model, example = self._builder()
        return FunctionInstance(model, self.model_name, self._store,
                                strategy=self._strategy,
                                io_workers=self._io_workers,
                                chunk_bytes=self._chunk_bytes,
                                example_batch=example,
                                cache=self.cache,
                                gen_slots=self.gen_slots,
                                gen_cache_len=self.gen_cache_len,
                                kv_page_tokens=self.kv_page_tokens,
                                kv_budget_bytes=self.kv_budget_bytes,
                                mesh_shape=self.mesh_shape,
                                rules=self.rules,
                                compute_quant=self.compute_quant,
                                metrics=self.metrics,
                                source=self.source)

    # ------------------------------------------------------------ lifecycle
    def acquire(self, *, timeout: Optional[float] = None,
                logical_now: Optional[float] = None):
        """Reserve an instance exclusively.  Preference order: a warm
        (live) idle instance, then a cold idle one, then scale-out up to
        ``max_instances``; otherwise block until a release.

        ``logical_now``: the requester's logical arrival time — idle
        instances whose keep-alive expired *before* this request are
        evicted here rather than reused warm, so eviction semantics
        stay per-request faithful even when replay runs far ahead of
        the logical clock (concurrent as-fast-as-possible replay)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                if logical_now is not None:
                    self._evict_expired_locked(logical_now)
                inst = next((i for i in self._idle if i.live), None)
                if inst is None and self._idle:
                    inst = self._idle[0]
                if inst is not None:
                    self._idle.remove(inst)
                    self._busy.append(inst)
                    self._excl_starved_until = 0.0   # exclusive won
                    return inst
                if len(self._instances) + self._creating \
                        < self.max_instances:
                    self._creating += 1
                    self._excl_starved_until = 0.0   # exclusive won
                    break
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    # the requester will likely requeue and retry (the
                    # Router's loop): keep joins paused across the gap,
                    # or a continuous joiner stream wins every race
                    self._excl_starved_until = time.monotonic() + \
                        self.EXCL_STARVATION_GRACE_S
                    raise TimeoutError(
                        f"pool {self.model_name!r} saturated "
                        f"({self.max_instances} instances busy)")
                # while we wait, _gen_candidate_locked grants no new joins, so
                # shared generation holds drain instead of starving us
                self._excl_waiters += 1
                try:
                    self._cv.wait(remaining)
                finally:
                    self._excl_waiters -= 1
        return self._provision()

    # --------------------------------------------------- shared generation
    def _gen_candidate_locked(self):
        """A live instance a generation request may join right now:
        not mid cold-load, not exclusively held by one-shot work, with
        scheduler slot capacity.  Idle instances preferred (caller
        holds the lock).

        While an exclusive acquire() is blocked in wait() — or recently
        timed out and is being requeued/retried by the Router — no new
        joins are granted: a continuous stream of joiners would
        otherwise keep ``gen_count > 0`` forever and starve one-shot
        work on a saturated pool.  Pausing joins lets the resident
        generations drain, the instance go idle, and the exclusive
        request win (joiners requeue via the router's acquire timeout
        meanwhile)."""
        if self._excl_waiters > 0 or \
                time.monotonic() < self._excl_starved_until:
            return None
        for inst in list(self._idle) + list(self._busy):
            if not inst.live:
                continue
            gid = id(inst)
            if gid in self._gen_cold:
                continue                      # pipeline still loading it
            cnt = self._gen_count.get(gid, 0)
            if inst in self._busy and cnt == 0:
                continue                      # exclusive one-shot holder
            if cnt < getattr(inst, "gen_slots", 1):
                return inst
        return None

    def acquire_gen(self, *, timeout: Optional[float] = None,
                    logical_now: Optional[float] = None):
        """Reserve a *shared* generation hold.  Returns
        ``(inst, joinable)``:

          * joinable=True  — inst is live; the caller can join its
            decode scheduler immediately (other requests may already be
            resident: that co-residency is the continuous batch);
          * joinable=False — inst is cold and now held for this
            caller's pipeline load; the pool keeps other generation
            requests off it until :meth:`mark_live`.

        Preference order mirrors :meth:`acquire`: live instance with
        slot capacity, then a cold idle one, then scale-out up to
        ``max_instances``; otherwise block until something frees."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                if logical_now is not None:
                    self._evict_expired_locked(logical_now)
                inst = self._gen_candidate_locked()
                if inst is not None:
                    gid = id(inst)
                    self._gen_count[gid] = self._gen_count.get(gid, 0) + 1
                    if inst in self._idle:
                        self._idle.remove(inst)
                        self._busy.append(inst)
                    return inst, True
                inst = next((i for i in self._idle if not i.live), None)
                if inst is not None:          # cold container: load here
                    self._idle.remove(inst)
                    self._busy.append(inst)
                    self._gen_count[id(inst)] = 1
                    self._gen_cold.add(id(inst))
                    return inst, False
                if len(self._instances) + self._creating \
                        < self.max_instances:
                    self._creating += 1
                    break
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"pool {self.model_name!r} saturated for "
                        f"generation ({self.max_instances} instances, "
                        f"all slots busy)")
                # nothing notifies when the exclusive-starvation window
                # lapses by itself (abandoned requester): cap the wait
                # at its expiry so joins resume then, not never
                window = self._excl_starved_until - time.monotonic()
                if window > 0:
                    remaining = window if remaining is None \
                        else min(remaining, window)
                self._cv.wait(remaining)
        return self._provision(gen=True), False

    def _provision(self, *, gen: bool = False):
        """Scale-out: build a fresh busy instance.  The caller already
        incremented ``_creating`` under the lock; the factory (builder +
        warmup compilation) runs *outside* it so provisioning never
        serializes the pool.  ``gen=True`` registers the instance as
        cold-held by one generation request (closed to joiners until
        :meth:`mark_live`)."""
        try:
            inst = self._factory()
        except BaseException:
            with self._cv:
                self._creating -= 1
                self._cv.notify_all()
            raise
        with self._cv:
            self._creating -= 1
            self._instances.append(inst)
            self._busy.append(inst)
            if gen:
                self._gen_count[id(inst)] = 1
                self._gen_cold.add(id(inst))
        return inst

    def mark_live(self, inst):
        """The cold load on ``inst`` finished: open it to concurrent
        generation joiners (called mid-request via on_live)."""
        with self._cv:
            self._gen_cold.discard(id(inst))
            self._cv.notify_all()

    def release_gen(self, inst, *, logical_now: float = 0.0,
                    cold: Optional[bool] = None):
        """Drop one shared generation hold; the instance returns to the
        idle list (keep-alive clock updated) when the last hold drops."""
        with self._cv:
            gid = id(inst)
            n = self._gen_count.get(gid, 0) - 1
            if n < 0:
                raise ValueError("release_gen without a matching hold")
            if n == 0:
                self._gen_count.pop(gid, None)
                self._gen_cold.discard(gid)
                self._busy.remove(inst)
                self._idle.append(inst)
            else:
                self._gen_count[gid] = n
            self._last_used[gid] = max(
                self._last_used.get(gid, 0.0), logical_now)
            if cold is True:
                self._cold_starts += 1
                self._m_cold.inc()
            elif cold is False:
                self._warm_hits += 1
                self._m_warm.inc()
            self._cv.notify_all()

    def release(self, inst, *, logical_now: float = 0.0,
                cold: Optional[bool] = None):
        with self._cv:
            if inst not in self._busy:
                raise ValueError("release of an instance not acquired")
            self._busy.remove(inst)
            self._idle.append(inst)
            # out-of-order completions must not move the keep-alive
            # clock backwards (a logically-older request finishing late)
            self._last_used[id(inst)] = max(
                self._last_used.get(id(inst), 0.0), logical_now)
            if cold is True:
                self._cold_starts += 1
                self._m_cold.inc()
            elif cold is False:
                self._warm_hits += 1
                self._m_warm.inc()
            self._cv.notify_all()

    def _evict_expired_locked(self, now: float) -> int:
        """Offer idle live instances to the eviction policy (caller
        holds the lock); returns the number evicted."""
        n = 0
        for inst in self._idle:
            if not inst.live:
                continue
            idle_s = now - self._last_used.get(id(inst), now)
            if self.policy.should_evict(idle_s):
                inst.evict()
                n += 1
        self._evictions += n
        if n:
            self._m_evict.inc(n)
        return n

    def sweep(self, now: float) -> int:
        """Run keep-alive eviction over idle live instances; returns the
        number evicted.  Busy instances are never considered."""
        with self._cv:
            return self._evict_expired_locked(now)

    # ----------------------------------------------------------- autoscaling
    def prewarm(self, *, logical_now: Optional[float] = None) -> bool:
        """Provision one warm instance *off the request path* (the
        autoscaler's scale-out action).  Reuses a cold idle container
        when one exists, else scales out up to ``max_instances``; the
        cold-start pipeline then runs on the caller's thread while the
        pool stays unlocked, and the warmed instance returns to the idle
        list ready for the burst.  Returns True when an instance was
        warmed, False when the pool had no capacity or was already fully
        warm."""
        created = False
        with self._cv:
            inst = next((i for i in self._idle if not i.live), None)
            if inst is not None:
                self._idle.remove(inst)
                self._busy.append(inst)
            elif len(self._instances) + self._creating \
                    < self.max_instances:
                self._creating += 1
                created = True
            else:
                return False
        if created:
            inst = self._provision()
        try:
            ensure = getattr(inst, "ensure_live", None)
            warmed = ensure() if ensure is not None else created
        except BaseException:
            # failed load: hand the (still cold) container back so a
            # real request can retry the pipeline with its own batch
            self.release(inst, logical_now=logical_now or 0.0)
            raise
        # cold=None: a prewarm is capacity provisioning, not a served
        # request — it must not count as a cold start or warm hit
        self.release(inst, logical_now=logical_now or 0.0)
        if warmed or created:
            with self._cv:
                self._prewarms += 1
            self._m_prewarm.inc()
            return True
        return False

    def scale_in(self, keep: int, *, now: float = 0.0) -> int:
        """Evict idle live instances until at most ``keep`` live
        instances remain (the autoscaler's scale-in action).  Only
        *idle* instances are touched: busy instances — including every
        instance holding resident generations, which live on the busy
        list until their last shared hold drops — are structurally out
        of reach.  Returns the number evicted."""
        keep = max(0, int(keep))
        with self._cv:
            excess = sum(1 for i in self._instances if i.live) - keep
            n = 0
            for inst in list(self._idle):
                if excess <= 0:
                    break
                if not inst.live:
                    continue
                inst.evict()
                self._last_used.pop(id(inst), None)
                n += 1
                excess -= 1
            self._evictions += n
            if n:
                self._m_evict.inc(n)
            return n

    # -------------------------------------------------------------- queries
    def any_live(self) -> bool:
        """True when some instance holds params (a request routed here
        is warm-servable -> INFERENCE class)."""
        with self._cv:
            return any(i.live for i in self._instances)

    def stats(self) -> PoolStats:
        with self._cv:
            return PoolStats(model=self.model_name,
                             size=len(self._instances),
                             live=sum(1 for i in self._instances if i.live),
                             busy=len(self._busy),
                             cold_starts=self._cold_starts,
                             warm_hits=self._warm_hits,
                             evictions=self._evictions,
                             gen_active=sum(self._gen_count.values()),
                             prewarms=self._prewarms)
