"""Function instances and per-model instance pools.

One :class:`FunctionInstance` models a container: it holds (at most) one
live model.  The first request after provisioning is a **cold start**
and goes through the Cicada pipeline (``ColdStartEngine``) — the
triggering request's inference is computed layer-by-layer *inside* the
loading pipeline.  Subsequent requests are **warm**: direct steady-state
forward.

:class:`InstancePool` owns up to ``max_instances`` containers for one
model function and hands them out under mutual exclusion:

  * a request acquires an instance exclusively, so a cold model hit by
    concurrent requests either rides the one in-flight pipeline
    (followers wait and are served warm) or scales out onto a fresh
    instance — never two pipelines loading into the same container;
  * keep-alive is delegated to an :class:`~repro.serving.policy.
    EvictionPolicy`; :meth:`sweep` offers only *idle* instances to it on
    whatever clock the caller advances (logical trace time in replay);
  * :meth:`stats` exposes cold/warm/eviction counters per pool.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.coldstart import ColdStartEngine, LoadResult
from repro.serving.api import PoolStats
from repro.serving.policy import EvictionPolicy, NeverEvict
from repro.store.cache import WeightCache
from repro.store.store import WeightStore

PyTree = Any


class FunctionInstance:
    """A container with one deployed model function.

    Not internally synchronized: the owning pool guarantees at most one
    request holds an instance between acquire() and release()."""

    def __init__(self, model, model_name: str, store: WeightStore, *,
                 strategy: str = "cicada", io_workers: int = 4,
                 chunk_bytes: int = 1 << 20, warm: bool = True,
                 example_batch: Optional[Dict[str, jax.Array]] = None,
                 cache: Optional[WeightCache] = None):
        self.model = model
        self.model_name = model_name
        self.engine = ColdStartEngine(model, model_name, store,
                                      strategy=strategy,
                                      io_workers=io_workers,
                                      chunk_bytes=chunk_bytes,
                                      cache=cache)
        self.params: Optional[PyTree] = None
        self.last_load: Optional[LoadResult] = None
        self._fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
        if warm and example_batch is not None:
            self.engine.warmup(example_batch)
            # warm the steady-state forward too
            ab = jax.eval_shape(lambda: model.init(jax.random.key(0)))
            zeros = jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), ab)
            jax.block_until_ready(self._fwd(zeros, example_batch))

    @property
    def live(self) -> bool:
        return self.params is not None

    def evict(self):
        self.params = None

    def invoke(self, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, dict]:
        """Returns (logits, {"cold": bool, "load_s": float, "infer_s"})."""
        if not self.live:
            res = self.engine.load(batch)
            self.params = res.params
            self.last_load = res
            return res.logits, {"cold": True,
                                "load_s": res.trace.total_time(),
                                "infer_s": 0.0,
                                "utilization": res.trace.utilization()}
        t0 = time.monotonic()
        logits = jax.block_until_ready(self._fwd(self.params, batch))
        return logits, {"cold": False, "load_s": 0.0,
                        "infer_s": time.monotonic() - t0,
                        "utilization": 1.0}


class InstancePool:
    """Thread-safe pool of FunctionInstances for one model function."""

    def __init__(self, model_name: str,
                 builder: Callable[[], Tuple[Any, Dict]],
                 store: Optional[WeightStore] = None, *,
                 strategy: str = "cicada",
                 policy: Optional[EvictionPolicy] = None,
                 max_instances: int = 1, io_workers: int = 4,
                 chunk_bytes: int = 1 << 20,
                 instance_factory: Optional[Callable[[], Any]] = None,
                 cache: Optional[WeightCache] = None):
        """builder: () -> (model, example_batch).  ``instance_factory``
        overrides container provisioning (tests / future remote pools);
        the default builds a warmed FunctionInstance.  ``cache``: one
        node-local WeightCache shared by every instance of this pool
        (and, via the platform, across pools) — concurrent scale-out
        cold starts then single-flight each unit's store read."""
        self.model_name = model_name
        self.policy = policy if policy is not None else NeverEvict()
        self.max_instances = max(1, int(max_instances))
        self.cache = cache
        self._builder = builder
        self._store = store
        self._strategy = strategy
        self._io_workers = io_workers
        self._chunk_bytes = chunk_bytes
        self._factory = instance_factory or self._default_factory
        self._cv = threading.Condition()
        self._instances: List[Any] = []
        self._idle: List[Any] = []
        self._busy: List[Any] = []
        self._creating = 0
        self._last_used: Dict[int, float] = {}     # id(inst) -> logical t
        self._cold_starts = 0
        self._warm_hits = 0
        self._evictions = 0

    def _default_factory(self):
        model, example = self._builder()
        return FunctionInstance(model, self.model_name, self._store,
                                strategy=self._strategy,
                                io_workers=self._io_workers,
                                chunk_bytes=self._chunk_bytes,
                                example_batch=example,
                                cache=self.cache)

    # ------------------------------------------------------------ lifecycle
    def acquire(self, *, timeout: Optional[float] = None,
                logical_now: Optional[float] = None):
        """Reserve an instance exclusively.  Preference order: a warm
        (live) idle instance, then a cold idle one, then scale-out up to
        ``max_instances``; otherwise block until a release.

        ``logical_now``: the requester's logical arrival time — idle
        instances whose keep-alive expired *before* this request are
        evicted here rather than reused warm, so eviction semantics
        stay per-request faithful even when replay runs far ahead of
        the logical clock (concurrent as-fast-as-possible replay)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                if logical_now is not None:
                    self._evict_expired(logical_now)
                inst = next((i for i in self._idle if i.live), None)
                if inst is None and self._idle:
                    inst = self._idle[0]
                if inst is not None:
                    self._idle.remove(inst)
                    self._busy.append(inst)
                    return inst
                if len(self._instances) + self._creating \
                        < self.max_instances:
                    self._creating += 1
                    break
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"pool {self.model_name!r} saturated "
                        f"({self.max_instances} instances busy)")
                self._cv.wait(remaining)
        # Provision outside the lock: builder() + warmup compilation are
        # expensive and must not serialize the pool.
        try:
            inst = self._factory()
        except BaseException:
            with self._cv:
                self._creating -= 1
                self._cv.notify_all()
            raise
        with self._cv:
            self._creating -= 1
            self._instances.append(inst)
            self._busy.append(inst)
        return inst

    def release(self, inst, *, logical_now: float = 0.0,
                cold: Optional[bool] = None):
        with self._cv:
            if inst not in self._busy:
                raise ValueError("release of an instance not acquired")
            self._busy.remove(inst)
            self._idle.append(inst)
            # out-of-order completions must not move the keep-alive
            # clock backwards (a logically-older request finishing late)
            self._last_used[id(inst)] = max(
                self._last_used.get(id(inst), 0.0), logical_now)
            if cold is True:
                self._cold_starts += 1
            elif cold is False:
                self._warm_hits += 1
            self._cv.notify_all()

    def _evict_expired(self, now: float) -> int:
        """Offer idle live instances to the eviction policy (caller
        holds the lock); returns the number evicted."""
        n = 0
        for inst in self._idle:
            if not inst.live:
                continue
            idle_s = now - self._last_used.get(id(inst), now)
            if self.policy.should_evict(idle_s):
                inst.evict()
                n += 1
        self._evictions += n
        return n

    def sweep(self, now: float) -> int:
        """Run keep-alive eviction over idle live instances; returns the
        number evicted.  Busy instances are never considered."""
        with self._cv:
            return self._evict_expired(now)

    # -------------------------------------------------------------- queries
    def any_live(self) -> bool:
        """True when some instance holds params (a request routed here
        is warm-servable -> INFERENCE class)."""
        with self._cv:
            return any(i.live for i in self._instances)

    def stats(self) -> PoolStats:
        with self._cv:
            return PoolStats(model=self.model_name,
                             size=len(self._instances),
                             live=sum(1 for i in self._instances if i.live),
                             busy=len(self._busy),
                             cold_starts=self._cold_starts,
                             warm_hits=self._warm_hits,
                             evictions=self._evictions)
