"""Serverless serving surface (generation-first).

  api      Request / GenerateSpec / RequestClass / Response / stats +
           typed errors (UnknownModelError, CacheOverflowError)
  autoscale  SLO autoscaler: arrival-rate slope + queue depth drive
           pool prewarm / scale-in (repro.metrics is the signal source)
  decode   DecodeScheduler: slot-based continuous-batching decode
           engine + the serial reference_generate oracle
  policy   keep-alive eviction policies (TTL, never-evict)
  pool     FunctionInstance (owns a DecodeScheduler when live) +
           per-model InstancePool (exclusive + shared-generation holds)
  router   thread-safe Router: admission control, priority dispatch,
           generation requests join running decode batches
  engine   ServerlessPlatform (trace replay on the Router, one-shot or
           generation) + the BatchedLMServer compat shim
  trace    bursty Azure-like invocation workload generator

The node-local WeightCache (repro.store.cache) is re-exported here:
one cache per platform makes scale-out cold starts reuse resident
weights and single-flight store reads.
"""
from repro.serving.api import (AdmissionError, CacheOverflowError,  # noqa: F401
                               GenerateSpec, PoolStats, Request,
                               RequestClass, Response, RouterStats,
                               UnknownModelError)
from repro.serving.decode import (DecodeScheduler, GenResult,  # noqa: F401
                                  reference_generate)
from repro.serving.autoscale import Autoscaler  # noqa: F401
from repro.serving.policy import (EvictionPolicy, KeepAliveTTL,  # noqa: F401
                                  NeverEvict, make_policy)
from repro.serving.pool import FunctionInstance, InstancePool  # noqa: F401
from repro.serving.router import Router  # noqa: F401
from repro.serving.engine import (BatchedLMServer,  # noqa: F401
                                  ServerlessPlatform)
from repro.store.cache import CacheStats, WeightCache  # noqa: F401
