"""Serverless serving surface.

  api      Request / RequestClass / Response / stats data model
  policy   keep-alive eviction policies (TTL, never-evict)
  pool     FunctionInstance + per-model InstancePool
  router   thread-safe Router: admission control, priority dispatch
  engine   ServerlessPlatform (trace replay on the Router) + LM server
  trace    bursty Azure-like invocation workload generator
"""
from repro.serving.api import (AdmissionError, PoolStats, Request,  # noqa: F401
                               RequestClass, Response, RouterStats)
from repro.serving.policy import (EvictionPolicy, KeepAliveTTL,  # noqa: F401
                                  NeverEvict, make_policy)
from repro.serving.pool import FunctionInstance, InstancePool  # noqa: F401
from repro.serving.router import Router  # noqa: F401
from repro.serving.engine import (BatchedLMServer,  # noqa: F401
                                  ServerlessPlatform)
