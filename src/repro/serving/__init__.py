"""Serverless serving surface.

  api      Request / RequestClass / Response / stats data model
  policy   keep-alive eviction policies (TTL, never-evict)
  pool     FunctionInstance + per-model InstancePool
  router   thread-safe Router: admission control, priority dispatch
  engine   ServerlessPlatform (trace replay on the Router) + LM server
  trace    bursty Azure-like invocation workload generator

The node-local WeightCache (repro.store.cache) is re-exported here:
one cache per platform makes scale-out cold starts reuse resident
weights and single-flight store reads.
"""
from repro.serving.api import (AdmissionError, PoolStats, Request,  # noqa: F401
                               RequestClass, Response, RouterStats)
from repro.serving.policy import (EvictionPolicy, KeepAliveTTL,  # noqa: F401
                                  NeverEvict, make_policy)
from repro.serving.pool import FunctionInstance, InstancePool  # noqa: F401
from repro.serving.router import Router  # noqa: F401
from repro.serving.engine import (BatchedLMServer,  # noqa: F401
                                  ServerlessPlatform)
from repro.store.cache import CacheStats, WeightCache  # noqa: F401
