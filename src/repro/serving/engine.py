"""Serverless serving engine: trace replay on the Router/InstancePool
platform API, plus the compat LM-server shim.

:class:`ServerlessPlatform` wires one :class:`InstancePool` per deployed
model behind a :class:`Router` and replays invocation traces through it.
``run_trace(..., concurrency=N)`` admits up to N invocations
concurrently (N router workers); ``concurrency=1`` reproduces the
seed's strictly serial replay semantics exactly.  ``run_trace(...,
make_spec=...)`` replays the trace as *generation* requests — each
invocation decodes through the instances' continuous-batching
DecodeSchedulers and its Response carries tokens / TTFT / TPOT.
Keep-alive accounting runs on the trace's *logical* clock regardless of
replay speed: before each submission the platform sweeps every pool,
and the eviction policy (default: the seed's TTL rule) reclaims idle
instances — re-triggering cold starts, the serverless lifecycle of the
paper's Fig. 2.

The classes the old API exposed (``FunctionInstance``, ``Response``,
``BatchedLMServer``) are re-exported / shimmed here so existing
benchmarks and examples run unmodified.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro import metrics as metrics_mod
from repro.serving.api import GenerateSpec, Request, Response  # noqa: F401
from repro.serving.autoscale import Autoscaler  # noqa: F401
from repro.serving.decode import DecodeScheduler, reference_generate  # noqa: F401
from repro.serving.policy import EvictionPolicy, make_policy
from repro.serving.pool import FunctionInstance, InstancePool  # noqa: F401
from repro.serving.router import Router
from repro.store.cache import CacheStats, WeightCache
from repro.store.store import WeightStore

PyTree = Any


class ServerlessPlatform:
    """Trace-driven multi-function platform (one pool per model)."""

    def __init__(self, store: WeightStore,
                 builders: Dict[str, Callable[[], tuple]], *,
                 strategy: str = "cicada", keep_alive_s: float = 60.0,
                 io_workers: int = 4, chunk_bytes: int = 1 << 20,
                 max_instances: int = 1,
                 policy: Optional[EvictionPolicy] = None,
                 cache_budget_bytes: Optional[int] = None,
                 cache: Optional[WeightCache] = None,
                 gen_slots: int = 8, gen_cache_len: int = 256,
                 kv_page_tokens: Optional[int] = None,
                 kv_budget_bytes: Optional[int] = None,
                 mesh_shape=None, rules=None, compute_quant: bool = False,
                 metrics: Optional[metrics_mod.MetricsRegistry] = None,
                 autoscale: Optional[Dict[str, Any]] = None,
                 source=None):
        """builders: model_name -> () -> (model, example_batch).

        cache_budget_bytes: enable ONE node-local WeightCache shared by
        every pool — scale-out and re-triggered cold starts then reuse
        already-resident unit leaves and single-flight store reads
        (None -> no cache, seed behaviour; 0 -> unbounded).  Pass
        ``cache`` to share an externally-owned cache instead (e.g. one
        cache across several platforms on a node).

        gen_slots / gen_cache_len: per-instance continuous-batching
        capacity — up to gen_slots concurrent generation requests share
        one slotted KV cache of gen_cache_len positions per slot.

        kv_page_tokens / kv_budget_bytes: block-paged decode KV — every
        instance's scheduler serves full-attention KV from a shared
        refcounted page pool (kv_page_tokens positions per page, pool
        sized by kv_budget_bytes; None -> slotted-arena-equivalent page
        count).  Mixed prompt lengths admit against the page budget
        instead of a per-slot ceiling, and requests sharing a prompt
        prefix pin the same physical pages (prefill skips the shared
        span).  ``kv.*`` gauges/counters land in metrics_snapshot().

        mesh_shape / rules: shard-granular cold starts — every
        instance's pipeline streams weights onto a ``(data, model)``
        device mesh of this shape (one byte-range retrieval stream per
        device; with the shared cache, keyed per shard) and serves warm
        requests from the mesh-sharded params.  ``4`` == ``(1, 4)``;
        rules defaults to the serving TP rules.

        compute_quant: serve int8-deployed models *quantized-resident* —
        cold starts keep the int8 values + scales as QuantLeaf params
        (≈quarter the f32 residency) and forwards run through the
        fused-dequant ``quant_matmul`` kernel.  Single-device only
        (incompatible with mesh_shape).

        metrics: registry behind :meth:`metrics_snapshot`; defaults to a
        *private* registry so each platform's snapshot is isolated from
        other platforms (and stray components) in the process.

        autoscale: when not None, build an
        :class:`~repro.serving.autoscale.Autoscaler` over this
        platform's pools with these kwargs (e.g.
        ``dict(rps_per_instance=2.0, min_warm=1)``; ``{}`` for
        defaults).  The autoscaler is attached to every Router this
        platform creates; drive it with ``platform.autoscaler.start()``
        (background ticks) or explicit ``tick()`` calls.

        source: ShardSource wired into every pool's cold-start
        retrieval streams — a cluster Node passes its peer-exchange
        tier here (see :mod:`repro.cluster`); requires a cache.
        """
        self.store = store
        self.strategy = strategy
        self.metrics = metrics if metrics is not None \
            else metrics_mod.MetricsRegistry()
        self.policy = policy if policy is not None \
            else make_policy(keep_alive_s)
        if cache is None and cache_budget_bytes is not None:
            cache = WeightCache(cache_budget_bytes, metrics=self.metrics)
        self.cache = cache
        self.source = source
        self.mesh_shape = mesh_shape
        self.pools: Dict[str, InstancePool] = {
            name: InstancePool(name, builder, store, strategy=strategy,
                               policy=self.policy,
                               max_instances=max_instances,
                               io_workers=io_workers,
                               chunk_bytes=chunk_bytes,
                               cache=self.cache,
                               gen_slots=gen_slots,
                               gen_cache_len=gen_cache_len,
                               kv_page_tokens=kv_page_tokens,
                               kv_budget_bytes=kv_budget_bytes,
                               mesh_shape=mesh_shape, rules=rules,
                               compute_quant=compute_quant,
                               metrics=self.metrics,
                               source=source)
            for name, builder in builders.items()}
        self.autoscaler: Optional[Autoscaler] = None
        if autoscale is not None:
            self.autoscaler = Autoscaler(self.pools, metrics=self.metrics,
                                         **autoscale)
        self.last_router_stats = None      # RouterStats of the last replay

    def router(self, *, workers: int = 4,
               max_pending: Optional[int] = None) -> Router:
        """A live Router over this platform's pools (caller shuts down)."""
        return Router(self.pools, workers=workers, max_pending=max_pending,
                      cache=self.cache, metrics=self.metrics,
                      autoscaler=self.autoscaler)

    def cache_stats(self) -> Optional[CacheStats]:
        """Counters of the shared node-local WeightCache (None when
        serving cache-less)."""
        return self.cache.stats() if self.cache is not None else None

    def metrics_snapshot(self) -> Dict[str, Any]:
        """The scrapeable observability surface: every live instrument
        (counters / gauges / histograms) plus point-in-time instance
        states refreshed from the pools at snapshot time."""
        for name, pool in self.pools.items():
            st = pool.stats()
            g = self.metrics.gauge
            g(f"pool/{name}/instances").set(st.size)
            g(f"pool/{name}/live").set(st.live)
            g(f"pool/{name}/busy").set(st.busy)
            g(f"pool/{name}/gen_active").set(st.gen_active)
        if self.cache is not None:
            cs = self.cache.stats()
            self.metrics.gauge("weight_cache/bytes").set(cs.bytes_cached)
            self.metrics.gauge("weight_cache/entries").set(cs.entries)
        return self.metrics.snapshot()

    def sweep(self, logical_now: float) -> int:
        """Run keep-alive eviction across all pools (idle instances
        only); returns the number of instances reclaimed."""
        return sum(p.sweep(logical_now) for p in self.pools.values())

    def pool_stats(self) -> Dict[str, Any]:
        return {name: p.stats() for name, p in self.pools.items()}

    def run_trace(self, invocations, make_batch,
                  *, time_scale: float = 0.0,
                  concurrency: int = 1,
                  make_spec: Optional[Callable[[str], GenerateSpec]] = None
                  ) -> List[Response]:
        """Replay a trace.  time_scale=0 -> as-fast-as-possible (arrival
        gaps are skipped but keep-alive accounting still uses the
        *logical* clock); >0 -> sleep scaled real time between arrivals.

        concurrency=1 replays strictly serially (seed semantics:
        ``latency_s`` measures the invocation only — instance
        provisioning and queue wait are reported in ``queue_s``);
        concurrency=N>1 keeps up to N invocations in flight through
        the Router's worker pool.  Keep-alive stays logical-clock
        faithful per request: expired idle instances are evicted at
        acquire time against the *requester's* arrival time, though an
        instance kept busy by overlapping requests counts as
        continuously active (so cold/warm mixes can differ from serial
        replay under contention).

        make_spec: model_name -> GenerateSpec.  When given, the trace
        replays as *generation* requests (make_batch is unused) —
        concurrent invocations of one model join its instance's decode
        scheduler and batch dynamically; each Response carries tokens,
        ttft_s and tpot_s.
        """
        router = self.router(workers=max(1, concurrency))
        try:
            futures = []
            logical_prev = None
            clock = 0.0
            for inv in invocations:
                if logical_prev is not None:
                    gap = inv.t - logical_prev
                    clock += gap
                    if time_scale > 0:
                        time.sleep(gap * time_scale)
                logical_prev = inv.t
                # logical keep-alive: evict instances idle past the TTL
                self.sweep(clock)
                if make_spec is not None:
                    req = Request(req_id=inv.req_id, model=inv.model,
                                  gen=make_spec(inv.model), t_logical=clock)
                else:
                    req = Request(req_id=inv.req_id, model=inv.model,
                                  batch=make_batch(inv.model),
                                  t_logical=clock)
                fut = router.submit(req)
                futures.append(fut)
                if concurrency <= 1:
                    fut.result()           # strict serial replay
            return [f.result() for f in futures]
        finally:
            router.shutdown()
            self.last_router_stats = router.stats


# ---------------------------------------------------------------------------
# LM batched serving — compat shim over the DecodeScheduler
# ---------------------------------------------------------------------------

class BatchedLMServer:
    """Compat shim: the old static-batch server surface, served by the
    slot-based continuous-batching :class:`DecodeScheduler`.

    Differences from the old implementation (both deliberate fixes):
    ``max_batch`` is *honored* as the scheduler's slot count (it was a
    dead knob), and a prompt+n_new that overflows ``cache_len`` raises
    :class:`~repro.serving.api.CacheOverflowError` instead of silently
    wrapping/dropping KV entries past the cache end."""

    def __init__(self, model, params: PyTree, *, max_batch: int = 8,
                 cache_len: int = 256):
        self.model = model
        self.params = params
        self.max_batch = int(max_batch)
        self.cache_len = int(cache_len)
        self.scheduler = DecodeScheduler(model, params, n_slots=max_batch,
                                         cache_len=cache_len)

    def generate(self, tokens: jax.Array, *, n_new: int,
                 greedy: bool = True, temperature: float = 1.0,
                 seed: int = 0) -> jax.Array:
        """tokens: (B, S) prompt batch -> (B, n_new) generated ids.

        Rows are submitted as B concurrent generation requests, so they
        decode as one continuous batch through the shared slotted KV
        cache (the old server stepped a private static batch)."""
        B, S = tokens.shape
        if B > self.max_batch:
            raise ValueError(
                f"batch {B} exceeds max_batch={self.max_batch} "
                f"(the scheduler's slot count)")
        specs = [GenerateSpec(prompt=tokens[b], n_new=n_new,
                              temperature=0.0 if greedy else temperature,
                              seed=seed + b)
                 for b in range(B)]
        if B == 1:
            rows = [self.scheduler.generate(specs[0]).tokens]
        else:
            with ThreadPoolExecutor(max_workers=B) as ex:
                rows = list(ex.map(
                    lambda s: self.scheduler.generate(s).tokens, specs))
        return jnp.asarray(rows, jnp.int32)
