"""Serverless serving engine.

One :class:`FunctionInstance` models a container: it holds (at most) one
live model.  The first request after provisioning is a **cold start**
and goes through the Cicada pipeline (``ColdStartEngine``) — the
triggering request's inference is computed layer-by-layer *inside* the
loading pipeline, so its latency is the pipeline's end-to-end time.
Subsequent requests are **warm**: direct steady-state forward (batched
prefill + decode for LMs).

:class:`ServerlessPlatform` maps invocations to instances with a
keep-alive policy (idle instances are reclaimed after ``keep_alive_s``,
re-triggering cold starts — the serverless lifecycle the paper's Fig. 2
describes).  Inference execution is given strict priority over
background loading I/O: while a warm request is executing, newly issued
retrieval streams for other instances start paused and resume after the
step (the Priority-Aware Scheduler's "inference first" rule).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coldstart import ColdStartEngine, LoadResult
from repro.store.store import WeightStore

PyTree = Any


@dataclasses.dataclass
class Response:
    req_id: int
    model: str
    cold: bool
    t_arrival: float
    t_done: float
    load_s: float           # cold-start pipeline time (0 for warm)
    infer_s: float          # steady-state inference time (warm requests)
    utilization: float      # pipeline utilization (cold requests)

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival


class FunctionInstance:
    """A container with one deployed model function."""

    def __init__(self, model, model_name: str, store: WeightStore, *,
                 strategy: str = "cicada", io_workers: int = 4,
                 chunk_bytes: int = 1 << 20, warm: bool = True,
                 example_batch: Optional[Dict[str, jax.Array]] = None):
        self.model = model
        self.model_name = model_name
        self.engine = ColdStartEngine(model, model_name, store,
                                      strategy=strategy,
                                      io_workers=io_workers,
                                      chunk_bytes=chunk_bytes)
        self.params: Optional[PyTree] = None
        self.last_used = time.monotonic()
        self.last_load: Optional[LoadResult] = None
        self._fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
        if warm and example_batch is not None:
            self.engine.warmup(example_batch)
            # warm the steady-state forward too
            ab = jax.eval_shape(lambda: model.init(jax.random.key(0)))
            zeros = jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), ab)
            jax.block_until_ready(self._fwd(zeros, example_batch))

    @property
    def live(self) -> bool:
        return self.params is not None

    def evict(self):
        self.params = None

    def invoke(self, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, dict]:
        """Returns (logits, {"cold": bool, "load_s": float, "infer_s"})."""
        self.last_used = time.monotonic()
        if not self.live:
            res = self.engine.load(batch)
            self.params = res.params
            self.last_load = res
            return res.logits, {"cold": True,
                                "load_s": res.trace.total_time(),
                                "infer_s": 0.0,
                                "utilization": res.trace.utilization()}
        t0 = time.monotonic()
        logits = jax.block_until_ready(self._fwd(self.params, batch))
        return logits, {"cold": False, "load_s": 0.0,
                        "infer_s": time.monotonic() - t0,
                        "utilization": 1.0}


class ServerlessPlatform:
    """Trace-driven multi-function platform (one instance per model)."""

    def __init__(self, store: WeightStore,
                 builders: Dict[str, Callable[[], Tuple[Any, Dict]]], *,
                 strategy: str = "cicada", keep_alive_s: float = 60.0,
                 io_workers: int = 4, chunk_bytes: int = 1 << 20):
        """builders: model_name -> () -> (model, example_batch)."""
        self.store = store
        self.strategy = strategy
        self.keep_alive_s = keep_alive_s
        self.io_workers = io_workers
        self.chunk_bytes = chunk_bytes
        self._builders = builders
        self._instances: Dict[str, FunctionInstance] = {}

    def _instance(self, model_name: str) -> FunctionInstance:
        if model_name not in self._instances:
            model, example = self._builders[model_name]()
            self._instances[model_name] = FunctionInstance(
                model, model_name, self.store, strategy=self.strategy,
                io_workers=self.io_workers, chunk_bytes=self.chunk_bytes,
                example_batch=example)
        return self._instances[model_name]

    def _reap(self, now: float):
        for inst in self._instances.values():
            if inst.live and now - inst.last_used > self.keep_alive_s:
                inst.evict()

    def run_trace(self, invocations, make_batch,
                  *, time_scale: float = 0.0) -> List[Response]:
        """Replay a trace.  time_scale=0 -> as-fast-as-possible (arrival
        gaps are skipped but keep-alive accounting still uses the
        *logical* clock); >0 -> sleep scaled real time between arrivals.
        """
        out: List[Response] = []
        logical_prev = None
        clock = 0.0
        for inv in invocations:
            if logical_prev is not None:
                gap = inv.t - logical_prev
                clock += gap
                if time_scale > 0:
                    time.sleep(gap * time_scale)
            logical_prev = inv.t
            # logical keep-alive: evict instances idle longer than TTL
            for inst in self._instances.values():
                if inst.live and getattr(inst, "_logical_last", 0.0) \
                        + self.keep_alive_s < clock:
                    inst.evict()
            inst = self._instance(inv.model)
            batch = make_batch(inv.model)
            t_arr = time.monotonic()
            _, info = inst.invoke(batch)
            t_done = time.monotonic()
            inst._logical_last = clock
            out.append(Response(inv.req_id, inv.model, info["cold"],
                                t_arr, t_done, info["load_s"],
                                info["infer_s"], info["utilization"]))
        return out


# ---------------------------------------------------------------------------
# LM batched serving (prefill + decode loop) — steady-state path
# ---------------------------------------------------------------------------

class BatchedLMServer:
    """Simple continuous-batching decode server for a live LM."""

    def __init__(self, model, params: PyTree, *, max_batch: int = 8,
                 cache_len: int = 256):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def generate(self, tokens: jax.Array, *, n_new: int,
                 greedy: bool = True) -> jax.Array:
        """tokens: (B, S) prompt batch -> (B, n_new) generated ids."""
        B, S = tokens.shape
        cache = self.model.init_cache(B, self.cache_len)
        logits, cache = self._prefill(self.params, {"tokens": tokens}, cache)
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outs = [cur]
        for t in range(S, S + n_new - 1):
            pos = jnp.full((B,), t, jnp.int32)
            logits, cache = self._decode(self.params, cache, cur, pos)
            cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            outs.append(cur)
        return jnp.concatenate(outs, axis=1)
