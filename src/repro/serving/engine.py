"""Serverless serving engine: trace replay on the Router/InstancePool
platform API, plus the steady-state batched LM server.

:class:`ServerlessPlatform` wires one :class:`InstancePool` per deployed
model behind a :class:`Router` and replays invocation traces through it.
``run_trace(..., concurrency=N)`` admits up to N invocations
concurrently (N router workers); ``concurrency=1`` reproduces the
seed's strictly serial replay semantics exactly.  Keep-alive accounting
runs on the trace's *logical* clock regardless of replay speed: before
each submission the platform sweeps every pool, and the eviction policy
(default: the seed's TTL rule) reclaims idle instances — re-triggering
cold starts, the serverless lifecycle of the paper's Fig. 2.

The classes the old API exposed (``FunctionInstance``, ``Response``)
are re-exported here so existing benchmarks and examples run unmodified.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.serving.api import Request, Response  # noqa: F401 (re-export)
from repro.serving.policy import EvictionPolicy, make_policy
from repro.serving.pool import FunctionInstance, InstancePool  # noqa: F401
from repro.serving.router import Router
from repro.store.cache import CacheStats, WeightCache
from repro.store.store import WeightStore

PyTree = Any


class ServerlessPlatform:
    """Trace-driven multi-function platform (one pool per model)."""

    def __init__(self, store: WeightStore,
                 builders: Dict[str, Callable[[], tuple]], *,
                 strategy: str = "cicada", keep_alive_s: float = 60.0,
                 io_workers: int = 4, chunk_bytes: int = 1 << 20,
                 max_instances: int = 1,
                 policy: Optional[EvictionPolicy] = None,
                 cache_budget_bytes: Optional[int] = None,
                 cache: Optional[WeightCache] = None):
        """builders: model_name -> () -> (model, example_batch).

        cache_budget_bytes: enable ONE node-local WeightCache shared by
        every pool — scale-out and re-triggered cold starts then reuse
        already-resident unit leaves and single-flight store reads
        (None -> no cache, seed behaviour; 0 -> unbounded).  Pass
        ``cache`` to share an externally-owned cache instead (e.g. one
        cache across several platforms on a node).
        """
        self.store = store
        self.strategy = strategy
        self.policy = policy if policy is not None \
            else make_policy(keep_alive_s)
        if cache is None and cache_budget_bytes is not None:
            cache = WeightCache(cache_budget_bytes)
        self.cache = cache
        self.pools: Dict[str, InstancePool] = {
            name: InstancePool(name, builder, store, strategy=strategy,
                               policy=self.policy,
                               max_instances=max_instances,
                               io_workers=io_workers,
                               chunk_bytes=chunk_bytes,
                               cache=self.cache)
            for name, builder in builders.items()}
        self.last_router_stats = None      # RouterStats of the last replay

    def router(self, *, workers: int = 4,
               max_pending: Optional[int] = None) -> Router:
        """A live Router over this platform's pools (caller shuts down)."""
        return Router(self.pools, workers=workers, max_pending=max_pending,
                      cache=self.cache)

    def cache_stats(self) -> Optional[CacheStats]:
        """Counters of the shared node-local WeightCache (None when
        serving cache-less)."""
        return self.cache.stats() if self.cache is not None else None

    def sweep(self, logical_now: float) -> int:
        """Run keep-alive eviction across all pools (idle instances
        only); returns the number of instances reclaimed."""
        return sum(p.sweep(logical_now) for p in self.pools.values())

    def pool_stats(self) -> Dict[str, Any]:
        return {name: p.stats() for name, p in self.pools.items()}

    def run_trace(self, invocations, make_batch,
                  *, time_scale: float = 0.0,
                  concurrency: int = 1) -> List[Response]:
        """Replay a trace.  time_scale=0 -> as-fast-as-possible (arrival
        gaps are skipped but keep-alive accounting still uses the
        *logical* clock); >0 -> sleep scaled real time between arrivals.

        concurrency=1 replays strictly serially (seed semantics:
        ``latency_s`` measures the invocation only — instance
        provisioning and queue wait are reported in ``queue_s``);
        concurrency=N>1 keeps up to N invocations in flight through
        the Router's worker pool.  Keep-alive stays logical-clock
        faithful per request: expired idle instances are evicted at
        acquire time against the *requester's* arrival time, though an
        instance kept busy by overlapping requests counts as
        continuously active (so cold/warm mixes can differ from serial
        replay under contention).
        """
        router = self.router(workers=max(1, concurrency))
        try:
            futures = []
            logical_prev = None
            clock = 0.0
            for inv in invocations:
                if logical_prev is not None:
                    gap = inv.t - logical_prev
                    clock += gap
                    if time_scale > 0:
                        time.sleep(gap * time_scale)
                logical_prev = inv.t
                # logical keep-alive: evict instances idle past the TTL
                self.sweep(clock)
                fut = router.submit(Request(
                    req_id=inv.req_id, model=inv.model,
                    batch=make_batch(inv.model), t_logical=clock))
                futures.append(fut)
                if concurrency <= 1:
                    fut.result()           # strict serial replay
            return [f.result() for f in futures]
        finally:
            router.shutdown()
            self.last_router_stats = router.stats


# ---------------------------------------------------------------------------
# LM batched serving (prefill + decode loop) — steady-state path
# ---------------------------------------------------------------------------

class BatchedLMServer:
    """Simple continuous-batching decode server for a live LM."""

    def __init__(self, model, params: PyTree, *, max_batch: int = 8,
                 cache_len: int = 256):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def generate(self, tokens: jax.Array, *, n_new: int,
                 greedy: bool = True) -> jax.Array:
        """tokens: (B, S) prompt batch -> (B, n_new) generated ids."""
        B, S = tokens.shape
        cache = self.model.init_cache(B, self.cache_len)
        logits, cache = self._prefill(self.params, {"tokens": tokens}, cache)
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outs = [cur]
        for t in range(S, S + n_new - 1):
            pos = jnp.full((B,), t, jnp.int32)
            logits, cache = self._decode(self.params, cache, cur, pos)
            cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            outs.append(cur)
        return jnp.concatenate(outs, axis=1)
