"""Slot-based continuous-batching decode engine.

One :class:`DecodeScheduler` is owned by each live
:class:`~repro.serving.pool.FunctionInstance`.  It holds a single
fixed-capacity *slotted* KV cache — ``init_cache(n_slots, cache_len)``
— and decodes every resident generation request with one shared jitted
step, whatever the slot occupancy:

  * a request **joins** at a step boundary: its prompt is prefilled into
    a fresh ``B=1`` cache on the calling thread, then merged into a free
    slot between two batch steps (an in-flight step never observes a
    half-written slot);
  * a request **leaves** on completion or EOS, freeing its slot for the
    next joiner — requests arriving at different times batch dynamically
    instead of serializing;
  * the batched step is **cooperatively driven**: every caller thread
    blocked in :meth:`generate` is eligible to run the next step, so the
    engine needs no dedicated decode thread and quiesces for free when
    no request is resident.

Correctness invariant (enforced by tests/test_generate.py): each
request's token sequence is *bit-identical* to :func:`reference_generate`
— a serial ``prefill`` + ``decode_step`` loop at ``B=1`` — because every
per-slot computation (attention over its own cache rows, per-row MoE
dispatch, SSM/RG-LRU state updates, sampling keyed by seed+position) is
independent of what the other slots hold.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import analysis, metrics as metrics_mod
from repro.kernels import ops
from repro.serving import kvpages
from repro.serving.api import CacheOverflowError, GenerateSpec

PyTree = Any


# ---------------------------------------------------------------------------
# sampling — one rule shared by the batched step, the first-token path
# (warm prefill AND the in-pipeline cold path) and the serial reference
# ---------------------------------------------------------------------------

def sample_tokens(logits: jax.Array, seed: jax.Array, next_pos: jax.Array,
                  temperature: jax.Array) -> jax.Array:
    """Per-row next-token choice.  logits: (B, V); seed/next_pos/
    temperature: (B,).  temperature == 0 -> greedy argmax; > 0 ->
    categorical over logits/temperature keyed by fold_in(seed, next_pos)
    — deterministic per request and independent of co-resident rows.
    """
    def _row(lg, sd, p, t):
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(jax.random.PRNGKey(sd), p)
        scaled = lg.astype(jnp.float32) / jnp.maximum(t, 1e-6)
        sampled = jax.random.categorical(key, scaled).astype(jnp.int32)
        return jnp.where(t > 0, sampled, greedy)

    return jax.vmap(_row)(logits, seed, next_pos, temperature)


def sample_first(logits, spec: GenerateSpec, n_prompt: int) -> int:
    """First token from full-prompt logits ((1, S, V): prefill output or
    the cold pipeline's in-flight forward)."""
    return int(sample_tokens(
        logits[:, -1, :],
        jnp.asarray([spec.seed], jnp.uint32),
        jnp.asarray([n_prompt], jnp.int32),
        jnp.asarray([spec.temperature], jnp.float32))[0])


def validate_spec(spec: GenerateSpec, n_prompt: int, cache_len: int) -> int:
    """Clamp n_new to the per-request max_len and validate against the
    KV cache capacity; returns the effective n_new.

    This replaces the old ``BatchedLMServer.generate`` behaviour of
    silently wrapping/dropping KV entries once S + n_new overran
    cache_len."""
    n_new = int(spec.n_new)
    if n_new < 1:
        raise ValueError(f"n_new must be >= 1, got {spec.n_new}")
    if spec.max_len is not None:
        n_new = min(n_new, int(spec.max_len) - n_prompt)
        if n_new < 1:
            raise CacheOverflowError(
                f"max_len={spec.max_len} leaves no room to generate "
                f"after a {n_prompt}-token prompt")
    if n_prompt + n_new > cache_len:
        raise CacheOverflowError(
            f"prompt ({n_prompt}) + n_new ({n_new}) = {n_prompt + n_new} "
            f"tokens overflow the decode cache (cache_len={cache_len}); "
            f"lower n_new / set max_len <= {cache_len} or provision a "
            f"larger cache")
    return n_new


def validate_spec_paged(spec: GenerateSpec, n_prompt: int, *,
                        page_tokens: int, n_pages: int,
                        stats: Optional["kvpages.KVPageStats"] = None) -> int:
    """Paged-mode admission check: the only *error* is a request that
    could never fit the page budget (everything smaller is blocking
    backpressure in the pool, not an exception).  Returns the effective
    n_new.  ``n_pages`` is the per-request page ceiling — min(pool
    budget, page-table width)."""
    n_new = int(spec.n_new)
    if n_new < 1:
        raise ValueError(f"n_new must be >= 1, got {spec.n_new}")
    if spec.max_len is not None:
        n_new = min(n_new, int(spec.max_len) - n_prompt)
        if n_new < 1:
            raise CacheOverflowError(
                f"max_len={spec.max_len} leaves no room to generate "
                f"after a {n_prompt}-token prompt")
    need = -(-(n_prompt + n_new) // page_tokens)
    if need > n_pages:
        occ = ""
        if stats is not None:
            occ = (f"; live occupancy {stats.used}/{stats.total} pages "
                   f"({stats.pinned} pinned, {stats.cached} cached)")
        raise CacheOverflowError(
            f"prompt ({n_prompt}) + n_new ({n_new}) needs {need} KV pages "
            f"but the per-request budget is {n_pages} pages x "
            f"{page_tokens} tokens = {n_pages * page_tokens} tokens{occ}; "
            f"lower n_new / set max_len or raise the page budget "
            f"(--kv-budget-mb)")
    return n_new


def paged_page_count(model, *, page_tokens: int,
                     budget_bytes: Optional[int] = None,
                     n_slots: int = 8, cache_len: int = 256) -> int:
    """Page budget for a scheduler: ``budget_bytes`` divided by the
    per-page device footprint across all paged layers, else (no byte
    budget, or a model with no paged layers — pure-SSM/ring states cost
    no page bytes) the slotted arena's worth of pages, so paged mode
    never regresses capacity by default."""
    per_page = model.kv_page_bytes(page_tokens)
    if budget_bytes and per_page > 0:
        n = int(budget_bytes) // per_page
        if n < 1:
            raise ValueError(
                f"kv budget {budget_bytes} B below one page "
                f"({per_page} B across paged layers)")
        return n
    return n_slots * (-(-cache_len // page_tokens))


def _as_prompt(prompt) -> jax.Array:
    arr = jnp.asarray(prompt, jnp.int32)
    if arr.ndim == 1:
        arr = arr[None, :]
    if arr.ndim != 2 or arr.shape[0] != 1 or arr.shape[1] < 1:
        raise ValueError(f"prompt must be (S,) or (1, S), got {arr.shape}")
    return arr


# ---------------------------------------------------------------------------
# results + per-request bookkeeping
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GenResult:
    """What one generation request produced."""
    tokens: List[int]            # emitted ids, first token included
    token_times: List[float]     # monotonic emission time per token
    n_prompt: int

    @property
    def t_first(self) -> float:
        return self.token_times[0]

    @property
    def tpot_s(self) -> List[float]:
        """Inter-token intervals (len == len(tokens) - 1)."""
        tt = self.token_times
        return [tt[i] - tt[i - 1] for i in range(1, len(tt))]


class _Active:
    """One resident request (pending join or holding a slot)."""

    def __init__(self, spec: GenerateSpec, cache1: PyTree, first: int,
                 t_first: float, n_prompt: int, n_new: int):
        self.spec = spec
        self.cache1 = cache1            # B=1 prefilled cache, until joined
        self.tokens = [first]
        self.times = [t_first]
        self.n_prompt = n_prompt
        self.remaining = n_new - 1
        self.done = False
        self.error: Optional[BaseException] = None
        # paged mode only: reserved physical pages (prefix hits first),
        # how many of them were prefix hits, and the prompt's running
        # page hashes (for publishing after the pack)
        self.page_ids: List[int] = []
        self.n_hit = 0
        self.hashes: List[str] = []

    @property
    def next_pos(self) -> int:
        """Absolute position of the next input token (the last emitted
        one): prompt occupies [0, S), generated token i sits at S + i."""
        return self.n_prompt + len(self.tokens) - 1


@functools.lru_cache(maxsize=16)
def _prefill_fn(model, fingerprint):
    """Jitted model.prefill per (model, kernel-dispatch fingerprint).

    The lambda matters: jax's global pjit cache keys on the underlying
    *function* — ``jax.jit(model.prefill)`` from two schedulers shares
    one trace, so a scheduler built after a ``REPRO_PALLAS`` change
    would silently reuse executables that baked the previous kernels
    in.  A fresh closure per cache entry gives each (model, modes) pair
    its own trace while still sharing it across schedulers of the same
    model (scale-out)."""
    return jax.jit(lambda params, batch, cache:
                   model.prefill(params, batch, cache))


@functools.lru_cache(maxsize=16)
def _step_fn(model, fingerprint):
    """Jitted batched decode step + sampling, shared across every
    scheduler of the same (model, dispatch) — same caching rationale as
    :func:`_prefill_fn`.  Sharing matters for serving: schedulers are
    rebuilt on every cold start and prewarm, and a per-scheduler
    ``jax.jit`` closure both recompiled the step on each fresh
    instance's first generation (~seconds of on-path latency that no
    amount of pre-provisioning could hide) and leaked one pinned
    executable per instance lifetime into the global pjit cache."""
    def step(params, cache, tok, pos, seed, temp):
        logits, cache = model.decode_step(params, cache, tok, pos)
        nxt = sample_tokens(logits[:, -1, :], seed, pos + 1, temp)
        return nxt[:, None], cache
    return jax.jit(step)


@functools.lru_cache(maxsize=16)
def _join_fn(model, fingerprint):
    """Jitted slot-merge (B=1 prefilled cache -> batch row ``slot``),
    shared like :func:`_step_fn`.  Top-level keys distinguish the
    stacked pattern groups ('s*': leaves are (n_units, B, ...)) from
    tail layers ('t*': leaves are (B, ...))."""
    def join(cache, one, slot):
        out = {}
        for k, big in cache.items():
            ax = 1 if k.startswith("s") else 0
            out[k] = jax.tree.map(
                lambda b, s, _ax=ax: jax.lax.dynamic_update_slice_in_dim(
                    b, s.astype(b.dtype), slot, axis=_ax), big, one[k])
        return out
    return jax.jit(join)


# paged-mode twins of the factories above — same caching and fresh-closure
# rationale (never jit a bound method: R5)

@functools.lru_cache(maxsize=16)
def _paged_step_fn(model, fingerprint):
    def step(params, cache, pools, tables, tok, pos, seed, temp):
        logits, cache, pools = model.decode_step_paged(
            params, cache, pools, tables, tok, pos)
        nxt = sample_tokens(logits[:, -1, :], seed, pos + 1, temp)
        return nxt[:, None], cache, pools
    return jax.jit(step)


@functools.lru_cache(maxsize=16)
def _prefill_cont_fn(model, fingerprint):
    return jax.jit(
        lambda params, batch, cache, off:
        model.prefill_continue(params, batch, cache, off=off),
        static_argnums=(3,))


@functools.lru_cache(maxsize=16)
def _gather_fn(model, fingerprint):
    return jax.jit(
        lambda cache, pools, ids: model.gather_pages(cache, pools, ids))


@functools.lru_cache(maxsize=16)
def _pack_fn(model, fingerprint):
    return jax.jit(
        lambda pools, cache, ids, first:
        model.pack_pages(pools, cache, ids, first),
        static_argnums=(3,))


class DecodeScheduler:
    """Continuous-batching decode over one slotted KV cache.

    Thread-safe: any number of threads may call :meth:`generate`
    concurrently; their requests share the batched step.  ``n_slots``
    bounds concurrent residency (the honored successor of the old
    server's dead ``max_batch`` knob) — an (n_slots+1)-th caller blocks
    until a slot frees, which continuous batching makes soon and often.

    The jitted prefill and decode step trace the model's attention
    through the kernel registry (:mod:`repro.kernels.ops`): on a TPU
    backend serving runs the ``flash_attention`` / ``decode_attention``
    Pallas kernels the tests verify; elsewhere the probed fallback (or
    the ``REPRO_PALLAS``/``--pallas`` forced mode) is baked in at trace
    time — :attr:`kernel_modes` records the resolution this scheduler
    was built under.
    """

    def __init__(self, model, params: PyTree, *, n_slots: int = 8,
                 cache_len: int = 256,
                 kv_page_tokens: Optional[int] = None,
                 kv_budget_bytes: Optional[int] = None,
                 kv_max_seq: Optional[int] = None,
                 metrics: Optional[metrics_mod.MetricsRegistry] = None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if cache_len < 2:
            raise ValueError(f"cache_len must be >= 2, got {cache_len}")
        self.model = model
        self.params = params
        self.n_slots = int(n_slots)
        self.cache_len = int(cache_len)
        # paged mode: full-attention KV lives in a shared page pool
        # (kvpages.KVPagePool bookkeeping + init_kv_pages device arrays)
        # instead of per-slot arena rows; admission is page-budgeted
        self.paged = kv_page_tokens is not None
        m = metrics_mod.resolve(metrics)
        if self.paged:
            pt = int(kv_page_tokens)
            if pt < 1:
                raise ValueError(
                    f"kv_page_tokens must be >= 1, got {kv_page_tokens}")
            self.page_tokens = pt
            self.n_pages = paged_page_count(
                model, page_tokens=pt, budget_bytes=kv_budget_bytes,
                n_slots=self.n_slots, cache_len=self.cache_len)
            # page-table width == per-request page ceiling; it bounds
            # the logical attention extent NP*pt — and with it
            # fallback-mode gather traffic — so it defaults to the
            # slotted cache_len rather than the whole pool.  Pass
            # kv_max_seq > cache_len to let one request stretch across
            # more of the page budget than a slotted arena row held.
            self.np_max = max(1, min(
                self.n_pages,
                -(-int(kv_max_seq if kv_max_seq is not None
                       else self.cache_len) // pt)))
            self.kvpool = kvpages.KVPagePool(
                n_pages=self.n_pages, page_tokens=pt,
                page_bytes=model.kv_page_bytes(pt),
                model_key=model.cfg.name, metrics=m)
            # device pools carry one trailing scratch page that inactive
            # batch rows write into
            self._kvpages = model.init_kv_pages(                # guarded-by: _cv
                self.n_pages + 1, pt)
            self._cache = model.init_cache_paged(               # guarded-by: _cv
                self.n_slots, self.cache_len)
            self._tables = np.full((self.n_slots, self.np_max),  # guarded-by: _cv
                                   self.kvpool.scratch_id, np.int32)
            # prefix reuse needs every sequence state paged; a model with
            # any slot-resident kind still pages admission accounting but
            # keeps the slotted length ceiling (ring/SSM semantics)
            self._prefix_ok = model.supports_prefix_cache
            self._all_paged = bool(model.paged_kinds()) and all(
                k in model.paged_kinds()
                for k in set(model.pattern) | set(model.tail_kinds))
        else:
            self._cache = model.init_cache(self.n_slots, self.cache_len)  # guarded-by: _cv
        # host-side per-slot step inputs
        self._tok = np.zeros((self.n_slots, 1), np.int32)    # guarded-by: _cv
        self._pos = np.zeros((self.n_slots,), np.int32)      # guarded-by: _cv
        self._seed = np.zeros((self.n_slots,), np.uint32)    # guarded-by: _cv
        self._temp = np.zeros((self.n_slots,), np.float32)   # guarded-by: _cv
        self._cv = analysis.make_condition("DecodeScheduler._cv")
        self._free: List[int] = list(range(self.n_slots))  # guarded-by: _cv
        self._slots: Dict[int, _Active] = {}               # guarded-by: _cv
        self._pending: deque = deque()                     # guarded-by: _cv
        self._stepping = False                             # guarded-by: _cv
        # the dispatch fingerprint this scheduler's jitted prefill/step
        # bake in (cheap: no capability probes)
        self._fingerprint = ops.registry.fingerprint()
        # shared per (model, registry resolution) — a fresh scheduler
        # (cold start, prewarm) reuses the already-compiled executables
        # (never a bound method: those share jax's global cache by
        # (__func__, __self__) equality — R5)
        self._prefill = _prefill_fn(model, self._fingerprint)
        self._step = _step_fn(model, self._fingerprint)
        self._join_cache = _join_fn(model, self._fingerprint)
        if self.paged:
            self._pstep = _paged_step_fn(model, self._fingerprint)
            self._prefill_cont = _prefill_cont_fn(model, self._fingerprint)
            self._gather = _gather_fn(model, self._fingerprint)
            self._pack = _pack_fn(model, self._fingerprint)
        # counters
        self.steps = 0
        self.max_occupancy = 0
        self.joined = 0
        # shared across all schedulers of a platform: occupancy/steps
        # aggregate over instances (the decode capacity the node runs)
        self._m_steps = m.counter("decode/steps")
        self._m_joined = m.counter("decode/joined")
        self._m_occ = m.gauge("decode/occupancy")

    # ------------------------------------------------------------ public API
    def generate(self, spec: GenerateSpec, *,
                 first_token: Optional[int] = None,
                 t_first: Optional[float] = None) -> GenResult:
        """Serve one generation request; blocks until it completes.

        ``first_token``/``t_first`` inject a token already produced
        elsewhere — the cold-start path, where the loading pipeline's
        own in-flight forward answers the prompt (TTFT ~ the pipeline's
        E-completion): the prompt is still prefilled here to build the
        slot's KV cache, but its logits are discarded and generation
        resumes at position S+1.
        """
        prompt = _as_prompt(spec.prompt)
        n_prompt = int(prompt.shape[1])
        if self.paged:
            return self._generate_paged(spec, prompt, n_prompt,
                                        first_token, t_first)
        n_new = validate_spec(spec, n_prompt, self.cache_len)

        cache1 = self.model.init_cache(1, self.cache_len)
        logits, cache1 = self._prefill(self.params, {"tokens": prompt},
                                       cache1)
        if first_token is None:
            jax.block_until_ready(logits)
            first_token = sample_first(logits, spec, n_prompt)
            t_first = time.monotonic()

        req = _Active(spec, cache1, int(first_token), float(t_first),
                      n_prompt, n_new)
        if req.remaining == 0 or (spec.eos_id is not None
                                  and req.tokens[-1] == spec.eos_id):
            return GenResult(req.tokens, req.times, n_prompt)

        with self._cv:
            self._pending.append(req)
            self._cv.notify_all()
        self._pump(req)
        if req.error is not None:
            raise req.error
        return GenResult(req.tokens, req.times, n_prompt)

    def _generate_paged(self, spec: GenerateSpec, prompt, n_prompt: int,
                        first_token, t_first) -> GenResult:
        """Paged admission: reserve whole pages (prefix hits first, the
        rest all-or-nothing from the pool — blocking backpressure, never
        a per-slot length ceiling), prefill only the unshared suffix,
        then join the batch like any slotted request."""
        pt = self.page_tokens
        n_new = validate_spec_paged(spec, n_prompt, page_tokens=pt,
                                    n_pages=self.np_max,
                                    stats=self.kvpool.stats())
        if not self._all_paged:
            # some sequence state is still slot-resident (ring / SSM):
            # its capacity ceiling applies unchanged
            n_new = validate_spec(spec, n_prompt, self.cache_len)
        need = -(-(n_prompt + n_new) // pt)
        hit: List[int] = []
        if self._prefix_ok:
            hashes = kvpages.page_hashes(self.kvpool.model_key,
                                         np.asarray(prompt)[0], pt)
            # a hit must leave a non-empty prefill suffix (the request's
            # own logits come from its last prompt token)
            hashes_full = hashes
            hashes = hashes[:min(len(hashes), (n_prompt - 1) // pt)]
            hit = self.kvpool.match_prefix(hashes)
        else:
            hashes_full = []
        try:
            new = self.kvpool.alloc(need - len(hit), timeout=120.0)
        except TimeoutError:
            # our own prefix pins may be what is starving the pool: drop
            # them and queue for the whole span like a cold request
            self.kvpool.release(hit)
            hit = []
            new = self.kvpool.alloc(need)
        page_ids = list(hit) + list(new)
        n_hit = len(hit)
        try:
            cache1 = self.model.init_request_cache(need * pt, self.cache_len)
            off = n_hit * pt
            if off:
                with self._cv:
                    pools = self._kvpages   # hit pages are pinned ⇒ immutable
                cache1 = self._gather(
                    cache1, pools, jnp.asarray(np.asarray(hit, np.int32)))
                logits, cache1 = self._prefill_cont(
                    self.params, {"tokens": prompt[:, off:]}, cache1, off)
            else:
                logits, cache1 = self._prefill(self.params,
                                               {"tokens": prompt}, cache1)
            if first_token is None:
                jax.block_until_ready(logits)
                first_token = sample_first(logits, spec, n_prompt)
                t_first = time.monotonic()
            req = _Active(spec, cache1, int(first_token), float(t_first),
                          n_prompt, n_new)
            req.page_ids = page_ids
            req.n_hit = n_hit
            req.hashes = hashes_full
        except BaseException:
            self.kvpool.release(page_ids)
            raise
        if req.remaining == 0 or (spec.eos_id is not None
                                  and req.tokens[-1] == spec.eos_id):
            self.kvpool.release(page_ids)
            return GenResult(req.tokens, req.times, n_prompt)

        with self._cv:
            self._pending.append(req)
            self._cv.notify_all()
        self._pump(req)
        if req.error is not None:
            raise req.error
        return GenResult(req.tokens, req.times, n_prompt)

    @property
    def kernel_modes(self) -> Dict[str, str]:
        """Resolved kernel-registry dispatch per op as of this
        scheduler's construction (what its jitted prefill/step bake in
        — set the mode BEFORE building schedulers); exact even after a
        later ``set_mode``.  Resolved lazily: in auto mode this
        triggers the one-time capability probes, which must not run in
        __init__ on the cold-start first-token path."""
        return ops.registry.modes_for(self._fingerprint)

    def stats(self) -> Dict[str, int]:
        with self._cv:
            out = {"steps": self.steps, "joined": self.joined,
                   "max_occupancy": self.max_occupancy,
                   "active": len(self._slots) + len(self._pending),
                   "n_slots": self.n_slots}
        if self.paged:
            ps = self.kvpool.stats()
            out.update(kv_page_tokens=self.page_tokens,
                       kv_pages_total=ps.total, kv_pages_used=ps.used,
                       kv_pages_pinned=ps.pinned,
                       kv_prefix_hits=ps.prefix_hits,
                       kv_prefix_misses=ps.prefix_misses)
        return out

    def reset_peaks(self):
        """Re-arm the max_occupancy watermark at the current occupancy
        — benchmark sweeps call this between phases so each phase
        reports its own peak, not the scheduler-lifetime maximum."""
        with self._cv:
            self.max_occupancy = len(self._slots)

    # -------------------------------------------------------- cooperative drive
    def _admit_locked(self):
        """Move pending joins into free slots (caller holds the lock) —
        the step boundary where requests enter the running batch."""
        while self._pending and self._free:
            req = self._pending.popleft()
            slot = min(self._free)
            self._free.remove(slot)
            if self.paged:
                self._join_paged_locked(req, slot)
            else:
                self._cache = self._join_cache(self._cache, req.cache1,
                                               jnp.int32(slot))
            req.cache1 = None
            self._slots[slot] = req
            self._tok[slot, 0] = req.tokens[-1]
            self._pos[slot] = req.next_pos
            self._seed[slot] = np.uint32(req.spec.seed)
            self._temp[slot] = np.float32(req.spec.temperature)
            self.joined += 1
            self.max_occupancy = max(self.max_occupancy, len(self._slots))
            self._m_joined.inc()
            self._m_occ.set(len(self._slots))

    def _join_paged_locked(self, req: _Active, slot: int):
        """Paged half of admission (caller holds the lock): merge the
        slot-resident state, move new prompt pages from the request's
        contiguous prefill cache into the pool, publish their hashes for
        prefix reuse, and point the slot's page-table row at them."""
        self._cache = self._join_cache(
            self._cache, self.model.strip_paged(req.cache1), jnp.int32(slot))
        n_pp = -(-req.n_prompt // self.page_tokens)   # pages holding prompt
        ids = req.page_ids
        # copy-on-write guard on the pack targets — fresh allocations
        # have refcount 1, so this only ever forks if a future caller
        # grows sharing semantics; the invariant stays locally enforced
        for j in range(req.n_hit, n_pp):
            pid, copied = self.kvpool.ensure_writable(ids[j])
            if copied:
                self._kvpages = self.model.copy_page(self._kvpages,
                                                     ids[j], pid)
                ids[j] = pid
        if n_pp > req.n_hit:
            self._kvpages = self._pack(
                self._kvpages, req.cache1,
                jnp.asarray(np.asarray(ids[req.n_hit:n_pp], np.int32)),
                req.n_hit)
        # publish *full* prompt pages only (device content final now);
        # partial trailing pages keep receiving decode writes
        for j in range(req.n_hit, min(len(req.hashes), n_pp)):
            self.kvpool.register(ids[j], req.hashes[j])
        self._tables[slot, :] = self.kvpool.scratch_id
        self._tables[slot, :len(ids)] = ids

    def _leave_paged_locked(self, req: _Active, slot: int):
        """Release a leaver's page references and park its table row on
        the scratch page (caller holds the lock)."""
        self._tables[slot, :] = self.kvpool.scratch_id
        self.kvpool.release(req.page_ids)
        req.page_ids = []

    def _fail_locked(self, e: BaseException):
        """Abort every resident request with ``e`` (caller holds the
        lock): a failed step/join leaves no thread parked forever."""
        self._stepping = False
        for req in list(self._slots.values()) + list(self._pending):
            req.error = e
            if self.paged and req.page_ids:
                self.kvpool.release(req.page_ids)
                req.page_ids = []
        if self.paged:
            self._tables[:, :] = self.kvpool.scratch_id
        self._slots.clear()
        self._pending.clear()
        self._free = list(range(self.n_slots))
        self._cv.notify_all()

    def _pump(self, my: _Active):
        """Drive batched steps until ``my`` completes.  Exactly one
        thread steps at a time; the others wait on the CV.  Every
        resident request has a caller thread parked here, so a stepper
        always exists while work remains."""
        while True:
            with self._cv:
                while True:
                    if my.done or my.error is not None:
                        return
                    if not self._stepping:
                        break
                    self._cv.wait()
                self._stepping = True
                try:
                    self._admit_locked()
                    params, cache = self.params, self._cache
                    tok = jnp.asarray(self._tok)
                    pos = jnp.asarray(self._pos)
                    seed = jnp.asarray(self._seed)
                    temp = jnp.asarray(self._temp)
                    if self.paged:
                        pools = self._kvpages
                        tables = jnp.asarray(self._tables)
                except BaseException as e:
                    # anything failing while _stepping is set must fail
                    # ALL residents, or their threads wait forever
                    self._fail_locked(e)
                    raise
            try:
                if self.paged:
                    nxt, new_cache, new_pools = self._pstep(
                        params, cache, pools, tables, tok, pos, seed, temp)
                else:
                    nxt, new_cache = self._step(params, cache, tok, pos,
                                                seed, temp)
                nxt_host = np.asarray(nxt)
            except BaseException as e:
                with self._cv:
                    self._fail_locked(e)
                raise
            t_now = time.monotonic()
            with self._cv:
                self._cache = new_cache
                if self.paged:
                    self._kvpages = new_pools
                self.steps += 1
                for slot in list(self._slots):
                    req = self._slots[slot]
                    t = int(nxt_host[slot, 0])
                    req.tokens.append(t)
                    req.times.append(t_now)
                    req.remaining -= 1
                    self._tok[slot, 0] = t
                    self._pos[slot] += 1
                    if req.remaining == 0 or \
                            (req.spec.eos_id is not None
                             and t == req.spec.eos_id):
                        req.done = True
                        del self._slots[slot]
                        self._free.append(slot)
                        if self.paged:
                            self._leave_paged_locked(req, slot)
                self._m_steps.inc()
                self._m_occ.set(len(self._slots))
                self._stepping = False
                self._cv.notify_all()


# ---------------------------------------------------------------------------
# serial reference — the oracle the batched engine must match bit-for-bit
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _ref_fns(model, fingerprint):
    """Per-(model, kernel-dispatch) jitted prefill/decode_step, cached
    so repeated reference calls (the bench's serial baseline) don't
    recompile.  Keyed on the registry fingerprint — and wrapped in
    per-entry closures, since the global pjit cache keys on the
    underlying function: ``jax.jit(model.prefill)`` would reuse a
    trace from a previous dispatch mode.  Bounded: the jitted closures
    pin the model and its executables, so an unbounded cache would
    leak one model per entry for the process lifetime."""
    return (jax.jit(lambda p, b, c: model.prefill(p, b, c)),
            jax.jit(lambda p, c, t, s: model.decode_step(p, c, t, s)))


def reference_generate(model, params: PyTree, prompt, *, n_new: int,
                       cache_len: int = 256, temperature: float = 0.0,
                       seed: int = 0, eos_id: Optional[int] = None,
                       max_len: Optional[int] = None) -> List[int]:
    """Serial B=1 ``prefill`` + ``decode_step`` loop with the same
    sampling rule as the DecodeScheduler.  Token-level ground truth for
    the equivalence tests and the bench's per-request serial baseline.
    """
    spec = GenerateSpec(prompt=prompt, n_new=n_new, temperature=temperature,
                        max_len=max_len, eos_id=eos_id, seed=seed)
    prompt = _as_prompt(prompt)
    S = int(prompt.shape[1])
    n_new = validate_spec(spec, S, cache_len)

    prefill, dec = _ref_fns(model, ops.registry.fingerprint())
    cache = model.init_cache(1, cache_len)
    logits, cache = prefill(params, {"tokens": prompt}, cache)
    out = [sample_first(logits, spec, S)]
    seeds = jnp.asarray([seed], jnp.uint32)
    temps = jnp.asarray([temperature], jnp.float32)
    cur = jnp.asarray([[out[0]]], jnp.int32)
    for t in range(S, S + n_new - 1):
        if eos_id is not None and out[-1] == eos_id:
            break
        pos = jnp.asarray([t], jnp.int32)
        logits, cache = dec(params, cache, cur, pos)
        cur = sample_tokens(logits[:, -1, :], seeds, pos + 1, temps)[:, None]
        out.append(int(cur[0, 0]))
    return out
