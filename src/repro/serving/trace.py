"""Bursty serverless invocation workload (paper Sec. IV-B, Fig. 8).

The paper drives its evaluation with day 14 of the Azure Functions
trace (1,980,951 invocations over 14 days; 2,426 invocations sampled
over one hour, assigned randomly to the evaluated models).  The raw
trace is not redistributable in this offline container, so we generate
a statistically similar arrival process and document the deviation:

  * doubly-stochastic Poisson process: a log-normal–modulated per-minute
    rate envelope (burst factor matching Fig. 8's spiky shape, where
    per-minute counts swing between ~10 and ~120);
  * total invocation count and horizon match the paper (2,426 over 1 h);
  * invocations are assigned uniformly at random to the model set,
    mirroring the paper's "randomly assigning functions to the
    evaluated models".

Everything is seeded and deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Invocation:
    t: float                  # arrival time (seconds from epoch 0)
    model: str
    req_id: int


def per_minute_envelope(minutes: int, mean_per_min: float, *,
                        burstiness: float = 0.9,
                        seed: int = 0) -> np.ndarray:
    """Log-normal modulated rates with occasional bursts (Fig. 8 shape)."""
    rng = np.random.default_rng(seed)
    base = rng.lognormal(mean=0.0, sigma=burstiness, size=minutes)
    # sparse bursts: ~8% of minutes spike 2-4x
    burst_mask = rng.random(minutes) < 0.08
    base[burst_mask] *= rng.uniform(2.0, 4.0, burst_mask.sum())
    rates = base / base.mean() * mean_per_min
    return rates


def azure_like_trace(*, duration_s: float = 3600.0,
                     n_invocations: int = 2426,
                     models: Sequence[str],
                     seed: int = 0) -> List[Invocation]:
    """Generate the full arrival sequence."""
    rng = np.random.default_rng(seed + 1)
    minutes = max(int(np.ceil(duration_s / 60.0)), 1)
    rates = per_minute_envelope(minutes, n_invocations / minutes, seed=seed)
    counts = rng.poisson(rates)
    # rescale to hit the exact invocation count
    while counts.sum() != n_invocations:
        diff = n_invocations - counts.sum()
        idx = rng.integers(0, minutes, abs(diff))
        if diff > 0:
            np.add.at(counts, idx, 1)
        else:
            for i in idx:
                if counts[i] > 0:
                    counts[i] -= 1
    out: List[Invocation] = []
    rid = 0
    for m in range(minutes):
        ts = np.sort(rng.uniform(m * 60.0, min((m + 1) * 60.0, duration_s),
                                 counts[m]))
        for t in ts:
            out.append(Invocation(float(t), models[rng.integers(
                0, len(models))], rid))
            rid += 1
    return out


def summarize(trace: List[Invocation]) -> dict:
    per_min: dict = {}
    for inv in trace:
        per_min[int(inv.t // 60)] = per_min.get(int(inv.t // 60), 0) + 1
    counts = np.array(list(per_min.values()))
    return {"n": len(trace),
            "minutes": len(per_min),
            "per_min_mean": float(counts.mean()),
            "per_min_max": int(counts.max()),
            "per_min_min": int(counts.min()),
            "burst_ratio": float(counts.max() / max(counts.mean(), 1e-9))}
