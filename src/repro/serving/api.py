"""Public serving data model: requests, priority classes, responses.

The platform's front door speaks three types:

  * :class:`Request` — one invocation of a deployed model function,
    carrying the input batch, the trace's *logical* arrival time (used
    for keep-alive accounting) and an optional explicit priority class;
  * :class:`RequestClass` — dispatch priority.  Lower value = served
    first.  The default classifier marks warm-servable work INFERENCE
    and cold starts COLDSTART, implementing the Priority-Aware
    Scheduler's "inference first" rule at the routing layer;
  * :class:`Response` — the per-request record benchmarks consume: the
    seed's fields (cold/load_s/infer_s/utilization/latency) plus the
    queueing delay introduced by concurrent admission.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, Optional


class RequestClass(enum.IntEnum):
    """Dispatch priority; lower value wins (inference-first rule)."""
    INFERENCE = 0          # warm steady-state forward
    COLDSTART = 1          # triggers the loading pipeline
    BACKGROUND = 2         # prefetch / maintenance work


@dataclasses.dataclass
class Request:
    """One invocation submitted to the Router."""
    req_id: int
    model: str
    batch: Optional[Dict[str, Any]] = None
    t_logical: float = 0.0          # trace arrival time (logical clock)
    cls: Optional[RequestClass] = None   # None -> classified at submit
    t_submit: float = 0.0           # wall clock, stamped by the Router


@dataclasses.dataclass
class Response:
    req_id: int
    model: str
    cold: bool
    t_arrival: float
    t_done: float
    load_s: float           # cold-start pipeline time (0 for warm)
    infer_s: float          # steady-state inference time (warm requests)
    utilization: float      # pipeline utilization (cold requests)
    queue_s: float = 0.0    # admission -> service start (router queue +
                            # pool wait + instance provisioning)
    cls: RequestClass = RequestClass.INFERENCE

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival


class AdmissionError(RuntimeError):
    """Raised by Router.submit when admission control rejects a request
    (pending queue at capacity)."""


@dataclasses.dataclass
class PoolStats:
    """Point-in-time + cumulative counters for one InstancePool."""
    model: str
    size: int               # provisioned instances
    live: int               # instances holding params
    busy: int               # instances currently serving
    cold_starts: int
    warm_hits: int
    evictions: int


@dataclasses.dataclass
class RouterStats:
    submitted: int = 0
    rejected: int = 0
    completed: int = 0
    max_queue_depth: int = 0
    max_in_flight: int = 0
