"""Public serving data model: requests, priority classes, responses.

The platform's front door is *generation-first*: the realistic
serverless-LLM workload is multi-token generation, where cold-start
latency is time-to-first-token (TTFT) and steady-state throughput is
decided by batching.  The front door speaks four types:

  * :class:`GenerateSpec` — what to generate: prompt tokens, how many
    new tokens, greedy/temperature sampling, a per-request length cap
    and an optional EOS id;
  * :class:`Request` — one invocation of a deployed model function.
    ``gen`` makes it a generation request served by the instance's
    continuous-batching :class:`~repro.serving.decode.DecodeScheduler`;
    the old one-shot ``batch`` form (a single ``batch -> logits``
    forward) remains the degenerate ``n_new=0`` case and keeps working
    unmodified;
  * :class:`RequestClass` — dispatch priority.  Lower value = served
    first.  The default classifier marks warm-servable work INFERENCE
    and cold starts COLDSTART, implementing the Priority-Aware
    Scheduler's "inference first" rule at the routing layer;
  * :class:`Response` — the per-request record benchmarks consume: the
    seed's fields (cold/load_s/infer_s/utilization/latency), the
    queueing delay introduced by concurrent admission, and for
    generation requests the emitted ``tokens`` plus TTFT / per-token
    TPOT timings.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Optional


class RequestClass(enum.IntEnum):
    """Dispatch priority; lower value wins (inference-first rule)."""
    INFERENCE = 0          # warm steady-state forward
    COLDSTART = 1          # triggers the loading pipeline
    BACKGROUND = 2         # prefetch / maintenance work


@dataclasses.dataclass
class GenerateSpec:
    """One generation job: decode ``n_new`` tokens after ``prompt``.

    prompt       token ids, any 1-D sequence / array (or ``(1, S)``)
    n_new        tokens to generate (>= 1)
    temperature  0 -> greedy argmax; > 0 -> categorical sampling at
                 this temperature, keyed by ``seed`` and the absolute
                 token position (deterministic for a fixed seed,
                 independent of batching)
    max_len      per-request cap on total length (prompt + generated);
                 ``n_new`` is clamped down to honor it
    eos_id       stop early when this token is produced
    seed         per-request sampling key seed
    """
    prompt: Any
    n_new: int = 16
    temperature: float = 0.0
    max_len: Optional[int] = None
    eos_id: Optional[int] = None
    seed: int = 0


@dataclasses.dataclass
class Request:
    """One invocation submitted to the Router."""
    req_id: int
    model: str
    batch: Optional[Dict[str, Any]] = None
    t_logical: float = 0.0          # trace arrival time (logical clock)
    cls: Optional[RequestClass] = None   # None -> classified at submit
    t_submit: float = 0.0           # wall clock, stamped by the Router
    gen: Optional[GenerateSpec] = None   # None -> one-shot logits request


@dataclasses.dataclass
class Response:
    req_id: int
    model: str
    cold: bool
    t_arrival: float
    t_done: float
    load_s: float           # cold-start pipeline time (0 for warm)
    infer_s: float          # steady-state inference time (warm requests)
    utilization: float      # pipeline utilization (cold requests)
    queue_s: float = 0.0    # admission -> service start (router queue +
                            # pool wait + instance provisioning)
    cls: RequestClass = RequestClass.INFERENCE
    # generation requests only (None for one-shot logits requests):
    tokens: Optional[Any] = None         # (n,) int array of emitted ids
    ttft_s: Optional[float] = None       # service start -> first token
    tpot_s: Optional[List[float]] = None  # inter-token intervals (n-1)
    node: Optional[str] = None           # serving node id (cluster routing;
                                         # None on a single-node platform)

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def n_generated(self) -> int:
        return 0 if self.tokens is None else len(self.tokens)


class AdmissionError(RuntimeError):
    """Raised by Router.submit when admission control rejects a request
    (pending queue at capacity)."""


class UnknownModelError(KeyError):
    """Raised by Router.submit — on the submitting thread, not inside a
    worker — when a request names a model with no deployed pool."""


class CacheOverflowError(ValueError):
    """Raised when prompt + n_new cannot fit the decode KV cache
    (``cache_len``) — instead of the silent ring-wrap/drop the old
    static-batch server performed past the cache end."""


@dataclasses.dataclass
class PoolStats:
    """Point-in-time + cumulative counters for one InstancePool."""
    model: str
    size: int               # provisioned instances
    live: int               # instances holding params
    busy: int               # instances currently serving
    cold_starts: int
    warm_hits: int
    evictions: int
    gen_active: int = 0     # generation requests currently joined
    prewarms: int = 0       # autoscaler pre-provisioned warm-ups


@dataclasses.dataclass
class RouterStats:
    submitted: int = 0
    rejected: int = 0
    completed: int = 0
    max_queue_depth: int = 0
    max_in_flight: int = 0
