"""Concurrency correctness toolkit for the repro tree.

Two sides share one vocabulary of lock node names ("Class._lock"):

* :mod:`repro.analysis.lint` — AST rules R1–R5 (guarded-by, cv-wait
  discipline, static lock-order cycles, no-sleep, jit-cache hygiene).
* :mod:`repro.analysis.locks` — the opt-in instrumented Lock / RLock /
  Condition factory (``REPRO_ANALYZE=1``) every repro module uses, plus
  the process-wide :data:`~repro.analysis.locks.probe`.

``python -m repro.analysis {lint,lockgraph,report}`` is the CLI.
"""
from .locks import (enabled, make_condition, make_lock, make_rlock,
                    note_io, probe)

__all__ = ["enabled", "make_lock", "make_rlock", "make_condition",
           "note_io", "probe"]
