"""AST-based concurrency lint over the ``repro`` source tree.

Project-specific rules (each with a stable finding ID usable in the
baseline file and in ``# analysis: ignore[...]`` inline suppressions):

  * **R1 guarded-by** — a shared attribute declared with a
    ``# guarded-by: _lock`` trailing comment (or a class-level
    ``_guarded_by = {...}`` registry) may only be accessed inside a
    ``with self._lock:`` scope.  Methods whose name ends in ``_locked``
    are the documented caller-holds-the-lock convention and are
    skipped; ``__init__`` is skipped (the object is not yet shared);
    nested ``def``/``lambda`` bodies are skipped (deferred execution —
    their lock context is unknowable statically).  The write-only
    variant ``# guarded-by[writes]: _lock`` checks mutations only
    (stores, aug-assigns, subscript stores, mutating method calls) —
    for append-only instrumentation read after the threads join.
  * **R2 cv-wait discipline** — every ``Condition.wait`` must sit
    inside a ``while`` loop (missed-wakeup / spurious-wakeup safety),
    and a numeric-literal timeout (``cv.wait(0.02)``) is flagged: the
    event-driven pipeline must never regress to polling grids.
    Computed deadlines (Algorithm 1) pass variables, not literals.
  * **R3 lock-order** — nested ``with``-acquisitions (plus one level of
    call-graph resolution through typed ``self.<attr>`` fields) build a
    module-spanning acquisition-order graph; a cycle is a static
    deadlock hazard.  The same graph merges with the runtime probe's
    observed edges in ``python -m repro.analysis lockgraph``.
  * **R4 no time.sleep** — outside the simulated storage device
    (``store/store.py``) and the trace-replay inter-arrival gap
    (``serving/engine.py``), a ``time.sleep`` is a polling wait and is
    an error.
  * **R5 jit-cache hygiene** — ``jax.jit(obj.method)`` on a *bound
    method* shares jax's global pjit cache entry across every object
    whose bound method compares equal — the PR-5 bug class, where a
    scheduler reused traces baked under a previous kernel-dispatch
    mode.  Serving paths must jit per-instance closures (lambdas) or
    key their caches on the kernel-registry fingerprint.

Suppression: ``# analysis: ignore`` or ``# analysis: ignore[R1,R2]``
on the flagged line, or the finding's ID in the reviewed baseline file
(``tests/analysis_baseline.txt``) with a one-line justification.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES = ("R1", "R2", "R3", "R4", "R5")

# file suffixes (posix, relative) where time.sleep models physical time
SLEEP_ALLOWED = ("store/store.py", "serving/engine.py")

# receiver attr/name must match one of these to count as a Condition
# for R2 when not resolvable from class assignments
_CV_NAME = re.compile(r"(^|_)(cv|cond|condition)$")

_GUARD_RE = re.compile(
    r"self\.(\w+)\b[^#]*#\s*guarded-by(\[writes\])?:\s*(\w+)")
_IGNORE_RE = re.compile(r"#\s*analysis:\s*ignore(?:\[([\w,\s]+)\])?")

_MUTATORS = {
    "append", "appendleft", "extend", "insert", "add", "discard",
    "remove", "pop", "popleft", "popitem", "clear", "update",
    "setdefault", "move_to_end", "difference_update", "push", "sort",
}

_LOCK_FACTORIES = {"Lock", "RLock", "Condition",
                   "make_lock", "make_rlock", "make_condition"}
_CV_FACTORIES = {"Condition", "make_condition"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str               # posix-relative path (stable across checkouts)
    line: int
    scope: str              # "Class.method" | "Class" | "<module>"
    detail: str             # stable discriminator within the scope
    message: str

    @property
    def id(self) -> str:
        return f"{self.rule}:{self.path}:{self.scope}:{self.detail}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} "
                f"[{self.scope}] {self.message}\n    id: {self.id}")


# ---------------------------------------------------------------------------
# per-file model
# ---------------------------------------------------------------------------

class ClassInfo:
    def __init__(self, name: str):
        self.name = name
        self.lock_attrs: Set[str] = set()      # attrs holding locks/CVs
        self.cv_attrs: Set[str] = set()        # subset: condition variables
        self.guards: Dict[str, Tuple[str, str]] = {}  # attr->(guard, mode)
        self.attr_types: Dict[str, str] = {}   # attr -> class name (typed)


class FileModel:
    """One parsed source file + everything the rules need from it."""

    def __init__(self, source: str, relpath: str):
        self.relpath = relpath.replace(os.sep, "/")
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=relpath)
        self.module_aliases = self._module_aliases()
        self.classes: Dict[str, ClassInfo] = {}
        for node in self.tree.body:
            if isinstance(node, ast.ClassDef):
                self.classes[node.name] = self._class_info(node)

    # ------------------------------------------------------------ helpers
    def ignored(self, line: int, rule: str) -> bool:
        if not (1 <= line <= len(self.lines)):
            return False
        m = _IGNORE_RE.search(self.lines[line - 1])
        if not m:
            return False
        if m.group(1) is None:
            return True
        return rule in {r.strip() for r in m.group(1).split(",")}

    def _module_aliases(self) -> Set[str]:
        names = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    names.add((a.asname or a.name).split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                for a in node.names:
                    # "from repro.kernels import ref" -> ref is a module
                    # alias only sometimes; treat bare lowercase names
                    # imported from packages as potential modules
                    names.add(a.asname or a.name)
        return names

    def _class_info(self, cdef: ast.ClassDef) -> ClassInfo:
        info = ClassInfo(cdef.name)
        # annotation-declared guards: scan the class's line range
        end = cdef.end_lineno or len(self.lines)
        for ln in range(cdef.lineno, end + 1):
            if ln > len(self.lines):
                break
            m = _GUARD_RE.search(self.lines[ln - 1])
            if m:
                mode = "writes" if m.group(2) else "all"
                info.guards[m.group(1)] = (m.group(3), mode)
        for node in ast.walk(cdef):
            # registry-declared guards: class-level _guarded_by dict
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and node.targets[0].id == "_guarded_by" \
                    and isinstance(node.value, ast.Dict):
                for k, v in zip(node.value.keys, node.value.values):
                    if isinstance(k, ast.Constant) \
                            and isinstance(v, ast.Constant):
                        guard, _, mode = str(v.value).partition(":")
                        info.guards[str(k.value)] = (
                            guard, mode or "all")
            # self.<attr> = <... Lock()/Condition()/make_*() ...>
            tgt = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt, val = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                tgt, val = node.target, node.value
            else:
                continue
            if not (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"):
                continue
            calls = [n for n in ast.walk(val) if isinstance(n, ast.Call)]
            factory = None
            for c in calls:
                fname = c.func.attr if isinstance(c.func, ast.Attribute) \
                    else (c.func.id if isinstance(c.func, ast.Name)
                          else None)
                if fname in _LOCK_FACTORIES:
                    factory = fname
                    break
            if factory is not None:
                info.lock_attrs.add(tgt.attr)
                if factory in _CV_FACTORIES:
                    info.cv_attrs.add(tgt.attr)
                continue
            # typed attribute: self.cache = cache  (cache: WeightCache)
            if isinstance(val, ast.Name):
                ann = self._param_annotation(cdef, val.id)
                if ann:
                    info.attr_types[tgt.attr] = ann
            elif isinstance(val, ast.Call) \
                    and isinstance(val.func, ast.Name):
                info.attr_types[tgt.attr] = val.func.id
        return info

    @staticmethod
    def _param_annotation(cdef: ast.ClassDef, pname: str) -> Optional[str]:
        """Class name from an __init__ parameter annotation, unwrapping
        Optional[...] / quoted forms."""
        for node in cdef.body:
            if isinstance(node, ast.FunctionDef) and node.name == "__init__":
                for arg in (node.args.posonlyargs + node.args.args
                            + node.args.kwonlyargs):
                    if arg.arg == pname and arg.annotation is not None:
                        return _ann_class(arg.annotation)
        return None


def _ann_class(ann: ast.expr) -> Optional[str]:
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        name = ann.value.split("[")[-1].rstrip("]").split(".")[-1]
        return name or None
    if isinstance(ann, ast.Subscript):          # Optional[X] / "X" forms
        return _ann_class(ann.slice)
    if isinstance(ann, ast.Attribute):
        return ann.attr
    return None


# ---------------------------------------------------------------------------
# lock-order graph (R3) — shared with the CLI's `lockgraph`
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LockEdge:
    src: str                 # "Class._lock"
    dst: str
    where: str               # "path:line" provenance


def _with_lock_attr(item: ast.withitem) -> Optional[str]:
    e = item.context_expr
    if isinstance(e, ast.Attribute) and isinstance(e.value, ast.Name) \
            and e.value.id == "self":
        return e.attr
    return None


class _LockOrderVisitor(ast.NodeVisitor):
    """Collects (a) locks each method acquires directly and (b) nested
    acquisition edges, with one level of call resolution."""

    def __init__(self, model: FileModel, cls: ClassInfo,
                 method_locks: Dict[Tuple[str, str], Set[str]],
                 global_classes: Dict[str, ClassInfo]):
        self.model = model
        self.cls = cls
        self.method_locks = method_locks
        self.global_classes = global_classes
        self.edges: List[LockEdge] = []
        self._held: List[str] = []           # lock node names

    def node_name(self, attr: str) -> str:
        return f"{self.cls.name}.{attr}"

    def visit_With(self, node: ast.With):
        pushed = 0
        for item in node.items:
            attr = _with_lock_attr(item)
            if attr is not None and attr in self.cls.lock_attrs:
                lock = self.node_name(attr)
                for held in self._held:
                    if held != lock:
                        self.edges.append(LockEdge(
                            held, lock,
                            f"{self.model.relpath}:{node.lineno}"))
                self._held.append(lock)
                pushed += 1
        for child in node.body:
            self.visit(child)
        for _ in range(pushed):
            self._held.pop()

    def visit_Call(self, node: ast.Call):
        if self._held:
            for dst in self._callee_locks(node):
                for held in self._held:
                    if held != dst:
                        self.edges.append(LockEdge(
                            held, dst,
                            f"{self.model.relpath}:{node.lineno}"))
        self.generic_visit(node)

    def _callee_locks(self, node: ast.Call) -> Set[str]:
        f = node.func
        if not isinstance(f, ast.Attribute):
            return set()
        meth = f.attr
        base = f.value
        # self.<m>() -> same class
        if isinstance(base, ast.Name) and base.id == "self":
            return {f"{self.cls.name}.{a}" for a in
                    self.method_locks.get((self.cls.name, meth), ())}
        # self.<attr>.<m>() with a typed attr -> that class
        if isinstance(base, ast.Attribute) \
                and isinstance(base.value, ast.Name) \
                and base.value.id == "self":
            tname = self.cls.attr_types.get(base.attr)
            if tname:
                return {f"{tname}.{a}" for a in
                        self.method_locks.get((tname, meth), ())}
        return set()

    # deferred bodies: lock context at call time is unknown
    def visit_FunctionDef(self, node):        # nested def
        pass

    def visit_AsyncFunctionDef(self, node):
        pass

    def visit_Lambda(self, node):
        pass


def build_static_lockgraph(models: Sequence[FileModel]
                           ) -> Tuple[List[LockEdge], List[List[str]]]:
    """(edges, cycles) over every model's classes."""
    global_classes: Dict[str, ClassInfo] = {}
    for m in models:
        global_classes.update(m.classes)
    # pass 1: direct acquisitions per method
    method_locks: Dict[Tuple[str, str], Set[str]] = {}
    for m in models:
        for node in m.tree.body:
            if not isinstance(node, ast.ClassDef):
                continue
            cls = m.classes[node.name]
            for fn in node.body:
                if not isinstance(fn, ast.FunctionDef):
                    continue
                acquired = set()
                for w in ast.walk(fn):
                    if isinstance(w, ast.With):
                        for item in w.items:
                            attr = _with_lock_attr(item)
                            if attr in cls.lock_attrs:
                                acquired.add(attr)
                if acquired:
                    method_locks[(node.name, fn.name)] = acquired
    # pass 2: nested acquisitions
    edges: List[LockEdge] = []
    for m in models:
        for node in m.tree.body:
            if not isinstance(node, ast.ClassDef):
                continue
            cls = m.classes[node.name]
            for fn in node.body:
                if not isinstance(fn, ast.FunctionDef):
                    continue
                v = _LockOrderVisitor(m, cls, method_locks, global_classes)
                for child in fn.body:
                    v.visit(child)
                edges.extend(v.edges)
    return edges, find_cycles({(e.src, e.dst) for e in edges})


def find_cycles(edge_set: Set[Tuple[str, str]]) -> List[List[str]]:
    """Every elementary cycle's node list (rotated to its minimum node
    for a stable identity), via iterative DFS per SCC-free shortcut."""
    adj: Dict[str, List[str]] = {}
    for a, b in edge_set:
        adj.setdefault(a, []).append(b)
    cycles: List[List[str]] = []
    seen_ids: Set[Tuple[str, ...]] = set()
    for start in sorted(adj):
        stack = [(start, [start])]
        while stack:
            node, path = stack.pop()
            for nxt in adj.get(node, ()):
                if nxt == start:
                    cyc = _rotate_min(path)
                    key = tuple(cyc)
                    if key not in seen_ids:
                        seen_ids.add(key)
                        cycles.append(cyc)
                elif nxt not in path and len(path) < 16:
                    stack.append((nxt, path + [nxt]))
    return cycles


def _rotate_min(path: List[str]) -> List[str]:
    i = path.index(min(path))
    return path[i:] + path[:i]


# ---------------------------------------------------------------------------
# R1 guarded-by
# ---------------------------------------------------------------------------

class _GuardVisitor(ast.NodeVisitor):
    def __init__(self, model: FileModel, cls: ClassInfo, scope: str,
                 findings: List[Finding]):
        self.model = model
        self.cls = cls
        self.scope = scope
        self.findings = findings
        self._held: Set[str] = set()
        self._parents: Dict[ast.AST, ast.AST] = {}

    def run(self, fn: ast.FunctionDef):
        for parent in ast.walk(fn):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        for child in fn.body:
            self.visit(child)

    def visit_With(self, node: ast.With):
        pushed = []
        for item in node.items:
            attr = _with_lock_attr(item)
            if attr is not None and attr in self.cls.lock_attrs \
                    and attr not in self._held:
                self._held.add(attr)
                pushed.append(attr)
        for child in node.body:
            self.visit(child)
        for attr in pushed:
            self._held.discard(attr)

    def visit_Attribute(self, node: ast.Attribute):
        if isinstance(node.value, ast.Name) and node.value.id == "self" \
                and node.attr in self.cls.guards:
            guard, mode = self.cls.guards[node.attr]
            if guard not in self._held and \
                    (mode == "all" or self._is_write(node)):
                kind = "write" if self._is_write(node) else "read"
                f = Finding(
                    "R1", self.model.relpath, node.lineno, self.scope,
                    node.attr,
                    f"{kind} of self.{node.attr} (guarded-by {guard}) "
                    f"outside `with self.{guard}`")
                if not self.model.ignored(node.lineno, "R1"):
                    self.findings.append(f)
        self.generic_visit(node)

    def _is_write(self, node: ast.Attribute) -> bool:
        if isinstance(node.ctx, (ast.Store, ast.Del)):
            return True
        parent = self._parents.get(node)
        if isinstance(parent, ast.Subscript) and parent.value is node \
                and isinstance(parent.ctx, (ast.Store, ast.Del)):
            return True
        if isinstance(parent, ast.Attribute) and parent.value is node \
                and parent.attr in _MUTATORS:
            gp = self._parents.get(parent)
            if isinstance(gp, ast.Call) and gp.func is parent:
                return True
        return False

    def visit_FunctionDef(self, node):
        pass                                  # deferred execution

    def visit_AsyncFunctionDef(self, node):
        pass

    def visit_Lambda(self, node):
        pass


# ---------------------------------------------------------------------------
# rule drivers
# ---------------------------------------------------------------------------

def _check_r1(model: FileModel, findings: List[Finding]):
    for node in model.tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        cls = model.classes[node.name]
        if not cls.guards:
            continue
        for fn in node.body:
            if not isinstance(fn, ast.FunctionDef):
                continue
            if fn.name in ("__init__", "__post_init__") \
                    or fn.name.endswith("_locked"):
                continue                     # not-yet-shared / by-convention
            v = _GuardVisitor(model, cls, f"{node.name}.{fn.name}",
                              findings)
            v.run(fn)


def _enclosing_function(parents: Dict[ast.AST, ast.AST],
                        node: ast.AST) -> Optional[ast.AST]:
    p = parents.get(node)
    while p is not None and not isinstance(
            p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        p = parents.get(p)
    return p


def _check_r2(model: FileModel, findings: List[Finding]):
    cv_attrs: Set[str] = set()
    for cls in model.classes.values():
        cv_attrs |= cls.cv_attrs
    parents: Dict[ast.AST, ast.AST] = {}
    for parent in ast.walk(model.tree):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent
    for node in ast.walk(model.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "wait"):
            continue
        recv = node.func.value
        rname = recv.attr if isinstance(recv, ast.Attribute) else (
            recv.id if isinstance(recv, ast.Name) else None)
        if rname is None:
            continue
        if rname not in cv_attrs and not _CV_NAME.search(rname):
            continue
        scope = _scope_of(parents, node)
        # (a) must sit inside a while loop within the same function
        fn = _enclosing_function(parents, node)
        p, in_while = parents.get(node), False
        while p is not None and p is not fn:
            if isinstance(p, ast.While):
                in_while = True
                break
            p = parents.get(p)
        if not in_while and not model.ignored(node.lineno, "R2"):
            findings.append(Finding(
                "R2", model.relpath, node.lineno, scope,
                f"{rname}.wait-not-in-while",
                f"{rname}.wait() outside a while-predicate loop "
                f"(missed/spurious wakeups)"))
        # (b) numeric-literal timeout = polling grid
        if node.args and isinstance(node.args[0], ast.Constant) \
                and isinstance(node.args[0].value, (int, float)) \
                and not model.ignored(node.lineno, "R2"):
            findings.append(Finding(
                "R2", model.relpath, node.lineno, scope,
                f"{rname}.wait-literal-timeout-{node.args[0].value}",
                f"{rname}.wait({node.args[0].value!r}): numeric-literal "
                f"timeout — polling; use notification or a computed "
                f"Algorithm-1 deadline"))


def _check_r4(model: FileModel, findings: List[Finding]):
    if any(model.relpath.endswith(sfx) for sfx in SLEEP_ALLOWED):
        return
    parents: Dict[ast.AST, ast.AST] = {}
    for parent in ast.walk(model.tree):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent
    for node in ast.walk(model.tree):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "sleep" \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == "time" \
                and not model.ignored(node.lineno, "R4"):
            findings.append(Finding(
                "R4", model.relpath, node.lineno,
                _scope_of(parents, node), "time.sleep",
                "time.sleep outside the simulated store/BandwidthModel/"
                "trace-replay gap — polling wait"))


def _check_r5(model: FileModel, findings: List[Finding]):
    parents: Dict[ast.AST, ast.AST] = {}
    for parent in ast.walk(model.tree):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent
    for node in ast.walk(model.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "jit"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "jax"
                and node.args):
            continue
        arg = node.args[0]
        if not isinstance(arg, ast.Attribute):
            continue                   # lambda / local def / call result
        base = arg.value
        if isinstance(base, ast.Name) \
                and base.id in model.module_aliases:
            continue                   # module-level function: one entry
        if model.ignored(node.lineno, "R5"):
            continue
        target = ast.unparse(arg)
        findings.append(Finding(
            "R5", model.relpath, node.lineno, _scope_of(parents, node),
            f"jit-bound-method-{target}",
            f"jax.jit({target}): bound-method jit shares the global "
            f"pjit cache across instances/dispatch modes (PR-5 bug "
            f"class) — jit a per-instance closure or key the cache on "
            f"the kernel-registry fingerprint"))


def _scope_of(parents: Dict[ast.AST, ast.AST], node: ast.AST) -> str:
    names: List[str] = []
    p: Optional[ast.AST] = node
    while p is not None:
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            names.append(p.name)
        p = parents.get(p)
    return ".".join(reversed(names)) if names else "<module>"


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def lint_source(source: str, relpath: str = "<string>") -> List[Finding]:
    """Lint one source string (the fixture-test entry point).  R3 runs
    file-locally here; cross-file edges need :func:`lint_paths`."""
    model = FileModel(source, relpath)
    return _lint_models([model])


def _lint_models(models: Sequence[FileModel]) -> List[Finding]:
    findings: List[Finding] = []
    for m in models:
        _check_r1(m, findings)
        _check_r2(m, findings)
        _check_r4(m, findings)
        _check_r5(m, findings)
    _, cycles = build_static_lockgraph(models)
    for cyc in cycles:
        m0 = models[0]
        findings.append(Finding(
            "R3", m0.relpath if len(models) == 1 else "<project>",
            0, "<lockgraph>", "cycle:" + "->".join(cyc),
            f"static lock-order cycle: {' -> '.join(cyc + [cyc[0]])}"))
    # dedupe identical IDs (keep first occurrence's line)
    out, seen = [], set()
    for f in findings:
        if f.id not in seen:
            seen.add(f.id)
            out.append(f)
    return out


def iter_py_files(root: str) -> Iterable[Tuple[str, str]]:
    """Yield (abspath, relpath) for every .py under ``root`` (or the
    single file)."""
    if os.path.isfile(root):
        yield root, os.path.basename(root)
        return
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                full = os.path.join(dirpath, fn)
                yield full, os.path.relpath(full, root)


def load_models(root: str) -> List[FileModel]:
    models = []
    for full, rel in iter_py_files(root):
        with open(full) as f:
            models.append(FileModel(f.read(), rel))
    return models


def lint_paths(roots: Sequence[str]) -> List[Finding]:
    models: List[FileModel] = []
    for root in roots:
        models.extend(load_models(root))
    return _lint_models(models)


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def load_baseline(path: str) -> Dict[str, str]:
    """{finding-id: justification} from the reviewed baseline file.
    Format: one ID per line, justification after ``  #``; blank lines
    and full-line comments ignored."""
    entries: Dict[str, str] = {}
    try:
        with open(path) as f:
            for raw in f:
                line = raw.strip()
                if not line or line.startswith("#"):
                    continue
                fid, _, just = line.partition(" #")
                entries[fid.strip()] = just.strip()
    except OSError:
        pass
    return entries


def apply_baseline(findings: Sequence[Finding], baseline: Dict[str, str]
                   ) -> Tuple[List[Finding], List[str]]:
    """(unsuppressed findings, stale baseline IDs that matched
    nothing)."""
    ids = {f.id for f in findings}
    unsuppressed = [f for f in findings if f.id not in baseline]
    stale = sorted(b for b in baseline if b not in ids)
    return unsuppressed, stale
