"""CLI: ``python -m repro.analysis {lint,lockgraph,report}``.

* ``lint [paths...]`` — run R1–R5 over the given roots (default
  ``src/repro``); exit 1 on findings not covered by the baseline or an
  inline ``# analysis: ignore``.  Stale baseline entries are warnings.
* ``lockgraph [paths...] [--observed probe.json] [--json-out f]`` —
  build the static lock-order graph, merge an observed-probe artifact
  if given, exit 1 on cycles.
* ``report [--observed probe.json]`` — the human-readable merged
  report (edges, cycles, hazards, hold/wait hotspots).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from . import lint, lockgraph


def _default_roots():
    for cand in ("src/repro", os.path.join(
            os.path.dirname(__file__), "..")):
        if os.path.isdir(cand):
            return [os.path.normpath(cand)]
    return ["."]


def _default_baseline():
    cand = os.path.join("tests", "analysis_baseline.txt")
    return cand if os.path.exists(cand) else None


def cmd_lint(args) -> int:
    findings = lint.lint_paths(args.paths or _default_roots())
    baseline = lint.load_baseline(args.baseline) if args.baseline else {}
    unsuppressed, stale = lint.apply_baseline(findings, baseline)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({
                "kind": "repro-analysis-lint",
                "findings": [vars(x) | {"id": x.id} for x in findings],
                "unsuppressed": [x.id for x in unsuppressed],
                "stale_baseline": stale,
            }, f, indent=2)
    for f in unsuppressed:
        print(f.render())
    for fid in stale:
        print(f"warning: stale baseline entry (no such finding): {fid}",
              file=sys.stderr)
    n_base = len(findings) - len(unsuppressed)
    print(f"{len(findings)} finding(s), {n_base} baselined, "
          f"{len(unsuppressed)} blocking.")
    return 1 if unsuppressed else 0


def cmd_lockgraph(args) -> int:
    models = []
    for root in (args.paths or _default_roots()):
        models.extend(lint.load_models(root))
    edges, _ = lint.build_static_lockgraph(models)
    observed = lockgraph.load_observed(args.observed) \
        if args.observed else None
    report = lockgraph.merge(edges, observed)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2)
    print(lockgraph.render(report))
    if report["cycles"]:
        print(f"FAIL: {len(report['cycles'])} lock-order cycle(s).",
              file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.analysis",
                                description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)

    lp = sub.add_parser("lint", help="run rules R1-R5")
    lp.add_argument("paths", nargs="*")
    lp.add_argument("--baseline", default=_default_baseline())
    lp.add_argument("--json-out")
    lp.set_defaults(fn=cmd_lint)

    gp = sub.add_parser("lockgraph",
                        help="static+observed lock-order graph")
    gp.add_argument("paths", nargs="*")
    gp.add_argument("--observed")
    gp.add_argument("--json-out")
    gp.set_defaults(fn=cmd_lockgraph)

    rp = sub.add_parser("report", help="human-readable merged report")
    rp.add_argument("paths", nargs="*")
    rp.add_argument("--observed")
    rp.add_argument("--json-out")
    rp.set_defaults(fn=cmd_lockgraph)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
