"""Merge the static lock-order graph with the runtime probe's observed
graph and render JSON / human reports.

The static side (``lint.build_static_lockgraph``) sees lexically nested
``with self.<lock>`` acquisitions plus one level of typed-attribute call
resolution; the runtime side (``locks.Probe``) sees every real
acquisition order the instrumented test run exercised, including
dynamic dispatch the AST cannot follow (callbacks, executor tasks,
closures handed across modules).  Merging both gives the strongest
cycle check either side can support: a cycle in the merged graph is a
deadlock hazard even if no single run interleaved into it.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from . import lint


def load_observed(path: str) -> Dict:
    """A ``repro-analysis-observed`` artifact dumped by the probe
    (``REPRO_ANALYZE_OUT`` or ``Probe.dump``)."""
    with open(path) as f:
        data = json.load(f)
    if data.get("kind") != "repro-analysis-observed":
        raise ValueError(f"{path}: not a repro-analysis-observed artifact")
    return data


def merge(static_edges: Sequence[lint.LockEdge],
          observed: Optional[Dict] = None) -> Dict:
    """Build the merged lockgraph report dict."""
    edges: Dict[Tuple[str, str], Dict] = {}
    for e in static_edges:
        rec = edges.setdefault((e.src, e.dst), {
            "src": e.src, "dst": e.dst, "static": [], "observed": 0})
        if e.where not in rec["static"]:
            rec["static"].append(e.where)
    obs_edges = (observed or {}).get("edges", [])
    for rec in obs_edges:
        src, dst, n = rec["src"], rec["dst"], rec.get("count", 1)
        merged = edges.setdefault((src, dst), {
            "src": src, "dst": dst, "static": [], "observed": 0})
        merged["observed"] += n
    cycles = lint.find_cycles(set(edges))
    report = {
        "kind": "repro-analysis-lockgraph",
        "edges": sorted(edges.values(),
                        key=lambda r: (r["src"], r["dst"])),
        "cycles": cycles,
        "locks": (observed or {}).get("locks", {}),
        "cv_waits": (observed or {}).get("cv_waits", {}),
        "hazards": (observed or {}).get("hazards", []),
        "observed_cycles": (observed or {}).get("cycles", []),
    }
    return report


def render(report: Dict) -> str:
    """Human-readable text for the ``report`` subcommand."""
    out: List[str] = []
    edges = report["edges"]
    out.append(f"lock-order graph: {len(edges)} edge(s)")
    for rec in edges:
        tags = []
        if rec["static"]:
            tags.append("static:" + ",".join(rec["static"][:2]))
        if rec["observed"]:
            tags.append(f"observed x{rec['observed']}")
        out.append(f"  {rec['src']} -> {rec['dst']}   [{'; '.join(tags)}]")
    if report["cycles"]:
        out.append(f"CYCLES ({len(report['cycles'])}) — deadlock hazards:")
        for cyc in report["cycles"]:
            out.append("  " + " -> ".join(cyc + [cyc[0]]))
    else:
        out.append("no cycles.")
    if report.get("hazards"):
        out.append(f"I/O-under-lock hazards ({len(report['hazards'])}):")
        for hz in report["hazards"]:
            out.append(f"  {hz['io']} with held "
                       f"{hz['held']} ({hz['thread']})")
    locks = report.get("locks") or {}
    if locks:
        out.append("lock hotspots (by total hold time):")
        ranked = sorted(locks.items(),
                        key=lambda kv: kv[1].get("hold_s", 0.0),
                        reverse=True)
        for name, st in ranked:
            out.append(
                f"  {name}: acquires={st.get('acquires', 0)} "
                f"contended={st.get('contended', 0)} "
                f"hold={st.get('hold_s', 0.0) * 1e3:.1f}ms "
                f"(max {st.get('hold_max_s', 0.0) * 1e3:.2f}ms) "
                f"wait={st.get('wait_s', 0.0) * 1e3:.1f}ms "
                f"(max {st.get('wait_max_s', 0.0) * 1e3:.2f}ms)")
    cvs = report.get("cv_waits") or {}
    if cvs:
        out.append("condition waits:")
        for name, st in sorted(cvs.items()):
            out.append(
                f"  {name}: waits={st.get('waits', 0)} "
                f"timed={st.get('timed_waits', 0)} "
                f"waited={st.get('wait_s', 0.0) * 1e3:.1f}ms")
    return "\n".join(out)
