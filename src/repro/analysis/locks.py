"""Instrumented lock factory + runtime concurrency probe.

Every ``repro`` module obtains its synchronization primitives from this
factory (:func:`make_lock` / :func:`make_rlock` / :func:`make_condition`)
instead of calling ``threading.Lock()`` directly.  In normal operation
the factory returns the plain ``threading`` objects — zero overhead, no
behavior change.  With ``REPRO_ANALYZE=1`` in the environment it
returns instrumented wrappers that feed one process-global
:class:`Probe`:

  * **per-thread held-lock sets** — every acquire/release maintains the
    acquiring thread's stack of held locks (reentrant acquires counted,
    condition waits correctly *drop* the lock for their duration);
  * **observed acquisition-order graph** — acquiring B while holding A
    records the edge ``A -> B``; a cycle in this graph is a real
    lock-order inversion observed at runtime (deadlock hazard even if
    this particular run got lucky with timing);
  * **wait / hold durations** — per lock: acquire count, contended-wait
    time and max, hold time and max — the data behind the
    lock-hotspot report;
  * **condition-wait discipline** — counts of ``Condition.wait`` calls
    and how many passed a timeout (the event-driven pipeline should
    show ~zero *polling* timeouts; Algorithm-1 deadline wakes are the
    intended exceptions);
  * **lock-held-across-I/O hazards** — the store's read paths call
    :func:`note_io`; reaching one with any instrumented lock held means
    a lock is pinned across (simulated) device I/O, serializing
    every sibling stream behind one read.

The probe's :meth:`Probe.report` snapshot merges with the static
lock-order graph via ``python -m repro.analysis lockgraph``; set
``REPRO_ANALYZE_OUT=<path>`` to dump the JSON artifact at process exit
(what the CI analysis job uploads).

The probe's own internal mutex is a *plain* ``threading.Lock`` and is
never self-instrumented.
"""
from __future__ import annotations

import atexit
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple


def enabled() -> bool:
    """True when the instrumented wrappers are active (REPRO_ANALYZE=1).

    Checked at primitive *construction* time: objects created while
    disabled stay plain, objects created while enabled stay
    instrumented — flipping the env var mid-process affects only locks
    created afterwards (tests construct their subjects after setting
    it)."""
    return os.environ.get("REPRO_ANALYZE", "") not in ("", "0")


# ---------------------------------------------------------------------------
# probe
# ---------------------------------------------------------------------------

class _Held:
    __slots__ = ("name", "t_acquired", "count")

    def __init__(self, name: str, t_acquired: float):
        self.name = name
        self.t_acquired = t_acquired
        self.count = 1


class Probe:
    """Process-global recorder behind every instrumented primitive."""

    def __init__(self):
        self._mu = threading.Lock()          # internal; never instrumented
        self._tls = threading.local()
        self.reset()

    # ------------------------------------------------------------- lifecycle
    def reset(self):
        with self._mu:
            # (holder, acquired) -> times observed nested
            self.edges: Dict[Tuple[str, str], int] = {}
            # name -> {acquires, contended, wait_s, wait_max_s,
            #          hold_s, hold_max_s}
            self.locks: Dict[str, Dict[str, float]] = {}
            # name -> {waits, timed_waits, wait_s}
            self.cv_waits: Dict[str, Dict[str, float]] = {}
            # list of {"io": tag, "held": [...], "thread": name}
            self.hazards: List[Dict[str, Any]] = []
            self._cycles: List[List[str]] = []

    def _held(self) -> List[_Held]:
        h = getattr(self._tls, "held", None)
        if h is None:
            h = self._tls.held = []
        return h

    # ------------------------------------------------------------ recording
    def _lock_rec(self, name: str) -> Dict[str, float]:
        rec = self.locks.get(name)
        if rec is None:
            rec = self.locks[name] = {
                "acquires": 0, "contended": 0, "wait_s": 0.0,
                "wait_max_s": 0.0, "hold_s": 0.0, "hold_max_s": 0.0}
        return rec

    def on_acquired(self, name: str, wait_s: float, contended: bool):
        """Called by a wrapper after its raw acquire succeeded."""
        held = self._held()
        for h in held:
            if h.name == name:               # reentrant re-acquire
                h.count += 1
                return
        now = time.monotonic()
        with self._mu:
            rec = self._lock_rec(name)
            rec["acquires"] += 1
            rec["wait_s"] += wait_s
            rec["wait_max_s"] = max(rec["wait_max_s"], wait_s)
            if contended:
                rec["contended"] += 1
            for h in held:
                if h.name != name:
                    self._add_edge_locked(h.name, name)
        held.append(_Held(name, now))

    def on_released(self, name: str):
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i].name == name:
                held[i].count -= 1
                if held[i].count == 0:
                    h = held.pop(i)
                    dur = time.monotonic() - h.t_acquired
                    with self._mu:
                        rec = self._lock_rec(name)
                        rec["hold_s"] += dur
                        rec["hold_max_s"] = max(rec["hold_max_s"], dur)
                return
        # release of a lock this thread never recorded (e.g. handed
        # across threads) — count nothing rather than corrupt the stack

    def suspend_held(self, name: str) -> Optional[_Held]:
        """A Condition.wait is releasing ``name``: take it off the held
        stack for the wait's duration (charging the hold so far)."""
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i].name == name:
                h = held.pop(i)
                dur = time.monotonic() - h.t_acquired
                with self._mu:
                    rec = self._lock_rec(name)
                    rec["hold_s"] += dur
                    rec["hold_max_s"] = max(rec["hold_max_s"], dur)
                return h
        return None

    def resume_held(self, h: Optional[_Held]):
        if h is not None:
            h.t_acquired = time.monotonic()
            self._held().append(h)

    def on_cv_wait(self, name: str, timeout: Optional[float],
                   waited_s: float):
        with self._mu:
            rec = self.cv_waits.get(name)
            if rec is None:
                rec = self.cv_waits[name] = {
                    "waits": 0, "timed_waits": 0, "wait_s": 0.0}
            rec["waits"] += 1
            rec["wait_s"] += waited_s
            if timeout is not None:
                rec["timed_waits"] += 1

    def note_io(self, tag: str):
        """An I/O region was entered; any held instrumented lock is a
        lock-held-across-I/O hazard."""
        held = [h.name for h in self._held()]
        if not held:
            return
        with self._mu:
            entry = {"io": tag, "held": held,
                     "thread": threading.current_thread().name}
            if not any(hz["io"] == tag and hz["held"] == held
                       for hz in self.hazards):
                self.hazards.append(entry)

    # ---------------------------------------------------------- cycle check
    def _add_edge_locked(self, a: str, b: str):
        key = (a, b)
        fresh = key not in self.edges
        self.edges[key] = self.edges.get(key, 0) + 1
        if fresh:
            cyc = find_cycle({k for k in self.edges}, start=b, target=a)
            if cyc is not None:
                self._cycles.append([a] + cyc)

    def cycles(self) -> List[List[str]]:
        with self._mu:
            return [list(c) for c in self._cycles]

    # -------------------------------------------------------------- report
    def report(self) -> Dict[str, Any]:
        """JSON-able snapshot: the observed half of the lockgraph
        artifact."""
        with self._mu:
            return {
                "kind": "repro-analysis-observed",
                "edges": [{"src": a, "dst": b, "count": n}
                          for (a, b), n in sorted(self.edges.items())],
                "locks": {k: dict(v)
                          for k, v in sorted(self.locks.items())},
                "cv_waits": {k: dict(v)
                             for k, v in sorted(self.cv_waits.items())},
                "hazards": [dict(h) for h in self.hazards],
                "cycles": [list(c) for c in self._cycles],
            }

    def dump(self, path: str):
        with open(path, "w") as f:
            json.dump(self.report(), f, indent=2, sort_keys=True)


def find_cycle(edges, start: str, target: str) -> Optional[List[str]]:
    """DFS path ``start -> ... -> target`` over directed ``edges``
    (iterable of (a, b)); returns the node path including both ends, or
    None.  Adding edge target->start therefore closes a cycle iff this
    returns a path."""
    adj: Dict[str, List[str]] = {}
    for a, b in edges:
        adj.setdefault(a, []).append(b)
    stack = [(start, [start])]
    seen = set()
    while stack:
        node, path = stack.pop()
        if node == target:
            return path
        if node in seen:
            continue
        seen.add(node)
        for nxt in adj.get(node, ()):
            stack.append((nxt, path + [nxt]))
    return None


probe = Probe()


def note_io(tag: str):
    """Module-level hook for I/O call sites (no-op when disabled)."""
    if enabled():
        probe.note_io(tag)


# ---------------------------------------------------------------------------
# instrumented primitives
# ---------------------------------------------------------------------------

class _InstrumentedLock:
    """threading.Lock with probe bookkeeping (non-reentrant)."""

    _reentrant = False

    def __init__(self, name: str):
        self.name = name
        self._raw = self._make_raw()

    @staticmethod
    def _make_raw():
        return threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1):
        t0 = time.monotonic()
        contended = not self._raw.acquire(False)
        ok = True
        if contended:
            if not blocking:
                return False
            ok = self._raw.acquire(True, timeout)
        if ok:
            probe.on_acquired(self.name, time.monotonic() - t0, contended)
        return ok

    def release(self):
        probe.on_released(self.name)
        self._raw.release()

    def locked(self):
        return self._raw.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class _InstrumentedRLock(_InstrumentedLock):
    _reentrant = True

    @staticmethod
    def _make_raw():
        return threading.RLock()


class _InstrumentedCondition:
    """Condition over an instrumented RLock.

    Composes a plain ``threading.Condition`` sharing the *raw* inner
    lock, so wait/notify semantics are stock; the wrapper only keeps
    the probe's held-stack honest — in particular a waiter's lock is
    *suspended* (not held) for the duration of the wait.
    """

    def __init__(self, name: str,
                 lock: Optional[_InstrumentedRLock] = None):
        self.name = name
        self._ilock = lock if lock is not None else _InstrumentedRLock(name)
        self._cond = threading.Condition(self._ilock._raw)

    # lock protocol ------------------------------------------------------
    def acquire(self, *a, **kw):
        return self._ilock.acquire(*a, **kw)

    def release(self):
        self._ilock.release()

    def __enter__(self):
        self._ilock.acquire()
        return self

    def __exit__(self, *exc):
        self._ilock.release()
        return False

    # condition protocol -------------------------------------------------
    def wait(self, timeout: Optional[float] = None):
        t0 = time.monotonic()
        saved = probe.suspend_held(self.name)
        try:
            # primitive layer: the while-predicate loop lives at every
            # call site, which R2 checks there
            return self._cond.wait(timeout)  # analysis: ignore[R2]
        finally:
            probe.resume_held(saved)
            probe.on_cv_wait(self.name, timeout, time.monotonic() - t0)

    def wait_for(self, predicate, timeout: Optional[float] = None):
        # stock implementation in terms of our wait(), so every
        # underlying wait is recorded
        endtime = None
        result = predicate()
        while not result:
            if timeout is not None:
                if endtime is None:
                    endtime = time.monotonic() + timeout
                waittime = endtime - time.monotonic()
                if waittime <= 0:
                    break
                self.wait(waittime)
            else:
                self.wait()
            result = predicate()
        return result

    def notify(self, n: int = 1):
        self._cond.notify(n)

    def notify_all(self):
        self._cond.notify_all()


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

def make_lock(name: str) -> Any:
    """A mutex for ``name`` (e.g. ``"KernelRegistry._lock"``): plain
    ``threading.Lock`` normally, instrumented under REPRO_ANALYZE=1."""
    return _InstrumentedLock(name) if enabled() else threading.Lock()


def make_rlock(name: str) -> Any:
    return _InstrumentedRLock(name) if enabled() else threading.RLock()


def make_condition(name: str, lock: Any = None) -> Any:
    """A condition variable for ``name``.  ``lock`` (optional) must come
    from this factory too when instrumenting."""
    if enabled():
        ilock = lock if isinstance(lock, _InstrumentedRLock) else None
        return _InstrumentedCondition(name, ilock)
    return threading.Condition(lock)


# ---------------------------------------------------------------------------
# artifact dump at exit
# ---------------------------------------------------------------------------

def _dump_at_exit():          # pragma: no cover - exercised by CI job
    out = os.environ.get("REPRO_ANALYZE_OUT")
    if out and enabled():
        try:
            probe.dump(out)
        except OSError:
            pass


atexit.register(_dump_at_exit)
