"""Single-token decode attention as a Pallas TPU kernel.

Decode is memory-bound: one query token must stream the whole KV cache
from HBM.  The kernel tiles the cache sequence (split-K) with the grid's
innermost dimension and merges partial softmax statistics in VMEM
scratch, processing all ``q_rep`` query heads of one KV head together so
each K/V block is read exactly once (GQA-aware).

Ring-buffer sliding-window caches are supported: slot ``j`` of a cache
with ``S_max == window`` holds absolute position ``p`` where
``p ≡ j (mod window)``; validity is derived in-kernel from ``pos``.

:func:`decode_attention_paged` is the block-paged variant: K/V live in
a shared physical pool of fixed-size pages (``(n_pages, K, pt, dh)``)
and each batch row owns a *page table* mapping logical page j to a
physical page id.  The split-K grid already tiles the cache sequence,
so paging is purely an index-map change — the table rides the scalar
prefetch channel (``num_scalar_prefetch=2``) and logical cache block
``s`` is fetched from physical block ``(table[b, s // r], s % r)``
where ``r = pt // bs``.  The kernel body (online softmax, GQA packing,
ring-window validity over *logical* positions) is shared verbatim with
the slotted kernel; unallocated table entries may point anywhere —
their positions are beyond ``pos``, so masking zeroes them exactly.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            window: int, bs: int, ns: int, rep: int, scale: float):
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]
    s_lo = si * bs

    # Skip cache blocks that are entirely invalid (beyond pos for a full
    # cache; a full ring buffer has no invalid blocks).
    if window > 0:
        run = jnp.logical_or(pos >= window, s_lo <= pos)
    else:
        run = s_lo <= pos

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (rep, dh)
        k = k_ref[0, 0].astype(jnp.float32)              # (bs, dh)
        v = v_ref[0, 0].astype(jnp.float32)              # (bs, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                    # (rep, bs)

        idx = s_lo + jax.lax.broadcasted_iota(jnp.int32, (rep, bs), 1)
        if window > 0:
            p_at = pos - ((pos - idx) % window)
            valid = jnp.logical_and(p_at >= 0, p_at > pos - window)
        else:
            valid = idx <= pos
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(si == ns - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "bs", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int = 0, bs: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, dh); caches: (B, K, S_max, dh) kv-head-major; pos: (B,).

    Returns (B, H, dh).  See module docstring for ring-buffer semantics.
    """
    B, H, dh = q.shape
    K, S_max = k_cache.shape[1], k_cache.shape[2]
    rep = H // K
    bs = min(bs, S_max)
    assert S_max % bs == 0
    ns = S_max // bs

    qr = q.reshape(B, K, rep, dh)
    kc = k_cache                                         # (B, K, S, dh)
    vc = v_cache

    grid = (B, K, ns)
    kern = functools.partial(_kernel, window=window, bs=bs, ns=ns, rep=rep,
                             scale=1.0 / math.sqrt(dh))

    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rep, dh), lambda b, h, s, _: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bs, dh), lambda b, h, s, _: (b, h, s, 0)),
                pl.BlockSpec((1, 1, bs, dh), lambda b, h, s, _: (b, h, s, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rep, dh),
                                   lambda b, h, s, _: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rep, LANES), jnp.float32),
                pltpu.VMEM((rep, LANES), jnp.float32),
                pltpu.VMEM((rep, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, rep, dh), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos.astype(jnp.int32), qr, kc, vc)
    return out.reshape(B, H, dh)


@functools.partial(
    jax.jit, static_argnames=("window", "bs", "interpret"))
def decode_attention_paged(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, tables: jax.Array,
                           pos: jax.Array, *, window: int = 0,
                           bs: int = 128, interpret: bool = False
                           ) -> jax.Array:
    """q: (B, H, dh); page pools: (P, K, pt, dh) kv-head-major, shared
    across the batch; tables: (B, NP) int32 physical page ids (logical
    sequence extent NP * pt per row); pos: (B,).  Returns (B, H, dh).

    ``bs`` must divide ``pt`` so every grid block lives inside one
    page.  Ring-window semantics are identical to the slotted kernel
    over the logical extent.
    """
    B, H, dh = q.shape
    K, pt = k_pages.shape[1], k_pages.shape[2]
    NP = tables.shape[1]
    rep = H // K
    bs = min(bs, pt)
    assert pt % bs == 0, (pt, bs)
    r = pt // bs                     # cache blocks per page
    ns = NP * r

    qr = q.reshape(B, K, rep, dh)
    grid = (B, K, ns)
    # the body is the slotted kernel's: s_lo = si * bs is the *logical*
    # offset of block si, which the shared masking math consumes; only
    # the fetch location below goes through the page table
    kern = functools.partial(_kernel, window=window, bs=bs, ns=ns, rep=rep,
                             scale=1.0 / math.sqrt(dh))

    def paged_kern(pos_ref, tbl_ref, *rest):
        del tbl_ref                  # consumed by the index maps only
        kern(pos_ref, *rest)

    def kv_map(b, h, s, pos_ref, tbl_ref):
        del pos_ref
        return (tbl_ref[b, s // r], h, s % r, 0)

    out = pl.pallas_call(
        paged_kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rep, dh),
                             lambda b, h, s, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bs, dh), kv_map),
                pl.BlockSpec((1, 1, bs, dh), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, rep, dh),
                                   lambda b, h, s, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rep, LANES), jnp.float32),
                pltpu.VMEM((rep, LANES), jnp.float32),
                pltpu.VMEM((rep, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, rep, dh), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos.astype(jnp.int32), tables.astype(jnp.int32), qr, k_pages, v_pages)
    return out.reshape(B, H, dh)
