"""Fused-dequant w8a16 matmul as a Pallas TPU kernel.

Decode is memory-bound on weights: every step streams each weight
matrix from HBM once.  Serving int8 weights in place halves that
traffic — the kernel reads int8 tiles plus per-column f32 scales,
upcasts *in-register* (``w.astype(f32) * scale``) and feeds the MXU
directly, so no dequantized copy ever exists in HBM or VMEM beyond the
current tile.

Grid is (M-tiles, N-tiles, K-tiles) with K innermost ("arbitrary"):
partial products accumulate into an f32 VMEM scratch and flush to the
output block on the last K step — the same scratch-merge idiom as the
decode-attention split-K kernel.  Non-divisible shapes are padded up to
the tile grid and sliced back (zero K padding contributes zero to the
accumulator).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, s_ref, o_ref, acc_scr, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)                   # (bm, bk)
    w = w_ref[...].astype(jnp.float32)                   # (bk, bn)
    s = s_ref[...].astype(jnp.float32)                   # (1, bn)
    acc_scr[...] += jax.lax.dot_general(
        x, w * s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("out_dtype", "bm", "bk", "bn", "interpret"))
def quant_matmul(x: jax.Array, w: jax.Array, scale: jax.Array, *,
                 out_dtype=None, bm: int = 256, bk: int = 512,
                 bn: int = 256, interpret: bool = False) -> jax.Array:
    """x: (m, k) activations; w: (k, n) int8; scale: (n,) f32 per-column.

    Returns (m, n) in ``out_dtype`` (default: x.dtype), numerically the
    dequant-then-matmul reference with dequant fused per tile.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert scale.shape == (n,), (scale.shape, n)
    out_dtype = out_dtype or x.dtype
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)

    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    xp = jnp.pad(x, ((0, pm), (0, pk))) if (pm or pk) else x
    wp = jnp.pad(w, ((0, pk), (0, pn))) if (pk or pn) else w
    sp = jnp.pad(scale, (0, pn)) if pn else scale
    M, K = xp.shape
    N = wp.shape[1]
    nm, nn, nk = M // bm, N // bn, K // bk

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, wp, sp[None, :])
    if pm or pn:
        out = out[:m, :n]
    return out
