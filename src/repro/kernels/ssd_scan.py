"""Mamba-2 SSD (state-space duality) as a chunked Pallas TPU kernel.

The SSD decomposition splits the sequence into chunks of ``bc`` steps:

  * intra-chunk: a (bc x bc) lower-triangular "attention-like" matmul
    ``(C B^T ⊙ L) (dt·x)`` — quadratic only within the chunk, runs on the
    MXU;
  * inter-chunk: a rank-N state ``h`` (dp x N) carried sequentially across
    chunks in VMEM scratch — ``y += (C ⊙ decay) h_prev`` and
    ``h = decay_total·h_prev + B^T (dt·x ⊙ decay_rem)``.

Grid = (B, n_heads, n_chunks) with chunks innermost (sequential), so the
state scratch persists across the chunk dimension and is reset at c == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(A_ref, x_ref, dt_ref, B_ref, C_ref, y_ref, h_scr, *, bc: int):
    h = pl.program_id(1)
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a_h = A_ref[h]                                       # scalar, negative
    dt = dt_ref[0, 0].astype(jnp.float32)                # (bc,)
    x = x_ref[0, 0].astype(jnp.float32)                  # (bc, dp)
    Bm = B_ref[0].astype(jnp.float32)                    # (bc, N)
    Cm = C_ref[0].astype(jnp.float32)                    # (bc, N)

    da = dt * a_h                                        # (bc,)
    cum = jnp.cumsum(da)                                 # (bc,) inclusive
    # L[i, j] = exp(cum_i - cum_j) for i >= j else 0   (segment-sum matrix)
    li = jax.lax.broadcasted_iota(jnp.int32, (bc, bc), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (bc, bc), 1)
    diff = cum[:, None] - cum[None, :] + da[None, :]     # exclusive at j
    L = jnp.where(li >= lj, jnp.exp(diff - da[None, :]), 0.0)

    xd = x * dt[:, None]                                 # (bc, dp)

    # intra-chunk quadratic part
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (bc, bc)
    y_intra = jax.lax.dot_general(cb * L, xd, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state h_prev (dp, N)
    c_dec = Cm * jnp.exp(cum)[:, None]                   # (bc, N)
    y_inter = jax.lax.dot_general(c_dec, h_scr[...],
                                  (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = exp(sum da) h_prev + sum_t decay_rem_t * xd_t B_t^T
    total = jnp.exp(cum[-1])
    rem = jnp.exp(cum[-1] - cum)                         # (bc,)
    xw = xd * rem[:, None]                               # (bc, dp)
    upd = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (dp, N)
    h_scr[...] = h_scr[...] * total + upd


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, bc: int = 128,
             interpret: bool = False) -> jax.Array:
    """Chunked SSD.  Shapes as in :func:`repro.kernels.ref.ssd`:

    x (b, nh, S, dp); dt (b, nh, S) positive; A (nh,) negative;
    B, C (b, S, N).  Returns y (b, nh, S, dp).
    """
    b, nh, S, dp = x.shape
    N = B.shape[-1]
    bc = min(bc, S)
    assert S % bc == 0
    nc = S // bc

    grid = (b, nh, nc)
    kern = functools.partial(_kernel, bc=bc)

    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bc, dp), lambda i, h, c, _: (i, h, c, 0)),
                pl.BlockSpec((1, 1, bc), lambda i, h, c, _: (i, h, c)),
                pl.BlockSpec((1, bc, N), lambda i, h, c, _: (i, c, 0)),
                pl.BlockSpec((1, bc, N), lambda i, h, c, _: (i, c, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, bc, dp),
                                   lambda i, h, c, _: (i, h, c, 0)),
            scratch_shapes=[pltpu.VMEM((dp, N), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, nh, S, dp), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt, B, C)
