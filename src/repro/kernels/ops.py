"""Kernel dispatch layer.

Every op has three implementations:

  * **pallas**   — the TPU kernel (``<name>.py``), the deployment target;
  * **interpret**— the same kernel body executed in interpret mode (CPU
                   correctness validation; enabled in kernel tests via
                   ``REPRO_PALLAS=interpret``);
  * **xla**      — a memory-efficient pure-jnp fallback with identical
                   semantics.  This is what the CPU dry-run lowers (the
                   roofline math — FLOPs, bytes, collectives — is the
                   same), and what tests use as the "efficient oracle".

Dispatch: ``REPRO_PALLAS`` env var ∈ {auto (default), pallas, interpret,
xla}.  ``auto`` → pallas on TPU backends, xla elsewhere.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas
from repro.kernels.rglru_scan import rglru_scan as _rglru_pallas
from repro.kernels.weight_transform import weight_transform as _wt_pallas

NEG_INF = -1e30


def _mode() -> str:
    m = os.environ.get("REPRO_PALLAS", "auto")
    if m == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return m


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _xla_flash(q, k, v, *, causal: bool, window: int, bk: int = 1024):
    """Blocked online-softmax attention in pure jnp — O(S·bk) memory,
    identical math to the Pallas kernel.

    The KV-block loop is a *Python* loop (nk <= ~32 for every assigned
    cell): the lowered HLO contains no while op, so the dry-run's
    ``cost_analysis`` is exact.  Blocks that are fully masked out
    (above the causal diagonal / outside the sliding window) are
    skipped at trace time — matching the Pallas kernel's ``pl.when``
    pruning, so HLO FLOPs reflect the real kernel's work."""
    B, H, S, dh = q.shape
    K, T = k.shape[1], k.shape[2]
    rep = H // K
    bk = min(bk, T)
    if T % bk:
        pad = (-T) % bk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Tp = T + pad
    else:
        Tp = T
    nk = Tp // bk
    q_offset = T - S

    # dots consume q/k/v in their stored dtype with f32 accumulation
    # (MXU semantics) — no materialized f32 copies of the slabs
    scale = 1.0 / float(dh) ** 0.5
    qr = q.reshape(B, K, rep, S, dh)
    qpos = q_offset + jnp.arange(S)

    m = jnp.full((B, K, rep, S), NEG_INF, jnp.float32)
    l = jnp.zeros((B, K, rep, S), jnp.float32)
    acc = jnp.zeros((B, K, rep, S, dh), jnp.float32)

    for ki in range(nk):
        k_lo = ki * bk
        # trace-time block pruning (mirrors pl.when in the kernel)
        if causal and k_lo > q_offset + S - 1:
            continue
        if causal and window > 0 and k_lo + bk - 1 <= q_offset - window:
            continue
        ks = k[:, :, k_lo:k_lo + bk]
        vs = v[:, :, k_lo:k_lo + bk]
        s = jnp.einsum("bkrsd,bktd->bkrst", qr, ks,
                       preferred_element_type=jnp.float32) * scale
        kpos = k_lo + jnp.arange(bk)
        mask = (kpos[None, :] < T)
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
            if window > 0:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
        elif window > 0:
            mask = mask & (jnp.abs(kpos[None, :] - qpos[:, None]) < window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkrst,bktd->bkrsd", p.astype(v.dtype), vs,
            preferred_element_type=jnp.float32)
        m = m_new

    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(B, H, S, dh)
    return out.astype(q.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, S, H, dh); k, v: (B, T, K, dh) — model layout (seq-major).
    Returns (B, S, H, dh)."""
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    return flash_attention_kvmajor(q, kt, vt, causal=causal, window=window)


def flash_attention_kvmajor(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool = True, window: int = 0
                            ) -> jax.Array:
    """q: (B, S, H, dh); k, v: (B, K, T, dh) — cache layout (kv-major;
    chunked prefill attends directly against cache slices, no transpose).
    Returns (B, S, H, dh)."""
    qt = jnp.swapaxes(q, 1, 2)
    mode = _mode()
    if mode == "pallas":
        o = _flash_pallas(qt, k, v, causal=causal, window=window)
    elif mode == "interpret":
        o = _flash_pallas(qt, k, v, causal=causal, window=window,
                          interpret=True)
    else:
        o = _xla_flash(qt, k, v, causal=causal, window=window)
    return jnp.swapaxes(o, 1, 2)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int = 0) -> jax.Array:
    """q: (B, H, dh); caches: (B, S_max, K, dh); pos: (B,). -> (B, H, dh)."""
    mode = _mode()
    if mode == "pallas":
        return _decode_pallas(q, k_cache, v_cache, pos, window=window)
    if mode == "interpret":
        return _decode_pallas(q, k_cache, v_cache, pos, window=window,
                              interpret=True)
    return ref.decode_attention(q, k_cache, v_cache, pos, window=window)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

def _xla_ssd(x, dt, A, B, C, *, bc: int = 128):
    """Chunked SSD in pure jnp — same decomposition as the kernel.

    The inter-chunk state pass is a *Python* loop (nc <= 128 for every
    assigned cell), so the lowered HLO has no while op and the dry-run's
    ``cost_analysis`` is exact."""
    b, nh, S, dp = x.shape
    N = B.shape[-1]
    bc = min(bc, S)
    assert S % bc == 0
    nc = S // bc

    xf = x.astype(jnp.float32).reshape(b, nh, nc, bc, dp)
    dtf = dt.astype(jnp.float32).reshape(b, nh, nc, bc)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32).reshape(b, nc, bc, N)
    Cf = C.astype(jnp.float32).reshape(b, nc, bc, N)

    da = dtf * Af[None, :, None, None]                    # (b, nh, nc, bc)
    cum = jnp.cumsum(da, axis=-1)
    li = jnp.arange(bc)[:, None]
    lj = jnp.arange(bc)[None, :]
    diff = cum[..., :, None] - cum[..., None, :]
    L = jnp.where(li >= lj, jnp.exp(diff), 0.0)           # (b,nh,nc,bc,bc)

    xd = xf * dtf[..., None]
    cb = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)            # (b, nc, bc, bc)
    y_intra = jnp.einsum("bhcij,bhcjp->bhcip", cb[:, None] * L, xd)

    # inter-chunk states, sequential over chunks
    total = jnp.exp(cum[..., -1])                         # (b, nh, nc)
    rem = jnp.exp(cum[..., -1:] - cum)                    # (b, nh, nc, bc)
    upd = jnp.einsum("bhcj,bhcjp,bcjn->bhcpn", rem, xd, Bf)

    h = jnp.zeros((b, nh, dp, N), jnp.float32)
    y_inters = []
    for c in range(nc):
        c_dec = Cf[:, c][:, None] * jnp.exp(cum[:, :, c, :, None])
        y_inters.append(jnp.einsum("bhin,bhpn->bhip", c_dec, h))
        h = h * total[:, :, c, None, None] + upd[:, :, c]
    y_inter = jnp.stack(y_inters, axis=2)                 # (b, nh, nc, bc, dp)
    y = (y_intra + y_inter).reshape(b, nh, S, dp)
    return y.astype(x.dtype)


def ssd_scan(x, dt, A, B, C, *, bc: int = 128):
    """Shapes as in ref.ssd.  Returns y (b, nh, S, dp).

    S is padded up to a multiple of the chunk size with dt = 0 steps
    (decay exp(0·A) = 1, zero input -> state unaffected); the padded
    outputs are sliced off."""
    S = x.shape[2]
    bc = min(bc, S)
    pad = (-S) % bc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    mode = _mode()
    if mode == "pallas":
        y = _ssd_pallas(x, dt, A, B, C, bc=bc)
    elif mode == "interpret":
        y = _ssd_pallas(x, dt, A, B, C, bc=bc, interpret=True)
    else:
        y = _xla_ssd(x, dt, A, B, C, bc=bc)
    return y[:, :, :S] if pad else y


def ssd_step(h, x_t, dt_t, A, B_t, C_t):
    """Single-token SSD recurrence for decode.
    h (b,nh,dp,N); x_t (b,nh,dp); dt_t (b,nh); A (nh,); B_t/C_t (b,N).
    Returns (h_new, y_t (b,nh,dp))."""
    hf = h.astype(jnp.float32)
    decay = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32)[None])
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t.astype(jnp.float32),
                     x_t.astype(jnp.float32), B_t.astype(jnp.float32))
    h_new = hf * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, C_t.astype(jnp.float32))
    return h_new.astype(h.dtype), y.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _xla_rglru(a, b):
    """Associative scan over the time axis — O(log S) depth, the natural
    XLA lowering of a first-order linear recurrence."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def combine(l, r):
        a_l, b_l = l
        a_r, b_r = r
        return a_l * a_r, b_l * a_r + b_r

    aa, bb = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return bb.astype(a.dtype)


def rglru_scan(a, b, *, bc: int = 256):
    """a, b: (B, S, W) -> h at every step (B, S, W)."""
    mode = _mode()
    if mode == "xla":
        return _xla_rglru(a, b)
    S = a.shape[1]
    bc = min(bc, S)
    pad = (-S) % bc
    if pad:                      # trailing pad only: earlier steps unaffected
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    y = _rglru_pallas(a, b, bc=bc, interpret=(mode == "interpret"))
    return y[:, :S] if pad else y


def rglru_step(h, a_t, b_t):
    """h, a_t, b_t: (B, W) -> h_new."""
    return (a_t.astype(jnp.float32) * h.astype(jnp.float32)
            + b_t.astype(jnp.float32)).astype(h.dtype)


# ---------------------------------------------------------------------------
# weight transform
# ---------------------------------------------------------------------------

def weight_transform(w, scale=None, *, out_dtype=jnp.bfloat16):
    """Dequant (int8 + per-col scale) or cast an (n, m) weight extent."""
    mode = _mode()
    if mode == "pallas":
        return _wt_pallas(w, scale, out_dtype=out_dtype)
    if mode == "interpret":
        return _wt_pallas(w, scale, out_dtype=out_dtype, interpret=True)
    return ref.weight_transform(w, scale, out_dtype)
