"""Kernel dispatch registry.

Every op has three implementations:

  * **pallas**   — the TPU kernel (``<name>.py``), the deployment target;
  * **interpret**— the same kernel body executed in interpret mode (CPU
                   correctness validation; enabled in kernel tests via
                   ``REPRO_PALLAS=interpret``);
  * **ref**      — a memory-efficient pure-jnp fallback with identical
                   semantics.  This is what the CPU dry-run lowers (the
                   roofline math — FLOPs, bytes, collectives — is the
                   same), and what tests use as the "efficient oracle".
                   (``xla`` is accepted as a legacy alias.)

Dispatch goes through one :class:`KernelRegistry`:

  * **capability probing** — the first time a kernel is dispatched in
    ``auto`` mode, the registry attempts to *lower* its Pallas callable
    on the active backend with tiny inputs and caches the verdict.  A
    backend that can lower the kernel (TPU) serves ``pallas``; one that
    cannot (CPU/GPU: "Only interpret mode is supported") serves the
    ``ref`` fallback.  The probe runs once per kernel per process —
    never on the hot path.
  * **forcing** — ``REPRO_PALLAS`` ∈ {auto (default), pallas,
    interpret, ref} overrides the probe, and :func:`set_mode` (the
    ``--pallas`` launcher flag) overrides the env var.  Forcing
    ``pallas`` on a backend that cannot lower it fails loudly at call
    time — it never silently degrades.
  * **block sizes** — tile shapes come from
    :func:`repro.configs.shapes.kernel_blocks` (one ``tpu`` profile,
    one ``interpret`` profile), not per-call literals.

Mode is resolved at *trace* time: jitted callers (the serving engine's
prefill/decode steps) bake the resolved kernel in, so set the mode
before building schedulers — :func:`fingerprint` keys caches that must
retrace on a change.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import analysis
from repro.configs.shapes import kernel_blocks, wt_shard_tiles
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.decode_attention import (
    decode_attention_paged as _decode_paged_pallas)
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas
from repro.kernels.rglru_scan import rglru_scan as _rglru_pallas
from repro.kernels.weight_transform import weight_transform as _wt_pallas
from repro.kernels.quant_matmul import quant_matmul as _qm_pallas

NEG_INF = -1e30

MODES = ("auto", "pallas", "interpret", "ref")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered kernel: its Pallas entry point and a probe that
    lowers it with minimal inputs (run once, verdict cached)."""
    name: str
    pallas_fn: Callable
    probe: Callable[[], Any]


class KernelRegistry:
    """Per-process dispatch state: forced mode + cached probe verdicts."""

    def __init__(self):
        self._kernels: Dict[str, KernelSpec] = {}
        self._lock = analysis.make_lock("KernelRegistry._lock")
        self._verdicts: Dict[str, bool] = {}        # guarded-by: _lock
        self._probe_errors: Dict[str, str] = {}     # guarded-by: _lock
        self._forced: Optional[str] = None
        # (kernel, mode) -> trace-time dispatch count: observability
        # that a given path (e.g. the serving engine's jitted step)
        # actually routed through a kernel, and in which mode
        self.dispatch_counts: Dict[Tuple[str, str], int] = {}  # guarded-by: _lock

    def register(self, spec: KernelSpec):
        self._kernels[spec.name] = spec

    # ------------------------------------------------------------- control
    @staticmethod
    def _normalize(mode: str) -> str:
        mode = {"xla": "ref"}.get(mode, mode)     # legacy alias
        if mode not in MODES:
            raise ValueError(
                f"REPRO_PALLAS/--pallas must be one of {MODES}, "
                f"got {mode!r}")
        return mode

    def set_mode(self, mode: Optional[str]):
        """Force a dispatch mode process-wide (``--pallas`` flag).
        ``None``/'auto' restores probe-based resolution; overrides the
        ``REPRO_PALLAS`` env var."""
        self._forced = None if mode is None else self._normalize(mode)

    # ------------------------------------------------------------- probing
    def pallas_supported(self, name: str) -> bool:
        """Can this backend lower the kernel's Pallas callable?  Probed
        once (tiny inputs, ``.lower()`` only — no execution) and
        cached for the process lifetime."""
        with self._lock:
            if name not in self._verdicts:
                try:
                    self._kernels[name].probe()
                    self._verdicts[name] = True
                except Exception as e:      # lowering rejected the kernel
                    self._verdicts[name] = False
                    self._probe_errors[name] = f"{type(e).__name__}: {e}"
            return self._verdicts[name]

    # ------------------------------------------------------------ resolve
    def mode(self, name: str) -> str:
        """The dispatch mode this call will take, resolving ``auto``
        through the cached capability probe."""
        m = self._forced or self._normalize(
            os.environ.get("REPRO_PALLAS", "auto"))
        if m == "auto":
            return "pallas" if self.pallas_supported(name) else "ref"
        return m

    def dispatch(self, name: str) -> str:
        """:meth:`mode`, counted — the op wrappers call this once per
        trace so callers can assert a path routed through a kernel."""
        m = self.mode(name)
        with self._lock:
            key = (name, m)
            self.dispatch_counts[key] = self.dispatch_counts.get(key, 0) + 1
        return m

    def fingerprint(self) -> Tuple[str, str]:
        """Cheap dispatch-cache key: (forced-or-env mode, backend).
        Within one process the resolved per-kernel mode is a
        deterministic function of exactly these two, so this
        discriminates every case the resolved modes would — WITHOUT
        forcing capability probes (probing all kernels eagerly costs
        ~1.7 s on CPU and would land on the first-token path)."""
        m = self._forced or self._normalize(
            os.environ.get("REPRO_PALLAS", "auto"))
        return (m, jax.default_backend())

    def modes(self) -> Dict[str, str]:
        """Resolved mode per kernel (probes on first call in auto)."""
        return {n: self.mode(n) for n in self._kernels}

    def modes_for(self, fingerprint: Tuple[str, str]) -> Dict[str, str]:
        """Resolved mode per kernel under a saved :meth:`fingerprint` —
        exact even after a later ``set_mode``, since auto's probe-based
        resolution is fixed per (backend, process)."""
        mode, _backend = fingerprint
        if mode == "auto":
            return {n: ("pallas" if self.pallas_supported(n) else "ref")
                    for n in self._kernels}
        return {n: mode for n in self._kernels}

    def dispatch_snapshot(self) -> Dict[Tuple[str, str], int]:
        """Consistent copy of :attr:`dispatch_counts` — the only
        sanctioned way to read it while op wrappers may be tracing on
        other threads."""
        with self._lock:
            return dict(self.dispatch_counts)

    def describe(self) -> Dict[str, Dict[str, Any]]:
        """Per-kernel dispatch report (benchmarks / `stats()` surface)."""
        out = {}
        for n in sorted(self._kernels):
            m = self.mode(n)
            out[n] = {"mode": m,
                      "pallas_supported": self.pallas_supported(n)}
            with self._lock:
                err = self._probe_errors.get(n)
            if err is not None:
                out[n]["probe_error"] = err
        return out


registry = KernelRegistry()


def set_mode(mode: Optional[str]):
    """Module-level convenience for launchers: force the dispatch mode
    (auto / pallas / interpret / ref)."""
    registry.set_mode(mode)


def _blocks():
    """Active block-size profile: the interpret profile when interpret
    mode is forced, the TPU profile otherwise."""
    forced = registry._forced or os.environ.get("REPRO_PALLAS", "auto")
    return kernel_blocks(
        "interpret" if forced == "interpret" else "tpu")


def _register(name: str, pallas_fn: Callable, probe: Callable[[], Any]):
    registry.register(KernelSpec(name, pallas_fn, probe))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _xla_flash(q, k, v, *, causal: bool, window: int, bk: int = 1024):
    """Blocked online-softmax attention in pure jnp — O(S·bk) memory,
    identical math to the Pallas kernel.

    The KV-block loop is a *Python* loop (nk <= ~32 for every assigned
    cell): the lowered HLO contains no while op, so the dry-run's
    ``cost_analysis`` is exact.  Blocks that are fully masked out
    (above the causal diagonal / outside the sliding window) are
    skipped at trace time — matching the Pallas kernel's ``pl.when``
    pruning, so HLO FLOPs reflect the real kernel's work."""
    B, H, S, dh = q.shape
    K, T = k.shape[1], k.shape[2]
    rep = H // K
    bk = min(bk, T)
    if T % bk:
        pad = (-T) % bk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Tp = T + pad
    else:
        Tp = T
    nk = Tp // bk
    q_offset = T - S

    # dots consume q/k/v in their stored dtype with f32 accumulation
    # (MXU semantics) — no materialized f32 copies of the slabs
    scale = 1.0 / float(dh) ** 0.5
    qr = q.reshape(B, K, rep, S, dh)
    qpos = q_offset + jnp.arange(S)

    m = jnp.full((B, K, rep, S), NEG_INF, jnp.float32)
    l = jnp.zeros((B, K, rep, S), jnp.float32)
    acc = jnp.zeros((B, K, rep, S, dh), jnp.float32)

    for ki in range(nk):
        k_lo = ki * bk
        # trace-time block pruning (mirrors pl.when in the kernel)
        if causal and k_lo > q_offset + S - 1:
            continue
        if causal and window > 0 and k_lo + bk - 1 <= q_offset - window:
            continue
        ks = k[:, :, k_lo:k_lo + bk]
        vs = v[:, :, k_lo:k_lo + bk]
        s = jnp.einsum("bkrsd,bktd->bkrst", qr, ks,
                       preferred_element_type=jnp.float32) * scale
        kpos = k_lo + jnp.arange(bk)
        mask = (kpos[None, :] < T)
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
            if window > 0:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
        elif window > 0:
            mask = mask & (jnp.abs(kpos[None, :] - qpos[:, None]) < window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkrst,bktd->bkrsd", p.astype(v.dtype), vs,
            preferred_element_type=jnp.float32)
        m = m_new

    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(B, H, S, dh)
    return out.astype(q.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, S, H, dh); k, v: (B, T, K, dh) — model layout (seq-major).
    Returns (B, S, H, dh)."""
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    return flash_attention_kvmajor(q, kt, vt, causal=causal, window=window)


def flash_attention_kvmajor(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool = True, window: int = 0
                            ) -> jax.Array:
    """q: (B, S, H, dh); k, v: (B, K, T, dh) — cache layout (kv-major;
    chunked prefill attends directly against cache slices, no transpose).
    Returns (B, S, H, dh)."""
    qt = jnp.swapaxes(q, 1, 2)
    mode = registry.dispatch("flash_attention")
    kb = _blocks()
    if mode == "pallas":
        o = _flash_pallas(qt, k, v, causal=causal, window=window,
                          bq=kb.flash_bq, bk=kb.flash_bk)
    elif mode == "interpret":
        # interpret path pads nothing: shrink tiles to divide S/T
        bq = _divisor_tile(kb.flash_bq, qt.shape[2])
        bk = _divisor_tile(kb.flash_bk, k.shape[2])
        o = _flash_pallas(qt, k, v, causal=causal, window=window,
                          bq=bq, bk=bk, interpret=True)
    else:
        o = _xla_flash(qt, k, v, causal=causal, window=window,
                       bk=kb.flash_ref_bk)
    return jnp.swapaxes(o, 1, 2)


def _divisor_tile(b: int, dim: int) -> int:
    """Largest tile <= b that divides dim (kernels assert divisibility;
    smoke models bring odd sequence lengths)."""
    b = min(b, dim)
    while dim % b:
        b -= 1
    return b


def _probe_flash():
    _flash_pallas.lower(
        jnp.zeros((1, 1, 128, 128), jnp.float32),
        jnp.zeros((1, 1, 128, 128), jnp.float32),
        jnp.zeros((1, 1, 128, 128), jnp.float32),
        causal=True, window=0, bq=128, bk=128)


_register("flash_attention", _flash_pallas, _probe_flash)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int = 0) -> jax.Array:
    """q: (B, H, dh); caches: (B, K, S_max, dh) kv-head-major;
    pos: (B,). -> (B, H, dh)."""
    mode = registry.dispatch("decode_attention")
    kb = _blocks()
    if mode == "pallas":
        return _decode_pallas(q, k_cache, v_cache, pos, window=window,
                              bs=kb.decode_bs)
    if mode == "interpret":
        bs = _divisor_tile(kb.decode_bs, k_cache.shape[2])
        return _decode_pallas(q, k_cache, v_cache, pos, window=window,
                              bs=bs, interpret=True)
    return ref.decode_attention(q, k_cache, v_cache, pos, window=window)


def _probe_decode():
    _decode_pallas.lower(
        jnp.zeros((1, 2, 128), jnp.float32),
        jnp.zeros((1, 1, 128, 128), jnp.float32),
        jnp.zeros((1, 1, 128, 128), jnp.float32),
        jnp.zeros((1,), jnp.int32), window=0, bs=128)


_register("decode_attention", _decode_pallas, _probe_decode)


def decode_attention_paged(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, tables: jax.Array,
                           pos: jax.Array, *, window: int = 0) -> jax.Array:
    """Block-paged decode attention: q (B, H, dh); page pools
    (P, K, pt, dh) shared across the batch; tables (B, NP) int32 page
    ids per row; pos (B,). -> (B, H, dh).

    The kernel tile must divide the page size (every cache block lives
    inside one physical page), so both pallas and interpret modes take
    the divisor tile of the profile's ``decode_bs``.
    """
    mode = registry.dispatch("decode_attention_paged")
    kb = _blocks()
    pt = k_pages.shape[2]
    if mode == "pallas":
        return _decode_paged_pallas(q, k_pages, v_pages, tables, pos,
                                    window=window,
                                    bs=_divisor_tile(kb.decode_bs, pt))
    if mode == "interpret":
        return _decode_paged_pallas(q, k_pages, v_pages, tables, pos,
                                    window=window,
                                    bs=_divisor_tile(kb.decode_bs, pt),
                                    interpret=True)
    return ref.decode_attention_paged(q, k_pages, v_pages, tables, pos,
                                      window=window)


def _probe_decode_paged():
    _decode_paged_pallas.lower(
        jnp.zeros((1, 2, 128), jnp.float32),
        jnp.zeros((2, 1, 128, 128), jnp.float32),
        jnp.zeros((2, 1, 128, 128), jnp.float32),
        jnp.zeros((1, 2), jnp.int32),
        jnp.zeros((1,), jnp.int32), window=0, bs=128)


_register("decode_attention_paged", _decode_paged_pallas,
          _probe_decode_paged)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

def _xla_ssd(x, dt, A, B, C, *, bc: int = 128):
    """Chunked SSD in pure jnp — same decomposition as the kernel.

    The inter-chunk state pass is a *Python* loop (nc <= 128 for every
    assigned cell), so the lowered HLO has no while op and the dry-run's
    ``cost_analysis`` is exact."""
    b, nh, S, dp = x.shape
    N = B.shape[-1]
    bc = min(bc, S)
    assert S % bc == 0
    nc = S // bc

    xf = x.astype(jnp.float32).reshape(b, nh, nc, bc, dp)
    dtf = dt.astype(jnp.float32).reshape(b, nh, nc, bc)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32).reshape(b, nc, bc, N)
    Cf = C.astype(jnp.float32).reshape(b, nc, bc, N)

    da = dtf * Af[None, :, None, None]                    # (b, nh, nc, bc)
    cum = jnp.cumsum(da, axis=-1)
    li = jnp.arange(bc)[:, None]
    lj = jnp.arange(bc)[None, :]
    diff = cum[..., :, None] - cum[..., None, :]
    L = jnp.where(li >= lj, jnp.exp(diff), 0.0)           # (b,nh,nc,bc,bc)

    xd = xf * dtf[..., None]
    cb = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)            # (b, nc, bc, bc)
    y_intra = jnp.einsum("bhcij,bhcjp->bhcip", cb[:, None] * L, xd)

    # inter-chunk states, sequential over chunks
    total = jnp.exp(cum[..., -1])                         # (b, nh, nc)
    rem = jnp.exp(cum[..., -1:] - cum)                    # (b, nh, nc, bc)
    upd = jnp.einsum("bhcj,bhcjp,bcjn->bhcpn", rem, xd, Bf)

    h = jnp.zeros((b, nh, dp, N), jnp.float32)
    y_inters = []
    for c in range(nc):
        c_dec = Cf[:, c][:, None] * jnp.exp(cum[:, :, c, :, None])
        y_inters.append(jnp.einsum("bhin,bhpn->bhip", c_dec, h))
        h = h * total[:, :, c, None, None] + upd[:, :, c]
    y_inter = jnp.stack(y_inters, axis=2)                 # (b, nh, nc, bc, dp)
    y = (y_intra + y_inter).reshape(b, nh, S, dp)
    return y.astype(x.dtype)


def ssd_scan(x, dt, A, B, C, *, bc: Optional[int] = None):
    """Shapes as in ref.ssd.  Returns y (b, nh, S, dp).

    S is padded up to a multiple of the chunk size with dt = 0 steps
    (decay exp(0·A) = 1, zero input -> state unaffected); the padded
    outputs are sliced off."""
    S = x.shape[2]
    bc = min(bc if bc is not None else _blocks().ssd_bc, S)
    pad = (-S) % bc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    mode = registry.dispatch("ssd_scan")
    if mode == "pallas":
        y = _ssd_pallas(x, dt, A, B, C, bc=bc)
    elif mode == "interpret":
        y = _ssd_pallas(x, dt, A, B, C, bc=bc, interpret=True)
    else:
        y = _xla_ssd(x, dt, A, B, C, bc=bc)
    return y[:, :, :S] if pad else y


def _probe_ssd():
    _ssd_pallas.lower(
        jnp.zeros((1, 1, 128, 128), jnp.float32),
        jnp.zeros((1, 1, 128), jnp.float32),
        jnp.zeros((1,), jnp.float32),
        jnp.zeros((1, 128, 128), jnp.float32),
        jnp.zeros((1, 128, 128), jnp.float32), bc=128)


_register("ssd_scan", _ssd_pallas, _probe_ssd)


def ssd_step(h, x_t, dt_t, A, B_t, C_t):
    """Single-token SSD recurrence for decode.
    h (b,nh,dp,N); x_t (b,nh,dp); dt_t (b,nh); A (nh,); B_t/C_t (b,N).
    Returns (h_new, y_t (b,nh,dp))."""
    hf = h.astype(jnp.float32)
    decay = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32)[None])
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t.astype(jnp.float32),
                     x_t.astype(jnp.float32), B_t.astype(jnp.float32))
    h_new = hf * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, C_t.astype(jnp.float32))
    return h_new.astype(h.dtype), y.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _xla_rglru(a, b):
    """Associative scan over the time axis — O(log S) depth, the natural
    XLA lowering of a first-order linear recurrence."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def combine(l, r):
        a_l, b_l = l
        a_r, b_r = r
        return a_l * a_r, b_l * a_r + b_r

    aa, bb = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return bb.astype(a.dtype)


def rglru_scan(a, b, *, bc: Optional[int] = None):
    """a, b: (B, S, W) -> h at every step (B, S, W)."""
    mode = registry.dispatch("rglru_scan")
    if mode == "ref":
        return _xla_rglru(a, b)
    S = a.shape[1]
    bc = min(bc if bc is not None else _blocks().rglru_bc, S)
    pad = (-S) % bc
    if pad:                      # trailing pad only: earlier steps unaffected
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    y = _rglru_pallas(a, b, bc=bc, interpret=(mode == "interpret"))
    return y[:, :S] if pad else y


def _probe_rglru():
    _rglru_pallas.lower(
        jnp.zeros((1, 128, 128), jnp.float32),
        jnp.zeros((1, 128, 128), jnp.float32), bc=128)


_register("rglru_scan", _rglru_pallas, _probe_rglru)


def rglru_step(h, a_t, b_t):
    """h, a_t, b_t: (B, W) -> h_new."""
    return (a_t.astype(jnp.float32) * h.astype(jnp.float32)
            + b_t.astype(jnp.float32)).astype(h.dtype)


# ---------------------------------------------------------------------------
# weight transform
# ---------------------------------------------------------------------------

def weight_transform(w, scale=None, *, out_dtype=jnp.bfloat16,
                     bn: Optional[int] = None, bm: Optional[int] = None):
    """Dequant (int8 + per-col scale) or cast an (n, m) weight extent.

    Per-shard callers (the decoupler's placement lanes) pass ``bn``/
    ``bm`` from :func:`repro.configs.shapes.wt_shard_tiles` so small
    shard slices keep a multi-cell grid; defaults come from the active
    block profile."""
    kb = _blocks()
    bn = bn if bn is not None else kb.wt_bn
    bm = bm if bm is not None else kb.wt_bm
    mode = registry.dispatch("weight_transform")
    if mode == "pallas":
        return _wt_pallas(w, scale, out_dtype=out_dtype, bn=bn, bm=bm)
    if mode == "interpret":
        return _wt_pallas(w, scale, out_dtype=out_dtype, bn=bn, bm=bm,
                          interpret=True)
    return ref.weight_transform(w, scale, out_dtype)


def _probe_wt():
    # probe at the active profile's tiles — what dispatch will actually
    # lower — not hard-coded literals that can drift from KernelBlocks
    kb = _blocks()
    _wt_pallas.lower(
        jnp.zeros((kb.wt_bn, kb.wt_bm), jnp.int8),
        jnp.zeros((kb.wt_bm,), jnp.float32),
        out_dtype=jnp.bfloat16, bn=kb.wt_bn, bm=kb.wt_bm)


_register("weight_transform", _wt_pallas, _probe_wt)


def wt_shard_blocks(nbytes: int) -> Tuple[int, int]:
    """(bn, bm) for a per-shard weight_transform of ``nbytes`` — thin
    re-export so decoupler-side callers need only this module."""
    return wt_shard_tiles(nbytes)


# ---------------------------------------------------------------------------
# quant matmul (w8a16: int8-resident weights, dequant fused into the dot)
# ---------------------------------------------------------------------------

def quant_matmul(x, w, scale, *, out_dtype=None,
                 bm: Optional[int] = None, bk: Optional[int] = None,
                 bn: Optional[int] = None):
    """Fused-dequant matmul over the trailing axis of ``x``.

    x: (..., k) activations; w: (k, n) int8; scale: (n,) f32
    per-column.  Leading axes of ``x`` are collapsed into the row dim
    and restored on the output (..., n).  The ``ref`` fallback is the
    dequant-then-matmul oracle — numerically identical to running
    ``weight_transform`` at load and a plain einsum at compute, so the
    quant-resident serving path degrades losslessly on backends without
    Pallas."""
    kb = _blocks()
    bm = bm if bm is not None else kb.qm_bm
    bk = bk if bk is not None else kb.qm_bk
    bn = bn if bn is not None else kb.qm_bn
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    mode = registry.dispatch("quant_matmul")
    if mode == "pallas":
        out = _qm_pallas(x2, w, scale, out_dtype=out_dtype,
                         bm=bm, bk=bk, bn=bn)
    elif mode == "interpret":
        # shrink tiles to divide each dim: no padded grid cells in the
        # (slow) interpret loop
        out = _qm_pallas(x2, w, scale, out_dtype=out_dtype,
                         bm=_divisor_tile(bm, x2.shape[0]),
                         bk=_divisor_tile(bk, w.shape[0]),
                         bn=_divisor_tile(bn, w.shape[1]),
                         interpret=True)
    else:
        out = ref.quant_matmul(x2, w, scale, out_dtype)
    return out.reshape(lead + (w.shape[1],))


def _probe_qm():
    kb = _blocks()
    _qm_pallas.lower(
        jnp.zeros((kb.qm_bm, kb.qm_bk), jnp.float32),
        jnp.zeros((kb.qm_bk, kb.qm_bn), jnp.int8),
        jnp.zeros((kb.qm_bn,), jnp.float32),
        out_dtype=jnp.float32, bm=kb.qm_bm, bk=kb.qm_bk, bn=kb.qm_bn)


_register("quant_matmul", _qm_pallas, _probe_qm)
