"""Flash attention (prefill / training) as a Pallas TPU kernel.

Online-softmax tiling: grid = (B, H, nQ, nK) with the KV dimension
innermost (sequential on TPU, so VMEM scratch carries running statistics
across KV blocks).  Supports causal masking, sliding windows and GQA
(every q head reads its kv head via the BlockSpec index map — no
materialized ``jnp.repeat``).

Block sizes are MXU-aligned (multiples of 128 on the contraction/lane
dims).  Fully-masked KV blocks are skipped with ``pl.when`` — on real
hardware this prunes ~half the work for causal prefill and all but
ceil(window/bk)+1 blocks per q row for sliding windows.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, bq: int, bk: int, nk: int,
            q_offset: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global coordinates of this tile; queries sit at the *end* of the key
    # sequence when q_offset > 0 (chunked prefill).
    q_lo = qi * bq + q_offset
    k_lo = ki * bk

    run = True
    if causal:
        run = k_lo <= q_lo + bq - 1                     # not above diagonal
    if window > 0:
        run = jnp.logical_and(run, k_lo + bk - 1 > q_lo - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)             # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                   # (bq, bk)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
            if window > 0:
                mask = jnp.logical_and(mask, kpos > qpos - window)
        elif window > 0:
            mask = jnp.logical_and(mask, jnp.abs(kpos - qpos) < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]                           # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)      # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        p = jnp.exp(s - m_new)                          # (bq, bk)
        l_new = alpha * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                 # fully-masked rows
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 256, bk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, dh); k, v: (B, K, T, dh).  Returns (B, H, S, dh).

    When T > S (chunked prefill against an existing prefix) queries are
    the last S positions of the key sequence.
    """
    B, H, S, dh = q.shape
    K, T = k.shape[1], k.shape[2]
    assert H % K == 0 and k.shape == v.shape
    rep = H // K
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    q_offset = T - S

    grid = (B, H, nq, nk)
    kern = functools.partial(
        _kernel, causal=causal, window=window, bq=bq, bk=bk, nk=nk,
        q_offset=q_offset, scale=1.0 / math.sqrt(dh))

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, i, j, _rep=rep: (b, h // _rep, j, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, i, j, _rep=rep: (b, h // _rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),   # running max
            pltpu.VMEM((bq, LANES), jnp.float32),   # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),      # output accumulator
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
