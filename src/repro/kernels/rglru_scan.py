"""RG-LRU linear recurrence (Griffin / RecurrentGemma) as a Pallas kernel.

``h_t = a_t * h_{t-1} + b_t`` elementwise over the width dim.  The
sequence is tiled into chunks (grid innermost dim, sequential); the
carried state lives in VMEM scratch.  Within a chunk the recurrence is a
``fori_loop`` over time steps, fully vectorized across the width lanes —
a pure VPU workload (no MXU), bound by the HBM stream of a and b.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, y_ref, h_scr, *, bc: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        a_t = a_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        h = a_t * h + b_t
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bc, step, h_scr[0])
    h_scr[0] = h


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, *, bc: int = 256,
               interpret: bool = False) -> jax.Array:
    """a, b: (B, S, W).  Returns h at every step, (B, S, W)."""
    B, S, W = a.shape
    bc = min(bc, S)
    assert S % bc == 0
    nc = S // bc

    kern = functools.partial(_kernel, bc=bc)
    return pl.pallas_call(
        kern,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, bc, W), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, bc, W), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, W), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, W), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
