"""Pallas TPU kernels for the compute hot-spots, with pure-jnp oracles.

  flash_attention   prefill/training attention (causal/SWA/GQA)
  decode_attention  split-K single-token decode over (ring) KV caches
  ssd_scan          Mamba-2 chunked state-space duality
  rglru_scan        Griffin RG-LRU linear recurrence
  weight_transform  fused dequant/cast — the paper's weight-application
                    compute phase as a TPU kernel

Use :mod:`repro.kernels.ops` (dispatching) in model code.
"""
