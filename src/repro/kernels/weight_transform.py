"""Weight transform: the compute phase of the paper's decoupled weight
application, fused into one Pallas kernel.

Cicada splits weight loading into I/O-bound *file retrieval* and
compute-bound *weight application*.  On TPU the application phase is a
dtype/layout transform ahead of the host->HBM DMA: dequantize int8
extents (per-output-channel scales) or cast f32 extents to the serving
dtype.  Fusing it keeps application off the critical path — one pass over
the weight bytes, tiled (bn x bm) to stay inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dequant_kernel(w_ref, s_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)          # (1, bm)
    o_ref[...] = (w * s).astype(o_ref.dtype)


def _cast_kernel(w_ref, o_ref):
    o_ref[...] = w_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("out_dtype", "bn", "bm", "interpret"))
def weight_transform(w: jax.Array, scale=None, *, out_dtype=jnp.bfloat16,
                     bn: int = 256, bm: int = 512,
                     interpret: bool = False) -> jax.Array:
    """w: (n, m) int8 (with scale (m,)) or float (scale None). -> (n, m)."""
    n, m = w.shape
    bn = min(bn, n)
    bm = min(bm, m)
    # pad to tile multiples (weight extents are arbitrary shapes)
    pn = (-n) % bn
    pm = (-m) % bm
    wp = jnp.pad(w, ((0, pn), (0, pm))) if (pn or pm) else w
    N, M = wp.shape
    grid = (N // bn, M // bm)

    if scale is not None:
        sp = jnp.pad(scale, (0, pm)) if pm else scale
        out = pl.pallas_call(
            _dequant_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
                pl.BlockSpec((1, bm), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((N, M), out_dtype),
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel", "parallel")),
            interpret=interpret,
        )(wp, sp[None, :])
    else:
        out = pl.pallas_call(
            _cast_kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((bn, bm), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((N, M), out_dtype),
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel", "parallel")),
            interpret=interpret,
        )(wp)
    if pn or pm:
        out = out[:n, :m]
    return out
