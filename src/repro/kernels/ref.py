"""Pure-jnp oracles for every Pallas kernel.

These are the *semantic definitions*: small, obviously-correct, O(S^2)
memory where that is the honest definition.  Kernel tests sweep shapes and
dtypes and assert the Pallas (interpret-mode) output matches these within
dtype tolerance.  ``ops.py`` never calls these on the hot path — it has its
own memory-efficient XLA fallbacks — except where the oracle *is* already
the efficient form.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def mha_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """Naive full-materialization attention.

    q: (B, H, S, dh); k, v: (B, K, T, dh) with H a multiple of K (GQA).
    window: 0 -> full; >0 -> sliding window of that many positions
    (a query at i attends to keys in (i-window, i]).
    Returns (B, H, S, dh), same dtype as q.
    """
    B, H, S, dh = q.shape
    K, T = k.shape[1], k.shape[2]
    rep = H // K
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=1)
    scores = jnp.einsum("bhsd,bhtd->bhst", qf, kf) / jnp.sqrt(dh)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        # queries are the *last* S positions of the T-long key sequence
        offs = T - S
        mask &= ki <= (qi + offs)
        if window > 0:
            mask &= ki > (qi + offs - window)
    elif window > 0:
        mask &= jnp.abs(ki - qi) < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p, vf)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int = 0) -> jax.Array:
    """One-token decode against a (possibly ring-buffered) KV cache.

    q: (B, H, dh) — the single new query (already rotated).
    k_cache/v_cache: (B, K, S_max, dh) — kv-head-major layout (dh is the
         contraction minor dim for both attention dots, so no transpose
         is ever materialized; see §Perf iteration 2).  Keys are rotated
         at write time.
    pos: (B,) int32 — index of the *current* token (the one q belongs to);
         its K/V entry is already in the cache.
    window: 0 -> full cache, valid slots are [0, pos]; >0 -> cache is a ring
         buffer of S_max == window slots, slot j holds some absolute position
         p with p % window == j; valid iff p in (pos-window, pos].
    Returns (B, H, dh).
    """
    B, H, dh = q.shape
    K, S_max = k_cache.shape[1], k_cache.shape[2]
    rep = H // K
    # dots consume the cache in its stored dtype and accumulate f32
    # (MXU semantics) — no materialized f32 copy of the cache
    qr = q.reshape(B, K, rep, dh)
    scores = jnp.einsum("bkrd,bksd->bkrs", qr, k_cache,
                        preferred_element_type=jnp.float32) / jnp.sqrt(dh)
    idx = jnp.arange(S_max)[None, :]                      # (1, S)
    if window > 0:
        # ring buffer: slot j valid iff the position it holds is within the
        # window.  Slot j holds position p where p = largest value <= pos
        # with p % window == j.
        cur = pos[:, None]
        p_at_slot = cur - ((cur - idx) % window)
        valid = (p_at_slot >= 0) & (p_at_slot > cur - window)
    else:
        valid = idx <= pos[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrs,bksd->bkrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, dh).astype(q.dtype)


def decode_attention_paged(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, tables: jax.Array,
                           pos: jax.Array, *, window: int = 0) -> jax.Array:
    """Block-paged decode attention: gather-then-attend oracle.

    q: (B, H, dh); k_pages/v_pages: (P, K, pt, dh) — physical page pools
    shared across the batch; tables: (B, NP) int32 page ids per row
    (logical extent NP * pt); pos: (B,).

    The definition: per batch row, gather its NP pages into the
    contiguous logical cache and run :func:`decode_attention` — so the
    math (masking of garbage rows beyond ``pos``, ring-window validity
    over logical positions, GQA) is *identical* to the slotted oracle.
    """
    B = q.shape[0]
    K, pt, dh = k_pages.shape[1], k_pages.shape[2], k_pages.shape[3]
    NP = tables.shape[1]
    kc = jnp.swapaxes(k_pages[tables], 1, 2).reshape(B, K, NP * pt, dh)
    vc = jnp.swapaxes(v_pages[tables], 1, 2).reshape(B, K, NP * pt, dh)
    return decode_attention(q, kc, vc, pos, window=window)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality)
# ---------------------------------------------------------------------------

def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
        C: jax.Array, *, h0: Optional[jax.Array] = None,
        return_state: bool = False):
    """Naive sequential SSD recurrence (the definition).

    x:  (b, nh, S, dp)   inputs per head
    dt: (b, nh, S)       positive step sizes (softplus already applied)
    A:  (nh,)            negative decay rates (A = -exp(A_log))
    B:  (b, S, N)        input projections (ngroups=1, shared over heads)
    C:  (b, S, N)        output projections
    h0: (b, nh, dp, N)   optional initial state
    Recurrence per head:  h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t B_t^T
                          y_t = h_t C_t + D x_t   (D skip applied by caller)
    Returns y (b, nh, S, dp) [, h_S (b, nh, dp, N)].
    """
    b, nh, S, dp = x.shape
    N = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, nh, dp, N), jnp.float32)

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def step(h, t):
        decay = jnp.exp(dtf[:, :, t] * Af[None, :])            # (b, nh)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtf[:, :, t], xf[:, :, t], Bf[:, t])
        h = h * decay[:, :, None, None] + upd
        y_t = jnp.einsum("bhpn,bn->bhp", h, Cf[:, t])
        return h, y_t

    hS, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 2).astype(x.dtype)                  # (b, nh, S, dp)
    if return_state:
        return y, hS
    return y


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------

def rglru(a: jax.Array, b: jax.Array, *, h0: Optional[jax.Array] = None,
          return_state: bool = False):
    """h_t = a_t * h_{t-1} + b_t, elementwise over the width dim.

    a, b: (B, S, W); h0: (B, W).  Returns h at every step (B, S, W).
    (Gate computation — r_t, i_t, the sqrt(1-a^2) input scale — happens in
    the model; the kernel is the pure first-order linear recurrence.)
    """
    B, S, W = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def step(h, t):
        h = af[:, t] * h + bf[:, t]
        return h, h

    hS, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1).astype(a.dtype)
    if return_state:
        return y, hS
    return y


# ---------------------------------------------------------------------------
# weight transform (the paper's weight-application compute phase)
# ---------------------------------------------------------------------------

def weight_transform(w: jax.Array, scale: Optional[jax.Array], out_dtype
                     ) -> jax.Array:
    """Dequantize / cast a stored weight to its compute representation.

    w: (n, m) int8 (quantized, with per-column f32 `scale` (m,)) or any
    float dtype (scale is None -> pure cast).
    """
    if scale is not None:
        return (w.astype(jnp.float32) * scale[None, :].astype(jnp.float32)
                ).astype(out_dtype)
    return w.astype(out_dtype)


# ---------------------------------------------------------------------------
# quant matmul (w8a16: int8-resident weights, dequant fused at compute)
# ---------------------------------------------------------------------------

def quant_matmul(x: jax.Array, w: jax.Array, scale: jax.Array,
                 out_dtype=None) -> jax.Array:
    """Dequant-then-matmul — the semantic definition the fused kernel
    must match: materialize the f32 weight exactly as the dequant-at-
    load path does (``weight_transform``), then contract in f32.

    x: (m, k) activations (any float dtype); w: (k, n) int8;
    scale: (n,) f32 per-column.  Returns (m, n) in ``out_dtype``
    (default: x.dtype).
    """
    wf = w.astype(jnp.float32) * scale[None, :].astype(jnp.float32)
    out = jnp.dot(x.astype(jnp.float32), wf,
                  preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)
