"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates at reduced config and runs one forward + one train step on
CPU with correct shapes and no NaNs.  Plus param-count sanity against
the published sizes for the full configs (abstract only, no allocation).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs import ASSIGNED
from repro.models import transformer
from repro.models.api import Family, get_config
from repro.training.optim import AdamW
from repro.training.train import make_train_step

ARCHS = list(ASSIGNED)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = transformer.build(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, B=2, S=24, labels=True)
    logits, aux = model.forward(params, batch)
    S_out = (24 if cfg.family != Family.VLM
             else batch["tokens"].shape[1] + batch["img"].shape[1])
    assert logits.shape == (2, S_out, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()

    if cfg.family == Family.VLM:
        batch["labels"] = batch["labels"][:, :S_out] if S_out <= 24 else \
            jnp.pad(batch["labels"], ((0, 0), (0, S_out - 24)))
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, opt))
    opt_state = opt.init(params)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved
    moved = any(
        np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max()
        > 0 for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_microbatched_step_matches_full(arch):
    """Gradient accumulation (M=2) reproduces the full-batch *gradient*.

    (Gradients, not post-Adam params: Adam's first step is ~sign(g), so
    it amplifies f32 reduction-order noise near g=0 unboundedly.)  MoE
    archs get a looser tolerance: the load-balance aux loss is nonlinear
    in batch composition, so micro-averaged aux differs slightly.
    """
    cfg = get_config(arch, smoke=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, compute_dtype=jnp.float32)
    model = transformer.build(cfg)
    params = model.init(jax.random.key(1))
    batch = make_batch(cfg, B=4, S=16, labels=True)
    if cfg.family == Family.VLM:
        S_out = batch["tokens"].shape[1] + batch["img"].shape[1]
        batch["labels"] = batch["labels"][:, :S_out]

    def grad_of(b):
        return jax.grad(lambda p: model.loss(p, b, remat=False)[0])(params)

    g_full = grad_of(batch)
    halves = [jax.tree.map(lambda x: x[:2], batch),
              jax.tree.map(lambda x: x[2:], batch)]
    g_micro = jax.tree.map(lambda a, b: (a + b) / 2,
                           grad_of(halves[0]), grad_of(halves[1]))
    loose = cfg.family == Family.MOE
    scale = max(float(jnp.max(jnp.abs(l)))
                for l in jax.tree.leaves(g_full))
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_micro)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=(2e-2 if loose else 1e-5) * max(scale, 1e-3), rtol=0.05)


@pytest.mark.parametrize("arch", ["resnet50", "vgg16", "vit_b_16"])
def test_vision_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = transformer.build(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, B=2)
    logits, _ = model.forward(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()


# published parameter counts (approximate, 5% tolerance on arch math)
PUBLISHED = {
    "yi-9b": 8.8e9,
    "mixtral-8x7b": 46.7e9,
    "arctic-480b": 480e9,
    "smollm-360m": 0.36e9,
    "mamba2-780m": 0.78e9,
    "recurrentgemma-2b": 2.7e9,   # incl. 256k-vocab embeddings
}


@pytest.mark.parametrize("arch,expected", sorted(PUBLISHED.items()))
def test_param_count_matches_published(arch, expected):
    cfg = get_config(arch)
    n = cfg.param_count()
    assert abs(n - expected) / expected < 0.15, (arch, n, expected)


@pytest.mark.parametrize("arch", ARCHS)
def test_abstract_matches_init(arch):
    """eval_shape structure (MiniLoader's view) == real init structure."""
    cfg = get_config(arch, smoke=True)
    model = transformer.build(cfg)
    ab = model.abstract()
    real = model.init(jax.random.key(0))
    ab_leaves = jax.tree_util.tree_flatten_with_path(ab)[0]
    real_leaves = jax.tree_util.tree_flatten_with_path(real)[0]
    assert len(ab_leaves) == len(real_leaves)
    for (pa, la), (pr, lr) in zip(ab_leaves, real_leaves):
        assert pa == pr
        assert tuple(la.shape) == tuple(lr.shape)
        assert la.dtype == lr.dtype


@pytest.mark.parametrize("arch", ARCHS)
def test_streaming_units_cover_model(arch):
    """unit view: assemble(init_unit for all units) == init structure."""
    cfg = get_config(arch, smoke=True)
    model = transformer.build(cfg)
    names = model.unit_names()
    assert names[0] == "embed" and names[-1] == "final"
    assert len(names) == cfg.n_layers + 2
    keys = jax.random.split(jax.random.key(0), len(names))
    units = {n: model.init_unit(n, k) for n, k in zip(names, keys)}
    asm = model.assemble(units)
    ab = model.abstract()
    assert jax.tree_util.tree_structure(asm) == \
        jax.tree_util.tree_structure(ab)
    for a, b in zip(jax.tree.leaves(asm), jax.tree.leaves(ab)):
        assert tuple(a.shape) == tuple(b.shape)
