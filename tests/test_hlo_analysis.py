"""HLO collective parser + cost composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (collective_bytes, combine_linear,
                                       scale_cost, shape_bytes)


def test_shape_bytes():
    assert shape_bytes("f32[128,1024]{1,0}") == 128 * 1024 * 4
    assert shape_bytes("bf16[2,4,8]") == 64 * 2
    assert shape_bytes("(f32[16], bf16[16])") == 64 + 32
    assert shape_bytes("pred[]") == 1           # scalar: empty dims -> 1 elt
    assert shape_bytes("token[]") == 0          # unknown dtypes ignored


def test_collective_parse_basic():
    hlo = """
  %ag = f32[256,1024]{1,0} all-gather(f32[16,1024]{1,0} %x), dimensions={0}
  %ar = bf16[128,128]{1,0} all-reduce(bf16[128,128]{1,0} %y), to_apply=%add
  %rs.1 = f32[8,64]{1,0} reduce-scatter(f32[64,64] %z), dimensions={0}
  %cp = f32[32]{0} collective-permute(f32[32]{0} %w), source_target_pairs={{0,1}}
  %done = f32[256,1024]{1,0} all-gather-done(f32[256,1024] %ag)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 256 * 1024 * 4
    assert got["all-reduce"] == 128 * 128 * 2
    assert got["reduce-scatter"] == 8 * 64 * 4
    assert got["collective-permute"] == 32 * 4
    assert got["_counts"]["all-gather"] == 1     # -done not double counted
    assert got["total"] == sum(got[k] for k in
                               ("all-gather", "all-reduce",
                                "reduce-scatter", "collective-permute"))


def test_collective_parse_async_start():
    hlo = "%a = (f32[16]{0}, f32[64]{0}) all-gather-start(f32[16] %x)\n"
    got = collective_bytes(hlo)
    assert got["all-gather"] == 16 * 4 + 64 * 4


def test_collective_parse_real_lowering():
    """Parse actual XLA output of a psum under 1-device SPMD (no
    collectives expected) and of a manual HLO check above."""
    c = jax.jit(lambda x: x * 2).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    got = collective_bytes(c.as_text())
    assert got["total"] == 0


def test_combine_linear():
    c1 = {"flops": 10.0, "collectives": {"all-reduce": 4, "total": 4}}
    c2 = {"flops": 16.0, "collectives": {"all-reduce": 6, "total": 6}}
    out = combine_linear(c1, c2, n_units=5)
    assert out["flops"] == 10 + 4 * 6.0
    assert out["collectives"]["all-reduce"] == 4 + 4 * 2
    # degenerate: n_units == 1 -> exactly c1
    out1 = combine_linear(c1, c2, n_units=1)
    assert out1["flops"] == 10.0


def test_combine_linear_clamps_negative_delta():
    c1 = {"flops": 10.0}
    c2 = {"flops": 9.0}      # compiler noise
    out = combine_linear(c1, c2, 10)
    assert out["flops"] == 10.0


def test_scale_cost():
    c = {"flops": 2.0, "collectives": {"total": 3}}
    out = scale_cost(c, 8)
    assert out == {"flops": 16.0, "collectives": {"total": 24}}


def test_unrolled_scan_cost_exactness():
    """The machinery's reason to exist: scan undercounts, unroll doesn't."""
    d = 64

    def fwd(x, ws, unroll):
        if unroll:
            for i in range(4):
                x = jnp.tanh(x @ ws[i])
            return x
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None),
                            x, ws)[0]

    xs = jax.ShapeDtypeStruct((8, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, d, d), jnp.float32)
    analytic = 2 * 8 * d * d * 4
    def cost(compiled):
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):      # jax 0.4.x: one dict/program
            ca = ca[0] if ca else {}
        return ca

    f_scan = jax.jit(lambda x, w: fwd(x, w, False)).lower(xs, ws).compile()
    f_unrl = jax.jit(lambda x, w: fwd(x, w, True)).lower(xs, ws).compile()
    assert cost(f_scan)["flops"] < analytic * 0.5
    assert cost(f_unrl)["flops"] == pytest.approx(analytic, rel=0.01)
