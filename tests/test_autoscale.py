"""Autoscaler policy behaviour + metrics-registry thread safety.

The Autoscaler is tested on a logical clock (every decision input takes
an explicit ``now``): arrival slopes and idle windows are constructed
exactly, and only the asynchronous prewarm dispatch needs a real-time
wait.  The metrics registry — the signal surface everything here reads
— is stormed under the instrumented lock probe (REPRO_ANALYZE=1): its
instrument locks must stay leaves (zero cycles) and do no I/O under a
lock (zero hazards)."""
import threading
import time

import pytest

from repro import analysis as RL
from repro.metrics import MetricsRegistry
from repro.serving.autoscale import Autoscaler
from repro.serving.pool import InstancePool


class WarmableInstance:
    """FunctionInstance's prewarm contract (ensure_live) without jax."""
    gen_slots = 4

    def __init__(self, load_s=0.0):
        self.params = None
        self.loads = 0
        self.load_s = load_s

    @property
    def live(self):
        return self.params is not None

    def ensure_live(self):
        if self.live:
            return False
        if self.load_s:
            time.sleep(self.load_s)
        self.loads += 1
        self.params = {"w": 1}
        return True

    def evict(self):
        self.params = None


def _pool(max_instances=4, load_s=0.0, metrics=None):
    return InstancePool("m", builder=None, max_instances=max_instances,
                        instance_factory=lambda: WarmableInstance(load_s),
                        metrics=metrics)


def _wait_live(pool, n, timeout=5.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pool.stats().live >= n:
            return True
        time.sleep(0.01)
    return False


# ---------------------------------------------------------------------------
# scale-out: rate slope -> pre-provisioned warm instances
# ---------------------------------------------------------------------------

def test_rising_arrival_slope_preprovisions_warm_instances():
    pool = _pool(max_instances=4)
    m = MetricsRegistry()
    asc = Autoscaler({"m": pool}, rps_per_instance=1.0, window_s=4.0,
                     horizon_s=2.0, queue_per_instance=0, metrics=m)
    try:
        # flat trickle (~0.5 rps): one instance is enough
        asc.observe("m", now=0.0)
        asc.observe("m", now=2.0)
        assert asc.target_warm("m", now=4.0) == 1
        # rising ramp: 8 arrivals in the recent 2 s; the positive slope
        # is extrapolated horizon_s ahead, past the raw recent rate
        for i in range(8):
            asc.observe("m", now=4.0 + i * 0.25)
        assert asc.rate_estimate("m", now=6.0) > 4.0
        assert asc.target_warm("m", now=6.0) == 4  # clamped to the pool
        asc.tick(now=6.0)                          # dispatches prewarms
        assert _wait_live(pool, 4)
    finally:
        asc.stop()                                 # drains in-flight jobs
    st = pool.stats()
    assert st.live == 4 and st.prewarms == 4
    # prewarms are provisioning, not served requests
    assert st.cold_starts == 0 and st.warm_hits == 0
    assert m.counter("autoscaler/m/prewarms").value == 4
    assert m.gauge("autoscaler/m/target").value == 4


def test_tick_does_not_duplicate_inflight_prewarms():
    """A tick while prewarms are still loading must not dispatch the
    deficit again (the in-flight count covers it)."""
    pool = _pool(max_instances=4, load_s=0.2)
    asc = Autoscaler({"m": pool}, rps_per_instance=1.0, window_s=4.0,
                     horizon_s=2.0, queue_per_instance=0,
                     max_prewarm_workers=4)
    try:
        for i in range(8):
            asc.observe("m", now=i * 0.25)
        asc.tick(now=2.0)
        asc.tick(now=2.05)                         # loads still running
        asc.tick(now=2.10)
        assert _wait_live(pool, 4)
    finally:
        asc.stop()
    st = pool.stats()
    assert st.size == 4 and st.prewarms == 4       # not 12


# ---------------------------------------------------------------------------
# scale-in: idle window -> reclaim, never below min_warm
# ---------------------------------------------------------------------------

def test_scale_in_reclaims_idle_capacity_after_idle_window():
    pool = _pool(max_instances=4)
    m = MetricsRegistry()
    asc = Autoscaler({"m": pool}, rps_per_instance=1.0, window_s=4.0,
                     horizon_s=0.0, queue_per_instance=0,
                     idle_scale_in_s=10.0, min_warm=1, metrics=m)
    try:
        for i in range(8):
            asc.observe("m", now=i * 0.25)         # burst justifies 4
        asc.tick(now=2.0)
        assert _wait_live(pool, 4)
        # idle, but shorter than the scale-in window: keep capacity
        asc.tick(now=5.0)
        assert pool.stats().live == 4
        # idle past the window: back to min_warm, evictions counted
        asc.tick(now=30.0)
        assert pool.stats().live == 1
        assert m.counter("autoscaler/m/scale_ins").value == 3
        assert pool.stats().evictions == 3
    finally:
        asc.stop()


def test_scale_in_never_evicts_gen_held_instances():
    """An instance with a resident generation lives in the pool's busy
    list until its last shared hold drops — scale-in (idle-only) cannot
    reach it, via the direct call or the autoscaler's idle tick."""
    pool = _pool(max_instances=2)
    assert pool.prewarm() and pool.prewarm()
    assert pool.stats().live == 2
    inst, joinable = pool.acquire_gen()
    assert joinable and inst.live
    assert pool.scale_in(0) == 1                   # only the idle one
    assert inst.live
    st = pool.stats()
    assert st.live == 1 and st.gen_active == 1
    # the autoscaler's most aggressive case: zero target, idle forever
    asc = Autoscaler({"m": pool}, rps_per_instance=1.0,
                     queue_per_instance=0, idle_scale_in_s=0.0)
    try:
        asc.tick(now=1e9)
    finally:
        asc.stop()
    assert inst.live and pool.stats().gen_active == 1
    # once the generation leaves, the instance is ordinary idle capacity
    pool.release_gen(inst)
    assert pool.scale_in(0) == 1
    assert not inst.live


def test_prewarm_is_not_a_served_request():
    pool = _pool(max_instances=2)
    assert pool.prewarm() is True
    st = pool.stats()
    assert st.prewarms == 1 and st.live == 1
    assert st.cold_starts == 0 and st.warm_hits == 0
    assert pool.prewarm() is True                  # scales out
    assert pool.prewarm() is False                 # at max, all live
    assert pool.stats().prewarms == 2


# ---------------------------------------------------------------------------
# queue-depth term + background loop
# ---------------------------------------------------------------------------

def test_queue_depth_term_adds_capacity_when_rate_lags():
    class _Router:
        def __init__(self, depth):
            self._depth = depth

        def queue_depth(self):
            return self._depth

    pool = _pool(max_instances=4)
    asc = Autoscaler({"m": pool}, rps_per_instance=1.0, window_s=4.0,
                     queue_per_instance=4)
    try:
        asc.router = _Router(0)
        assert asc.target_warm("m", now=0.0) == 0  # no arrivals, no queue
        # a backlog the rate estimate hasn't seen yet forces capacity
        asc.router = _Router(12)
        assert asc.target_warm("m", now=0.0) >= 2
    finally:
        asc.stop()


def test_background_loop_ticks_and_stops_clean():
    pool = _pool(max_instances=2)
    m = MetricsRegistry()
    with Autoscaler({"m": pool}, rps_per_instance=1.0, interval_s=0.02,
                    queue_per_instance=0, metrics=m) as asc:
        asc.observe("m")
        deadline = time.monotonic() + 5.0
        while "autoscaler/m/target" not in m.names() and \
                time.monotonic() < deadline:
            time.sleep(0.01)
    assert "autoscaler/m/target" in m.names()      # ticked at least once
    assert asc._thread is None                     # stopped by __exit__


# ---------------------------------------------------------------------------
# metrics registry: thread safety under the instrumented lock probe
# ---------------------------------------------------------------------------

@pytest.fixture
def analyze(monkeypatch):
    monkeypatch.setenv("REPRO_ANALYZE", "1")
    RL.probe.reset()
    yield RL.probe
    RL.probe.reset()


def test_metrics_registry_concurrent_storm(analyze):
    """8 threads hammer one registry (create-or-get races included):
    exact final counts, and the probe sees zero lock cycles and zero
    I/O-under-lock hazards — instrument locks stay leaves."""
    m = MetricsRegistry()
    n_threads, n_iter = 8, 300
    barrier = threading.Barrier(n_threads)
    errors = []

    def worker(tid):
        try:
            barrier.wait()
            for i in range(n_iter):
                m.counter("c").inc()
                m.counter(f"c{tid % 2}").inc(2)
                m.gauge("g").set(float(i))
                m.gauge("hw").add(1.0)
                m.histogram("h").observe(i * 1e-3)
        except BaseException as e:                 # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors and not any(t.is_alive() for t in threads)
    total = n_threads * n_iter
    assert m.counter("c").value == total
    assert m.counter("c0").value + m.counter("c1").value == 2 * total
    assert m.gauge("hw").value == total
    snap = m.snapshot()
    assert snap["histograms"]["h"]["count"] == total
    rep = analyze.report()
    assert rep["cycles"] == []
    assert rep["hazards"] == []


def test_autoscaler_under_probe_no_cycles(analyze):
    """The full observe/tick/prewarm/scale-in loop under the probe:
    the autoscaler CV, pool CV and metric instruments interleave
    without closing a lock cycle or doing I/O under a lock."""
    m = MetricsRegistry()
    pool = _pool(max_instances=3, metrics=m)
    asc = Autoscaler({"m": pool}, rps_per_instance=1.0, window_s=2.0,
                     horizon_s=1.0, queue_per_instance=0,
                     idle_scale_in_s=5.0, metrics=m)
    try:
        for i in range(6):
            asc.observe("m", now=i * 0.25)
            asc.tick(now=i * 0.25)
        assert _wait_live(pool, 1)
        asc.tick(now=100.0)                        # idle -> scale-in
    finally:
        asc.stop()
    rep = analyze.report()
    assert rep["cycles"] == []
    assert rep["hazards"] == []
