"""The concurrency toolkit itself: lint rules R1-R5 (fixture snippets,
positive + negative, suppression syntax, stable IDs), the runtime lock
probe (cycle detection, I/O hazards, cv-wait bookkeeping), barrier-
released thread-fuzz storms over WeightCache / InstancePool under
REPRO_ANALYZE=1, and the meta-test pinning ``src/repro`` clean modulo
``tests/analysis_baseline.txt``."""
import os
import textwrap
import threading
import time

import pytest

from repro.analysis import lint as L
from repro.analysis import lockgraph as G
from repro.analysis import locks as RL

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src", "repro")
BASELINE = os.path.join(REPO, "tests", "analysis_baseline.txt")


def lint(src, relpath="mod.py"):
    return L.lint_source(textwrap.dedent(src), relpath)


def ids_of(findings):
    return {f.id for f in findings}


# ---------------------------------------------------------------------------
# R1 guarded-by
# ---------------------------------------------------------------------------

def test_r1_fires_on_unlocked_access_and_not_on_locked():
    fs = lint("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0   # guarded-by: _lock

            def good(self):
                with self._lock:
                    self._n += 1

            def bad(self):
                return self._n
    """)
    assert ids_of(fs) == {"R1:mod.py:C.bad:_n"}
    assert fs[0].rule == "R1"


def test_r1_factory_made_lock_and_registry_declaration():
    fs = lint("""
        from repro.analysis import make_lock

        class C:
            _guarded_by = {"_n": "_lock"}

            def __init__(self):
                self._lock = make_lock("C._lock")
                self._n = 0

            def bad(self):
                self._n = 5
    """)
    assert ids_of(fs) == {"R1:mod.py:C.bad:_n"}


def test_r1_writes_mode_checks_mutations_only():
    fs = lint("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.events = []   # guarded-by[writes]: _lock

            def ok_read(self):
                return len(self.events)

            def ok_locked_write(self):
                with self._lock:
                    self.events.append(1)

            def bad_append(self):
                self.events.append(1)

            def bad_setitem(self):
                self.events[0] = 2

            def bad_rebind(self):
                self.events = []
    """)
    assert ids_of(fs) == {"R1:mod.py:C.bad_append:events",
                          "R1:mod.py:C.bad_setitem:events",
                          "R1:mod.py:C.bad_rebind:events"}


def test_r1_skips_locked_suffix_init_and_lambdas():
    fs = lint("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0   # guarded-by: _lock

            def _bump_locked(self):
                self._n += 1          # caller holds the lock: convention

            def deferred(self):
                return lambda: self._n    # runs under unknowable scope
    """)
    assert fs == []


def test_r1_inline_suppression():
    fs = lint("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0   # guarded-by: _lock

            def prepare(self):
                self._n = 0   # analysis: ignore[R1]
    """)
    assert fs == []


# ---------------------------------------------------------------------------
# R2 cv-wait discipline
# ---------------------------------------------------------------------------

R2_SRC = """
    import threading

    class C:
        def __init__(self):
            self._cv = threading.Condition()
            self.ready = False

        def bad_poll(self):
            with self._cv:
                self._cv.wait(0.02)

        def good(self, wait_s):
            with self._cv:
                while not self.ready:
                    self._cv.wait(wait_s)
"""


def test_r2_flags_no_while_and_literal_timeout():
    fs = lint(R2_SRC)
    assert ids_of(fs) == {
        "R2:mod.py:C.bad_poll:_cv.wait-not-in-while",
        "R2:mod.py:C.bad_poll:_cv.wait-literal-timeout-0.02"}
    # the good computed-deadline while-loop wait produced nothing
    assert all("good" not in f.scope for f in fs)


def test_r2_inline_suppression_and_stable_ids_across_line_shift():
    shifted = "\n\n\n" + textwrap.dedent(R2_SRC)
    assert ids_of(lint(R2_SRC)) == ids_of(L.lint_source(shifted, "mod.py"))


# ---------------------------------------------------------------------------
# R3 lock order
# ---------------------------------------------------------------------------

def test_r3_cycle_in_nested_with_acquisitions():
    fs = lint("""
        import threading

        class A:
            def __init__(self):
                self._la = threading.Lock()
                self._lb = threading.Lock()

            def one(self):
                with self._la:
                    with self._lb:
                        pass

            def two(self):
                with self._lb:
                    with self._la:
                        pass
    """)
    assert len(fs) == 1 and fs[0].rule == "R3"
    assert "A._la" in fs[0].message and "A._lb" in fs[0].message


def test_r3_edge_via_typed_attribute_call_resolution():
    model = L.FileModel(textwrap.dedent("""
        import threading

        class Inner:
            def __init__(self):
                self._lock = threading.Lock()

            def poke(self):
                with self._lock:
                    pass

        class Outer:
            def __init__(self, inner: Inner):
                self._lock = threading.Lock()
                self.inner = inner

            def call(self):
                with self._lock:
                    self.inner.poke()
    """), "m.py")
    edges, cycles = L.build_static_lockgraph([model])
    assert ("Outer._lock", "Inner._lock") in {(e.src, e.dst) for e in edges}
    assert cycles == []


def test_r3_no_cycle_for_consistent_order():
    fs = lint("""
        import threading

        class A:
            def __init__(self):
                self._la = threading.Lock()
                self._lb = threading.Lock()

            def one(self):
                with self._la:
                    with self._lb:
                        pass

            def two(self):
                with self._la:
                    with self._lb:
                        pass
    """)
    assert fs == []


# ---------------------------------------------------------------------------
# R4 time.sleep
# ---------------------------------------------------------------------------

def test_r4_flags_sleep_and_respects_allowlist():
    src = """
        import time

        def poll():
            time.sleep(0.1)
    """
    fs = lint(src)
    assert ids_of(fs) == {"R4:mod.py:poll:time.sleep"}
    # the simulated storage device is allowed to sleep
    assert lint(src, relpath="store/store.py") == []
    # inline suppression
    assert lint(src.replace("time.sleep(0.1)",
                            "time.sleep(0.1)  # analysis: ignore[R4]")) == []


# ---------------------------------------------------------------------------
# R5 jit-cache hygiene
# ---------------------------------------------------------------------------

def test_r5_flags_bound_method_jit_not_lambda_or_module_fn():
    fs = lint("""
        import jax
        from repro.kernels import ref

        class C:
            def __init__(self, model):
                self._bad = jax.jit(self.step)
                self._bad2 = jax.jit(model.prefill)
                self._ok = jax.jit(lambda p, b: model.forward(p, b))
                self._ok2 = jax.jit(ref.decode_attention)

            def step(self, x):
                return x
    """)
    assert ids_of(fs) == {
        "R5:mod.py:C.__init__:jit-bound-method-self.step",
        "R5:mod.py:C.__init__:jit-bound-method-model.prefill"}


def test_r5_inline_suppression():
    fs = lint("""
        import jax

        class C:
            def __init__(self, model):
                self._f = jax.jit(model.assemble)  # analysis: ignore[R5]
    """)
    assert fs == []


# ---------------------------------------------------------------------------
# baseline workflow
# ---------------------------------------------------------------------------

def test_baseline_load_apply_and_stale_detection(tmp_path):
    fs = lint(R2_SRC)
    p = tmp_path / "baseline.txt"
    p.write_text(
        "# comment line\n"
        "R2:mod.py:C.bad_poll:_cv.wait-not-in-while  # legacy polling\n"
        "R2:mod.py:C.gone:_cv.wait-not-in-while  # no longer exists\n")
    baseline = L.load_baseline(str(p))
    assert baseline["R2:mod.py:C.bad_poll:_cv.wait-not-in-while"] \
        == "legacy polling"
    unsup, stale = L.apply_baseline(fs, baseline)
    assert ids_of(unsup) == {"R2:mod.py:C.bad_poll:"
                             "_cv.wait-literal-timeout-0.02"}
    assert stale == ["R2:mod.py:C.gone:_cv.wait-not-in-while"]


# ---------------------------------------------------------------------------
# meta: the repro tree itself is clean modulo the reviewed baseline
# ---------------------------------------------------------------------------

def test_src_repro_clean_modulo_baseline():
    findings = L.lint_paths([SRC])
    unsup, stale = L.apply_baseline(findings, L.load_baseline(BASELINE))
    assert not unsup, "\n".join(f.render() for f in unsup)
    assert not stale, f"stale baseline entries: {stale}"


def test_src_repro_static_lockgraph_acyclic():
    edges, cycles = L.build_static_lockgraph(L.load_models(SRC))
    assert cycles == []


# ---------------------------------------------------------------------------
# runtime probe
# ---------------------------------------------------------------------------

@pytest.fixture
def analyze(monkeypatch):
    monkeypatch.setenv("REPRO_ANALYZE", "1")
    RL.probe.reset()
    yield RL.probe
    RL.probe.reset()


def test_factory_returns_plain_primitives_when_disabled(monkeypatch):
    monkeypatch.delenv("REPRO_ANALYZE", raising=False)
    assert isinstance(RL.make_lock("x"), type(threading.Lock()))
    assert isinstance(RL.make_condition("x"), threading.Condition)


def test_probe_observes_edges_and_detects_inversion(analyze):
    a, b = RL.make_lock("A"), RL.make_lock("B")
    with a:
        with b:
            pass
    assert analyze.cycles() == []
    with b:
        with a:                      # inversion: closes A->B->A
            pass
    cycles = analyze.cycles()
    assert len(cycles) == 1 and set(cycles[0]) == {"A", "B"}
    rep = analyze.report()
    assert {(e["src"], e["dst"]) for e in rep["edges"]} \
        == {("A", "B"), ("B", "A")}


def test_probe_io_hazard_only_under_held_lock(analyze):
    a = RL.make_lock("A")
    RL.note_io("read_unit")                  # no lock held: fine
    assert analyze.report()["hazards"] == []
    with a:
        RL.note_io("read_unit")
    hz = analyze.report()["hazards"]
    assert hz == [{"io": "read_unit", "held": ["A"],
                   "thread": threading.current_thread().name}]


def test_condition_wait_suspends_held_lock(analyze):
    cv = RL.make_condition("CV")
    seen = {}
    done = []

    def waiter():
        with cv:
            while not done:
                cv.wait()

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    # while the waiter is parked its lock must be SUSPENDED, so another
    # thread acquiring it records no contention-edge artifacts and an
    # I/O probe on the waiter's behalf would see nothing held
    with cv:
        seen["acquired_while_waiting"] = True
        done.append(1)
        cv.notify_all()
    t.join(timeout=5)
    assert not t.is_alive() and seen["acquired_while_waiting"]
    rep = analyze.report()
    assert rep["cv_waits"]["CV"]["waits"] >= 1
    assert rep["cv_waits"]["CV"]["timed_waits"] == 0
    assert rep["cycles"] == []


def test_probe_wait_for_records_waits(analyze):
    cv = RL.make_condition("CV2")
    done = []

    def setter():
        time.sleep(0.02)
        with cv:
            done.append(1)
            cv.notify_all()

    t = threading.Thread(target=setter)
    t.start()
    with cv:
        assert cv.wait_for(lambda: done, timeout=5.0)
    t.join()
    assert analyze.report()["cv_waits"]["CV2"]["waits"] >= 1


def test_merge_static_and_observed_graphs(analyze, tmp_path):
    a, b = RL.make_lock("X"), RL.make_lock("Y")
    with a:
        with b:
            pass
    obs = tmp_path / "probe.json"
    analyze.dump(str(obs))
    static_edges = [L.LockEdge("Y", "X", "m.py:1")]
    report = G.merge(static_edges, G.load_observed(str(obs)))
    assert [tuple(c) for c in report["cycles"]] == [("X", "Y")]
    text = G.render(report)
    assert "CYCLES" in text and "X -> Y" in text


# ---------------------------------------------------------------------------
# thread-fuzz storms (satellite): cache + pool under the probe
# ---------------------------------------------------------------------------

def test_fuzz_weight_cache_storm(analyze):
    from repro.store.cache import HIT, LOAD, WeightCache

    cache = WeightCache(budget_bytes=3_000)      # forces evictions
    cache.register_load("m")
    units = [f"u{i}" for i in range(6)]
    n_threads = 8
    barrier = threading.Barrier(n_threads)
    errors = []

    def worker(tid):
        try:
            barrier.wait()
            for i in range(30):
                u = units[(tid + i) % len(units)]
                status, leaves = cache.begin("m", u)
                if status == LOAD:
                    cache.complete("m", u, {"w": tid}, 1_000)
                else:
                    assert status == HIT and leaves is not None
                cache.release("m", u)
        except BaseException as e:               # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors and not any(t.is_alive() for t in threads)
    cache.unregister_load("m")
    st = cache.stats()
    assert st.pinned == 0
    rep = analyze.report()
    assert rep["cycles"] == []
    assert rep["hazards"] == []
    assert rep["locks"]["WeightCache._cv"]["acquires"] > 0


def test_fuzz_instance_pool_storm(analyze):
    from repro.serving.pool import InstancePool

    class _Dummy:
        gen_slots = 4

        def __init__(self):
            self.params = None

        @property
        def live(self):
            return self.params is not None

        def evict(self):
            self.params = None

    pool = InstancePool("m", builder=None, max_instances=3,
                        instance_factory=_Dummy)
    n_threads = 8
    barrier = threading.Barrier(n_threads)
    errors = []

    def worker(tid):
        try:
            barrier.wait()
            for i in range(25):
                if (tid + i) % 2:
                    try:
                        inst = pool.acquire(timeout=5.0, logical_now=i)
                    except TimeoutError:
                        continue
                    inst.params = {"w": 1}
                    pool.release(inst, logical_now=i, cold=False)
                else:
                    try:
                        inst, joinable = pool.acquire_gen(timeout=5.0)
                    except TimeoutError:
                        continue
                    if not joinable:
                        inst.params = {"w": 1}
                        pool.mark_live(inst)
                    pool.release_gen(inst, logical_now=i)
        except BaseException as e:               # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors and not any(t.is_alive() for t in threads)
    st = pool.stats()
    assert st.busy == 0 and st.gen_active == 0
    rep = analyze.report()
    assert rep["cycles"] == []
    assert rep["locks"]["InstancePool._cv"]["acquires"] > 0
    # and the two fuzzed modules are R1-clean statically
    fs = L.lint_paths([os.path.join(SRC, "store", "cache.py"),
                       os.path.join(SRC, "serving", "pool.py")])
    assert [f for f in fs if f.rule == "R1"] == []
