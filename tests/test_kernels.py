"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes, plus the XLA fallbacks against the same
oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention as decode_pallas
from repro.kernels.decode_attention import (decode_attention_paged
                                            as paged_pallas)
from repro.kernels.flash_attention import flash_attention as flash_pallas
from repro.kernels.rglru_scan import rglru_scan as rglru_pallas
from repro.kernels.ssd_scan import ssd_scan as ssd_pallas
from repro.kernels.weight_transform import weight_transform as wt_pallas

R = np.random.default_rng(0)


def arr(*s, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(R.standard_normal(s) * scale, dtype)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,K,S,dh", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 8, 2, 256, 64),     # GQA 4x
    (1, 3, 1, 128, 32),     # MQA, odd heads
])
@pytest.mark.parametrize("causal,window", [
    (True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(B, H, K, S, dh, causal, window, dtype):
    q, k, v = arr(B, H, S, dh, dtype=dtype), arr(B, K, S, dh, dtype=dtype), \
        arr(B, K, S, dh, dtype=dtype)
    o_ref = ref.mha_attention(q, k, v, causal=causal, window=window)
    o_pal = flash_pallas(q, k, v, causal=causal, window=window,
                         bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32), **tol(dtype))
    o_xla = ops._xla_flash(q, k, v, causal=causal, window=window, bk=64)
    np.testing.assert_allclose(np.asarray(o_xla, np.float32),
                               np.asarray(o_ref, np.float32), **tol(dtype))


def test_flash_chunked_prefill():
    """T > S: queries are the last S positions (prefix continuation)."""
    B, H, K, S, T, dh = 1, 4, 2, 64, 192, 32
    q = arr(B, H, S, dh)
    k, v = arr(B, K, T, dh), arr(B, K, T, dh)
    o_ref = ref.mha_attention(q, k, v, causal=True, window=0)
    o_pal = flash_pallas(q, k, v, causal=True, window=0, bq=32, bk=32,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_block_shape_sweep():
    B, H, K, S, dh = 1, 2, 2, 256, 64
    q, k, v = arr(B, H, S, dh), arr(B, K, S, dh), arr(B, K, S, dh)
    o_ref = ref.mha_attention(q, k, v, causal=True)
    for bq, bk in [(32, 128), (128, 32), (256, 256), (64, 64)]:
        o = flash_pallas(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_vs_ref(window, dtype):
    B, H, K, dh, S = 3, 8, 2, 64, 128
    q = arr(B, H, dh, dtype=dtype)
    kc, vc = arr(B, K, S, dh, dtype=dtype), arr(B, K, S, dh, dtype=dtype)
    pos = jnp.asarray([3, 100, 127], jnp.int32)
    o_ref = ref.decode_attention(q, kc, vc, pos, window=window)
    o_pal = decode_pallas(q, kc, vc, pos, window=window, bs=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32), **tol(dtype))


def test_decode_matches_full_attention():
    """Decode over a cache == last row of full causal attention."""
    B, H, K, dh, S = 2, 4, 2, 32, 96
    q_all = arr(B, H, S, dh)
    k_all, v_all = arr(B, K, S, dh), arr(B, K, S, dh)
    full = ref.mha_attention(q_all, k_all, v_all, causal=True)
    pos = jnp.full((B,), S - 1, jnp.int32)
    dec = ref.decode_attention(q_all[:, :, -1], k_all, v_all, pos)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :, -1]),
                               atol=1e-5, rtol=1e-5)


def test_decode_ring_buffer_semantics():
    """A full ring cache attends to exactly the last `window` positions."""
    B, H, K, dh, W = 1, 2, 1, 16, 32
    pos_val = 100                          # cache wrapped 3+ times
    keys = arr(B, K, W, dh)
    vals = arr(B, K, W, dh)
    q = arr(B, H, dh)
    pos = jnp.asarray([pos_val], jnp.int32)
    out = ref.decode_attention(q, keys, vals, pos, window=W)
    # oracle: arrange the W entries by absolute position and attend to all
    o_pal = decode_pallas(q, keys, vals, pos, window=W, bs=8, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(out),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------

def _paged_from_slotted(kc, vc, NP, pt, n_garbage=0):
    """Scatter a (B, K, S, dh) slotted cache into page pools + tables;
    page order is deliberately shuffled.  ``n_garbage`` extra table
    columns point at an arbitrary live page (rows beyond the logical
    extent, whose masking must zero them exactly)."""
    B, K, S, dh = kc.shape
    assert S == NP * pt
    P = B * NP + 2                         # two never-referenced pages
    perm = np.random.default_rng(7).permutation(B * NP)
    tables = np.full((B, NP + n_garbage), perm[0], np.int32)
    k_pages = np.array(arr(P, K, pt, dh, dtype=kc.dtype))  # garbage fill
    v_pages = np.array(arr(P, K, pt, dh, dtype=vc.dtype))
    for b in range(B):
        for j in range(NP):
            pid = int(perm[b * NP + j])
            tables[b, j] = pid
            k_pages[pid] = np.asarray(kc[b, :, j * pt:(j + 1) * pt])
            v_pages[pid] = np.asarray(vc[b, :, j * pt:(j + 1) * pt])
    return (jnp.asarray(k_pages), jnp.asarray(v_pages),
            jnp.asarray(tables))


@pytest.mark.parametrize("window", [0, 128])
@pytest.mark.parametrize("pt,bs", [(32, 32), (32, 16), (64, 32)])
def test_decode_paged_vs_slotted(window, pt, bs):
    """Paged kernel == slotted kernel on the same logical cache, across
    divisible and sub-page tile sizes."""
    B, H, K, dh, S = 3, 8, 2, 64, 128
    q = arr(B, H, dh)
    kc, vc = arr(B, K, S, dh), arr(B, K, S, dh)
    pos = jnp.asarray([3, 100, 127], jnp.int32)
    o_slot = ref.decode_attention(q, kc, vc, pos, window=window)
    k_pages, v_pages, tables = _paged_from_slotted(kc, vc, S // pt, pt)
    o_ref = ref.decode_attention_paged(q, k_pages, v_pages, tables, pos,
                                       window=window)
    np.testing.assert_array_equal(np.asarray(o_ref), np.asarray(o_slot))
    o_pal = paged_pallas(q, k_pages, v_pages, tables, pos, window=window,
                         bs=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_slot),
                               atol=2e-5, rtol=2e-5)


def test_decode_paged_ragged_tables():
    """Table columns beyond the logical extent hold arbitrary page ids:
    their positions exceed ``pos`` so masking must zero them exactly."""
    B, H, K, dh, S, pt = 2, 4, 2, 32, 64, 16
    q = arr(B, H, dh)
    kc, vc = arr(B, K, S, dh), arr(B, K, S, dh)
    pos = jnp.asarray([10, 63], jnp.int32)
    o_slot = ref.decode_attention(q, kc, vc, pos)
    k_pages, v_pages, tables = _paged_from_slotted(kc, vc, S // pt, pt,
                                                   n_garbage=2)
    o_ref = ref.decode_attention_paged(q, k_pages, v_pages, tables, pos)
    np.testing.assert_array_equal(np.asarray(o_ref), np.asarray(o_slot))
    o_pal = paged_pallas(q, k_pages, v_pages, tables, pos, bs=16,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_slot),
                               atol=2e-5, rtol=2e-5)


def test_decode_paged_ops_dispatch():
    """ops.decode_attention_paged (registry wrapper) matches the oracle
    in whatever mode this environment resolved."""
    B, H, K, dh, S, pt = 2, 4, 2, 32, 64, 16
    q = arr(B, H, dh)
    kc, vc = arr(B, K, S, dh), arr(B, K, S, dh)
    pos = jnp.asarray([7, 60], jnp.int32)
    k_pages, v_pages, tables = _paged_from_slotted(kc, vc, S // pt, pt)
    o_ref = ref.decode_attention_paged(q, k_pages, v_pages, tables, pos)
    o = ops.decode_attention_paged(q, k_pages, v_pages, tables, pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,nh,S,dp,N,bc", [
    (1, 2, 64, 16, 32, 16),
    (2, 3, 128, 32, 64, 64),
    (1, 1, 96, 16, 16, 32),    # S not a multiple of 2*bc
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_vs_ref(b, nh, S, dp, N, bc, dtype):
    x = arr(b, nh, S, dp, dtype=dtype)
    dt = jnp.abs(arr(b, nh, S)) * 0.1 + 0.01
    A = -jnp.abs(arr(nh)) - 0.1
    B = arr(b, S, N, scale=0.3)
    C = arr(b, S, N, scale=0.3)
    y_ref = ref.ssd(x, dt, A, B, C)
    y_pal = ssd_pallas(x, dt, A, B, C, bc=bc, interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)
    y_xla = ops._xla_ssd(x, dt, A, B, C, bc=bc)
    np.testing.assert_allclose(np.asarray(y_xla, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_step_matches_scan():
    b, nh, S, dp, N = 2, 2, 16, 8, 16
    x = arr(b, nh, S, dp)
    dt = jnp.abs(arr(b, nh, S)) * 0.1 + 0.01
    A = -jnp.abs(arr(nh)) - 0.1
    B, C = arr(b, S, N), arr(b, S, N)
    y_ref = ref.ssd(x, dt, A, B, C)
    h = jnp.zeros((b, nh, dp, N))
    for t in range(S):
        h, y = ops.ssd_step(h, x[:, :, t], dt[:, :, t], A, B[:, t], C[:, t])
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref[:, :, t]),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W,bc", [(1, 64, 32, 16), (3, 128, 48, 64),
                                      (2, 80, 16, 16)])
def test_rglru_vs_ref(B, S, W, bc):
    a = jnp.abs(arr(B, S, W)) * 0.2
    b = arr(B, S, W)
    h_ref = ref.rglru(a, b)
    h_pal = rglru_pallas(a, b, bc=bc, interpret=True)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               atol=1e-5, rtol=1e-5)
    h_xla = ops._xla_rglru(a, b)
    np.testing.assert_allclose(np.asarray(h_xla), np.asarray(h_ref),
                               atol=1e-5, rtol=1e-5)


def test_rglru_ops_pads_nondivisible_seq():
    """The dispatcher pads S up to the chunk size (interpret path)."""
    import os
    B, S, W = 2, 80, 16                    # 80 % 256 != 0
    a = jnp.abs(arr(B, S, W)) * 0.2
    b = arr(B, S, W)
    h_ref = ref.rglru(a, b)
    os.environ["REPRO_PALLAS"] = "interpret"
    try:
        h = ops.rglru_scan(a, b, bc=32)
    finally:
        os.environ.pop("REPRO_PALLAS")
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=1e-5, rtol=1e-5)


def test_rglru_step_matches_scan():
    B, S, W = 2, 12, 8
    a = jnp.abs(arr(B, S, W)) * 0.3
    b = arr(B, S, W)
    h_ref = ref.rglru(a, b)
    h = jnp.zeros((B, W))
    for t in range(S):
        h = ops.rglru_step(h, a[:, t], b[:, t])
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref[:, t]),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# weight transform
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,bn,bm", [(64, 64, 32, 32), (100, 70, 32, 32),
                                       (17, 300, 8, 128)])
def test_weight_transform_dequant(n, m, bn, bm):
    w8 = jnp.asarray(R.integers(-127, 128, (n, m)), jnp.int8)
    sc = jnp.abs(arr(m)) * 0.01 + 1e-4
    o_ref = ref.weight_transform(w8, sc, jnp.float32)
    o_pal = wt_pallas(w8, sc, out_dtype=jnp.float32, bn=bn, bm=bm,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=1e-6, rtol=1e-6)


def test_weight_transform_cast():
    w = arr(50, 130)
    o = wt_pallas(w, out_dtype=jnp.bfloat16, bn=16, bm=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(o),
                                  np.asarray(w.astype(jnp.bfloat16)))


# ---------------------------------------------------------------------------
# quant matmul (fused dequant, w8a16)
# ---------------------------------------------------------------------------

from repro.kernels.quant_matmul import quant_matmul as qm_pallas  # noqa: E402


@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (8, 512, 1024, 8, 128, 256),      # decode: few rows, wide weight
    (128, 256, 512, 64, 128, 128),    # prefill block
    (100, 70, 33, 32, 32, 16),        # nothing divides: padding path
    (17, 300, 5, 8, 64, 4),           # tiny N (stacked-gate leaves)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_vs_ref(m, k, n, bm, bk, bn, dtype):
    x = arr(m, k, dtype=dtype)
    w = jnp.asarray(R.integers(-127, 128, (k, n)), jnp.int8)
    sc = jnp.abs(arr(n)) * 0.02 + 1e-4
    o_ref = ref.quant_matmul(x, w, sc, dtype)
    o_pal = qm_pallas(x, w, sc, out_dtype=dtype, bm=bm, bk=bk, bn=bn,
                      interpret=True)
    assert o_pal.shape == (m, n) and o_pal.dtype == dtype
    # K is accumulated in bk-sized tiles vs the reference's single dot,
    # so f32 picks up summation-order noise past 2e-5
    t = dict(atol=1e-3, rtol=1e-3) if dtype == jnp.float32 else tol(dtype)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32), **t)


def test_quant_matmul_dispatch_leading_dims():
    """ops.quant_matmul collapses leading activation dims (the model
    einsums feed (B, S, K)) and restores them on the output."""
    B, S, K, N = 2, 6, 32, 24
    x = arr(B, S, K)
    w = jnp.asarray(R.integers(-127, 128, (K, N)), jnp.int8)
    sc = jnp.abs(arr(N)) * 0.02 + 1e-4
    o = ops.quant_matmul(x, w, sc)
    assert o.shape == (B, S, N)
    o_ref = ref.quant_matmul(x.reshape(B * S, K), w, sc).reshape(B, S, N)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("eq,wshape,n_contract", [
    ("bsd,dw->bsw", (32, 48), 1),          # dense / griffin projection
    ("bshk,hkd->bsd", (4, 8, 32), 2),      # attn output fold
    ("bsd,dhk->bshk", (32, 4, 8), 1),      # qkv projection
])
def test_quant_einsum_matches_dequant_einsum(eq, wshape, n_contract):
    """quant.einsum == einsum against the dequantized weight, for every
    weight layout the model layers dispatch (scale tiles across middle
    output axes; multi-axis contractions collapse row-major)."""
    from repro import quant

    wq = jnp.asarray(R.integers(-127, 128, wshape), jnp.int8)
    sc = jnp.abs(arr(wshape[-1])) * 0.02 + 1e-4
    leaf = quant.QuantLeaf(wq, sc)
    if n_contract == 1:
        x = arr(2, 5, wshape[0])
    else:
        x = arr(2, 5, *wshape[:n_contract])
    got = quant.einsum(eq, x, leaf, jnp.float32, n_contract=n_contract)
    want = jnp.einsum(eq, x, leaf.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_quant_expert_einsum_matches_dequant():
    """MoE expert dispatch: every expert's slab shares the per-column
    scale; both the routed (per-expert x) and dense-oracle (shared x)
    forms must match the dequantized einsum."""
    from repro import quant

    E, d, f = 4, 16, 24
    wq = jnp.asarray(R.integers(-127, 128, (E, d, f)), jnp.int8)
    sc = jnp.abs(arr(f)) * 0.02 + 1e-4
    leaf = quant.QuantLeaf(wq, sc)
    x_routed = arr(2, E, 3, d)                 # (B, E, C, d)
    got = quant.expert_einsum("becd,edf->becf", x_routed, leaf,
                              jnp.float32)
    want = jnp.einsum("becd,edf->becf", x_routed,
                      leaf.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    x_shared = arr(2, 3, d)                    # (B, S, d), dense oracle
    got = quant.expert_einsum("bsd,edf->besf", x_shared, leaf,
                              jnp.float32, shared_x=True)
    want = jnp.einsum("bsd,edf->besf", x_shared,
                      leaf.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_quant_gather_rows_bit_identical():
    """Gather-then-dequant == dequant-then-gather, bit for bit (the
    embedding lookup never materializes the dequantized table)."""
    from repro import quant

    V, D = 64, 16
    wq = jnp.asarray(R.integers(-127, 128, (V, D)), jnp.int8)
    sc = jnp.abs(arr(D)) * 0.02 + 1e-4
    leaf = quant.QuantLeaf(wq, sc)
    idx = jnp.asarray(R.integers(0, V, (2, 7)), jnp.int32)
    got = quant.gather_rows(leaf, idx, jnp.bfloat16)
    want = leaf.astype(jnp.bfloat16)[idx]
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_quant_matmul_interpret_matches_ref_mode():
    """The registry's interpret path (divisor tiles, no padding) agrees
    with the ref fallback through the same dispatcher."""
    import os

    x = arr(12, 80)
    w = jnp.asarray(R.integers(-127, 128, (80, 40)), jnp.int8)
    sc = jnp.abs(arr(40)) * 0.02 + 1e-4
    os.environ["REPRO_PALLAS"] = "ref"
    try:
        o_ref = ops.quant_matmul(x, w, sc)
    finally:
        os.environ.pop("REPRO_PALLAS")
    os.environ["REPRO_PALLAS"] = "interpret"
    try:
        o_int = ops.quant_matmul(x, w, sc)
    finally:
        os.environ.pop("REPRO_PALLAS")
    np.testing.assert_allclose(np.asarray(o_int), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


def test_autotuned_blocks_overlay():
    """set/load_autotuned overlay kernel_blocks() per profile; backend
    mismatches are skipped; clear restores the static profile."""
    from repro.configs import shapes

    base = shapes.kernel_blocks("tpu")
    art = {"autotune": {
        "quant_matmul": {"backend": "cpu",
                         "winner": {"qm_bm": 128, "qm_bk": 256,
                                    "qm_bn": 128}},
        "weight_transform": {"backend": "other",
                             "winner": {"wt_bn": 64}}}}
    try:
        applied = shapes.load_autotuned(art, backend="cpu", profile="tpu")
        assert applied == {"qm_bm": 128, "qm_bk": 256, "qm_bn": 128}
        kb = shapes.kernel_blocks("tpu")
        assert (kb.qm_bm, kb.qm_bk, kb.qm_bn) == (128, 256, 128)
        assert kb.wt_bn == base.wt_bn          # backend mismatch skipped
        assert kb.flash_bq == base.flash_bq    # untouned fields intact
    finally:
        shapes.clear_autotuned()
    kb = shapes.kernel_blocks("tpu")
    assert (kb.qm_bm, kb.qm_bk, kb.qm_bn) == \
        (base.qm_bm, base.qm_bk, base.qm_bn)
