"""Weight store: roundtrip, integrity, int8 quantization bounds,
chunked suspendable reads."""
import threading
import time
import zlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st

from repro.models import transformer
from repro.models.api import get_config
from repro.store.store import (BandwidthModel, WeightStore, deploy_model,
                               flatten_unit, unflatten_unit)


@pytest.fixture
def deployed(tmp_path):
    cfg = get_config("smollm-360m", smoke=True)
    m = transformer.build(cfg)
    store = WeightStore(str(tmp_path))
    deploy_model(store, m, "m", jax.random.key(5))
    return store, m


def test_roundtrip_exact(deployed):
    store, m = deployed
    for unit in ["embed", "block_001", "final"]:
        leaves = store.read_and_deserialize("m", unit)
        ab = m.abstract_unit(unit)
        tree = unflatten_unit(ab, {k: v for k, (v, _) in leaves.items()})
        ref = m.init_unit(unit, jax.random.split(
            jax.random.key(5), len(m.unit_names()))[
                m.unit_names().index(unit)])
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crc_detects_corruption(deployed, tmp_path):
    store, m = deployed
    path = str(tmp_path / "m" / "block_000.bin")
    with open(path, "r+b") as f:
        f.seek(100)
        byte = f.read(1)
        f.seek(100)
        f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(IOError, match="crc"):
        store.read_and_deserialize("m", "block_000")


def test_manifest_accounting(deployed):
    store, m = deployed
    man = store.manifest("m")
    assert set(man["units"]) == set(m.unit_names())
    total = store.model_nbytes("m")
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(m.abstract()))
    assert total >= n_params * 4          # f32 leaves + alignment padding
    assert total < n_params * 4 * 1.05    # padding bounded


@given(n=st.integers(2, 64), m=st.integers(2, 64),
       seed=st.integers(0, 2 ** 16))
def test_int8_quant_roundtrip_bound(n, m, seed):
    """Per-channel int8: |deq - w| <= scale/2 = amax/254 per column."""
    r = np.random.default_rng(seed)
    w = (r.standard_normal((n, m)) * r.uniform(0.01, 10)).astype(np.float32)
    amax = np.abs(w).max(axis=0)
    scale = np.where(amax > 0, amax / 127.0, 1.0)
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    deq = q.astype(np.float32) * scale
    assert (np.abs(deq - w) <= scale / 2 + 1e-7).all()


def test_int8_deploy_shrinks_bytes(tmp_path):
    cfg = get_config("smollm-360m", smoke=True)
    m = transformer.build(cfg)
    store = WeightStore(str(tmp_path))
    deploy_model(store, m, "f32", jax.random.key(0))
    deploy_model(store, m, "i8", jax.random.key(0), quant="int8")
    ratio = store.model_nbytes("i8") / store.model_nbytes("f32")
    assert ratio < 0.35                   # ~4x for matrices, 1-D stays f32


def test_suspend_and_resume(deployed):
    store, m = deployed
    gate = threading.Event()              # cleared -> suspended
    got = {}

    def reader():
        got["raw"] = store.read_unit("m", "block_000", chunk_bytes=64,
                                     gate=gate)

    t = threading.Thread(target=reader)
    t.start()
    time.sleep(0.05)
    assert t.is_alive()                   # blocked on the cleared gate
    gate.set()
    t.join(5)
    assert not t.is_alive()
    assert len(got["raw"]) == store.unit_nbytes("m", "block_000")


def test_bandwidth_model_throttles(tmp_path):
    cfg = get_config("smollm-360m", smoke=True)
    m = transformer.build(cfg)
    fast = WeightStore(str(tmp_path / "fast"))
    deploy_model(fast, m, "m", jax.random.key(0))
    slow = WeightStore(str(tmp_path / "fast"),
                       BandwidthModel(bandwidth_mbps=20))
    nbytes = fast.unit_nbytes("m", "embed")
    t0 = time.monotonic()
    slow.read_unit("m", "embed")
    dur = time.monotonic() - t0
    expect = nbytes / 20e6
    assert dur >= expect * 0.8


def test_flatten_unflatten_inverse(rng):
    tree = {"a": {"b": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "c": [np.ones((4,), np.int32), np.zeros((2, 2), np.float32)]}
    flat = flatten_unit(tree)
    names = [n for n, _ in flat]
    assert len(set(names)) == len(names)  # unique stable paths
    ab = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
    back = unflatten_unit(ab, dict(flat))
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
