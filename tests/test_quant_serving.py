"""Quantized-resident serving (``compute_quant``): int8 leaves stay
resident as :class:`~repro.quant.QuantLeaf` (no ``weight_transform`` at
commit), forwards dispatch the fused-dequant ``quant_matmul`` kernel,
and generation stays token-identical to the dequant-at-load reference.

CI's workflow_dispatch tpu-pallas leg runs this file under
``REPRO_PALLAS=pallas``; the default (and any non-TPU run) exercises
interpret mode — the same kernel bodies walked by the interpreter.
"""
import dataclasses
import types

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.coldstart import ColdStartEngine
from repro.kernels import ops
from repro.models import transformer
from repro.models.api import get_config
from repro.quant import QuantLeaf
from repro.serving import (DecodeScheduler, GenerateSpec, Request,
                           reference_generate)
from repro.serving.engine import ServerlessPlatform
from repro.store.store import WeightStore, deploy_model

CACHE_LEN = 64
PROMPT_LEN = 8

# dense / MoE / hybrid smoke archs (f32 so token identity is meaningful)
GEN_ARCHS = ["smollm-360m", "mixtral-8x7b", "recurrentgemma-2b"]


def _f32_cfg(arch):
    return dataclasses.replace(get_config(arch, smoke=True),
                               compute_dtype=jnp.float32)


def _prompt(cfg, seed):
    r = np.random.default_rng(seed)
    return r.integers(0, cfg.vocab_size, (PROMPT_LEN,)).astype(np.int32)


def _deploy_int8(tmp_path, arch):
    cfg = _f32_cfg(arch)
    m = transformer.build(cfg)
    store = WeightStore(str(tmp_path / "store"))
    deploy_model(store, m, arch, jax.random.key(0), quant="int8")
    return cfg, m, store


def _quant_load(m, arch, store):
    eng = ColdStartEngine(m, arch, store, compute_quant=True)
    cfg_batch = {"tokens": jnp.zeros((1, PROMPT_LEN), jnp.int32)}
    return eng.load(cfg_batch).params


def _leaves(params):
    return jax.tree.leaves(
        params, is_leaf=lambda l: isinstance(l, QuantLeaf))


# ---------------------------------------------------------------------------
# residency: cold-start apply keeps QuantLeaf, bytes shrink
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", GEN_ARCHS)
def test_quant_resident_params_shrink(arch, tmp_path):
    """compute_quant apply keeps int8 + scale resident: the matmul
    weights come back as QuantLeaf and total param bytes land well
    under the dequantized load's."""
    cfg, m, store = _deploy_int8(tmp_path, arch)
    qparams = _quant_load(m, arch, store)
    qleaves = [l for l in _leaves(qparams) if isinstance(l, QuantLeaf)]
    assert qleaves, "no leaf stayed quantized"
    for l in qleaves:
        assert l.q.dtype == jnp.int8
        assert l.scale.dtype == jnp.float32
        # stacked-layer leaves carry stacked (L, last) scales
        assert l.scale.shape[-1] == l.q.shape[-1]

    fparams = ColdStartEngine(m, arch, store).load(
        {"tokens": jnp.zeros((1, PROMPT_LEN), jnp.int32)}).params
    qbytes = sum(l.nbytes for l in _leaves(qparams))
    fbytes = sum(l.nbytes for l in _leaves(fparams))
    # int8 + per-column f32 scale vs f32 leaves; norms/gates stay float
    assert qbytes < 0.6 * fbytes


def test_compute_quant_rejects_mesh(tmp_path):
    """Quantized residency is single-device: shard plans describe the
    dequantized layout, so compute_quant + mesh must fail loudly."""
    cfg, m, store = _deploy_int8(tmp_path, "smollm-360m")
    with pytest.raises(ValueError, match="single"):
        ColdStartEngine(m, "smollm-360m", store, compute_quant=True,
                        mesh=types.SimpleNamespace(size=2))


def test_quantleaf_astype_matches_weight_transform():
    """The transparent fallback (QuantLeaf.astype) is bit-identical to
    the registry's dequant — untouched call sites lose nothing."""
    from repro.kernels import ref

    r = np.random.default_rng(3)
    q = jnp.asarray(r.integers(-127, 128, (48, 32)), jnp.int8)
    sc = jnp.asarray(np.abs(r.standard_normal(32)).astype(np.float32)
                     + 1e-3)
    leaf = QuantLeaf(q, sc)
    want = ref.weight_transform(q, sc, jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(leaf.astype(jnp.bfloat16), np.float32),
        np.asarray(want, np.float32))


# ---------------------------------------------------------------------------
# generation identity: DecodeScheduler under compute_quant == reference
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", GEN_ARCHS)
def test_quant_generation_token_identical(arch, tmp_path, monkeypatch):
    """Quantized-resident generation through the continuous-batching
    scheduler reproduces the dequant-at-load reference token-for-token,
    under the resolved kernel mode (interpret by default, pallas on the
    TPU CI leg) — and the run actually dispatched quant_matmul."""
    import os

    mode = os.environ.get("REPRO_PALLAS")
    if mode != "pallas":
        mode = "interpret"
    monkeypatch.setenv("REPRO_PALLAS", mode)

    cfg, m, store = _deploy_int8(tmp_path, arch)
    qparams = _quant_load(m, arch, store)
    fparams = ColdStartEngine(m, arch, store).load(
        {"tokens": jnp.zeros((1, PROMPT_LEN), jnp.int32)}).params

    before = ops.registry.dispatch_snapshot()
    sched = DecodeScheduler(m, qparams, n_slots=2, cache_len=CACHE_LEN)
    spec = GenerateSpec(prompt=_prompt(cfg, 5), n_new=4)
    got = sched.generate(spec).tokens
    want = reference_generate(m, fparams, spec.prompt, n_new=4,
                              cache_len=CACHE_LEN)
    assert got == want
    after = ops.registry.dispatch_snapshot()
    assert after.get(("quant_matmul", mode), 0) > \
        before.get(("quant_matmul", mode), 0)


@pytest.mark.slow
def test_quant_generation_token_identical_ref_mode(tmp_path, monkeypatch):
    """Same identity through the pure-jnp ref dispatch (the CPU hot
    path serving actually takes)."""
    monkeypatch.setenv("REPRO_PALLAS", "ref")
    cfg, m, store = _deploy_int8(tmp_path, "smollm-360m")
    qparams = _quant_load(m, "smollm-360m", store)
    fparams = ColdStartEngine(m, "smollm-360m", store).load(
        {"tokens": jnp.zeros((1, PROMPT_LEN), jnp.int32)}).params
    sched = DecodeScheduler(m, qparams, n_slots=2, cache_len=CACHE_LEN)
    spec = GenerateSpec(prompt=_prompt(cfg, 7), n_new=4)
    assert sched.generate(spec).tokens == reference_generate(
        m, fparams, spec.prompt, n_new=4, cache_len=CACHE_LEN)


# ---------------------------------------------------------------------------
# platform end-to-end: --compute-quant residency under a fixed budget
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_quant_platform_generation_and_double_residency(tmp_path):
    """End-to-end through the platform Router: quantized generation
    matches the reference, and a cache budget sized *between* two int8
    residencies and two f32 residencies keeps BOTH models warm — the
    halved footprint is what buys the second resident model."""
    arch = "smollm-360m"
    cfg, m, store = _deploy_int8(tmp_path, arch)
    # second int8 deploy of the same arch under another name
    deploy_model(store, m, f"{arch}-b", jax.random.key(1), quant="int8")

    # size the budget from the actual quant/f32 residencies
    qbytes = sum(l.nbytes for l in _leaves(_quant_load(m, arch, store)))
    fbytes = sum(l.nbytes for l in _leaves(
        ColdStartEngine(m, arch, store).load(
            {"tokens": jnp.zeros((1, PROMPT_LEN), jnp.int32)}).params))
    budget = int(2.2 * qbytes)
    assert 2 * qbytes <= budget < 2 * fbytes, \
        "smoke arch residencies no longer separate the budget"

    example = {"tokens": jnp.asarray(_prompt(cfg, 99)[None])}
    platform = ServerlessPlatform(
        store, {arch: lambda: (m, example),
                f"{arch}-b": lambda: (m, example)},
        strategy="cicada", keep_alive_s=1e9, max_instances=1,
        gen_slots=2, gen_cache_len=CACHE_LEN,
        cache_budget_bytes=budget, compute_quant=True)
    spec = GenerateSpec(prompt=_prompt(cfg, 11), n_new=4)
    with platform.router(workers=2) as router:
        got_a = router.submit(
            Request(req_id=0, model=arch, gen=spec)).result().tokens
        got_b = router.submit(
            Request(req_id=1, model=f"{arch}-b", gen=spec)).result().tokens
    fparams = ColdStartEngine(m, arch, store).load(
        {"tokens": jnp.zeros((1, PROMPT_LEN), jnp.int32)}).params
    assert list(got_a) == list(reference_generate(
        m, fparams, spec.prompt, n_new=4, cache_len=CACHE_LEN))
    assert len(got_b) == 4

    stats = platform.cache_stats()
    assert stats.evictions == 0, \
        "two int8 models must co-reside under the budget"
    assert stats.bytes_cached <= budget
    for name in (arch, f"{arch}-b"):
        inst = platform.pools[name]._instances[0]
        assert any(isinstance(l, QuantLeaf) for l in _leaves(inst.params))


# ---------------------------------------------------------------------------
# autotuned block overlay plumbing (shapes <-> kernels_micro artifact)
# ---------------------------------------------------------------------------

def test_load_autotuned_roundtrip():
    from repro.configs import shapes

    art = {"autotune": {"quant_matmul": {
        "backend": "cpu", "winner": {"qm_bm": 128, "qm_bk": 512,
                                     "qm_bn": 128}}}}
    try:
        assert shapes.load_autotuned(art, backend="cpu",
                                     profile="tpu") != {}
        kb = shapes.kernel_blocks("tpu")
        assert (kb.qm_bm, kb.qm_bk, kb.qm_bn) == (128, 512, 128)
        # other-backend artifacts must not leak in
        assert shapes.load_autotuned(art, backend="tpu",
                                     profile="tpu") == {}
    finally:
        shapes.clear_autotuned()
