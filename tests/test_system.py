"""End-to-end system behaviour: the launchers drive the full stack."""
import numpy as np
import pytest


def test_train_launcher_end_to_end(tmp_path):
    """train.py: init -> sharded train -> checkpoint -> resume."""
    from repro.launch.train import main
    hist = main(["--arch", "smollm-360m", "--smoke", "--steps", "30",
                 "--seq", "32", "--batch", "4", "--lr", "5e-3",
                 "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "10"])
    assert hist["loss"][-1] < hist["loss"][0]
    # resume picks up from the written checkpoint
    hist2 = main(["--arch", "smollm-360m", "--smoke", "--steps", "10",
                  "--seq", "32", "--batch", "4", "--lr", "5e-3",
                  "--ckpt-dir", str(tmp_path / "ck"), "--resume"])
    assert np.isfinite(hist2["loss"][-1])


def test_train_launcher_with_compression():
    from repro.launch.train import main
    hist = main(["--arch", "smollm-360m", "--smoke", "--steps", "20",
                 "--seq", "32", "--batch", "4", "--lr", "5e-3",
                 "--compress-grads"])
    assert hist["loss"][-1] < hist["loss"][0]


@pytest.mark.slow
def test_serve_launcher_end_to_end(tmp_path):
    """serve.py: deploy -> trace replay -> cold/warm statistics."""
    from repro.launch.serve import main
    responses = main(["--models", "smollm-360m", "--strategy", "cicada",
                      "--invocations", "6", "--duration", "60",
                      "--keep-alive", "1000",
                      "--store", str(tmp_path / "store"),
                      "--bandwidth-mbps", "500"])
    assert len(responses) == 6
    colds = [r for r in responses if r.cold]
    warms = [r for r in responses if not r.cold]
    assert len(colds) >= 1 and len(warms) >= 1
    # warm requests are much faster than cold starts
    assert (np.mean([r.latency_s for r in warms])
            < np.mean([r.latency_s for r in colds]))
