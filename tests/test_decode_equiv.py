"""Prefill + decode == full forward, per family (f32 for exactness).

This is the serving engine's core correctness property: the KV caches
(full + ring-buffered SWA), SSD states, RG-LRU states and conv states
all have to carry exactly the same information as a fresh full-sequence
forward.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import transformer
from repro.models.api import get_config

FAMS = ["yi-9b",               # dense full attention
        "h2o-danube-3-4b",     # dense + SWA ring cache
        "smollm-360m",         # odd head counts
        "mixtral-8x7b",        # MoE (+SWA)
        "arctic-480b",         # MoE + dense residual
        "recurrentgemma-2b",   # hybrid pattern + tail + tied embeddings
        "mamba2-780m"]         # SSM


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              compute_dtype=jnp.float32)
    m = transformer.build(cfg)
    params = m.init(jax.random.key(0))
    r = np.random.default_rng(1)
    B, S, Sp = 2, 24, 16
    toks = jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full, _ = m.forward(params, {"tokens": toks})

    cache = m.init_cache(B, 64)
    lg, cache = m.prefill(params, {"tokens": toks[:, :Sp]}, cache)
    np.testing.assert_allclose(np.asarray(lg[:, -1]),
                               np.asarray(full[:, Sp - 1]),
                               atol=1e-4, rtol=1e-3)
    for t in range(Sp, S):
        lg, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, t]),
                                   atol=1e-4, rtol=1e-3)


def test_swa_ring_cache_wraps():
    """Decode far past the window: ring cache must stay exact."""
    cfg = dataclasses.replace(get_config("h2o-danube-3-4b", smoke=True),
                              compute_dtype=jnp.float32)
    assert cfg.sliding_window == 16
    m = transformer.build(cfg)
    params = m.init(jax.random.key(0))
    r = np.random.default_rng(2)
    B, S = 1, 48                        # 3x the window
    toks = jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full, _ = m.forward(params, {"tokens": toks})
    cache = m.init_cache(B, 64)         # ring: min(64, window=16) slots
    lg, cache = m.prefill(params, {"tokens": toks[:, :8]}, cache)
    for t in range(8, S):
        lg, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, -1]),
                               atol=1e-4, rtol=1e-3)


def test_unrolled_forward_matches_scan():
    """The roofline lowering (unroll=True) is numerically identical."""
    for arch in ["yi-9b", "recurrentgemma-2b", "mamba2-780m"]:
        cfg = dataclasses.replace(get_config(arch, smoke=True),
                                  compute_dtype=jnp.float32)
        m = transformer.build(cfg)
        params = m.init(jax.random.key(0))
        r = np.random.default_rng(3)
        toks = jnp.asarray(r.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
        a, _ = m.forward(params, {"tokens": toks})
        b, _ = m.forward(params, {"tokens": toks}, unroll=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_encoder_has_no_decode_units():
    cfg = get_config("hubert-xlarge", smoke=True)
    assert cfg.is_encoder
    from repro.configs import SHAPES, supported
    ok, reason = supported(cfg, SHAPES["decode_32k"])
    assert not ok and "encoder" in reason
    ok, _ = supported(cfg, SHAPES["prefill_32k"])
    assert ok


def test_long500k_applicability():
    from repro.configs import SHAPES, supported
    cell = SHAPES["long_500k"]
    runs = {a: supported(get_config(a), cell)[0] for a in
            ["yi-9b", "codeqwen1.5-7b", "smollm-360m", "internvl2-76b",
             "arctic-480b", "hubert-xlarge",
             "h2o-danube-3-4b", "mixtral-8x7b", "recurrentgemma-2b",
             "mamba2-780m"]}
    assert not any(runs[a] for a in ["yi-9b", "codeqwen1.5-7b",
                                     "smollm-360m", "internvl2-76b",
                                     "arctic-480b", "hubert-xlarge"])
    assert all(runs[a] for a in ["h2o-danube-3-4b", "mixtral-8x7b",
                                 "recurrentgemma-2b", "mamba2-780m"])
