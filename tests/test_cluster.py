"""Cluster-scale serving (repro.cluster): placement-table leader
election (cluster-wide single-flight), peer-to-peer shard exchange with
stale-referral fallback, cache-eviction -> placement-withdrawal wiring,
locality-aware front-end routing, and the storm test the instrumented
lock probe (REPRO_ANALYZE=1) runs in CI's analysis job."""
import threading
import time

import pytest

from repro.cluster import (ORIGIN, PEER, ClusterPlatform,
                           ClusterShardSource, PlacementTable)
from repro.serving.api import Request, UnknownModelError
from repro.store.cache import LOAD, WeightCache
from repro.store.store import WeightStore


# ---------------------------------------------------------------------------
# WeightCache: on-evict callback + try_get (the placement wiring's base)
# ---------------------------------------------------------------------------

def _put(c, model, unit, nbytes, shard=0):
    status, _ = c.begin(model, unit, shard)
    assert status == LOAD
    c.complete(model, unit, {unit: nbytes}, nbytes, shard)
    c.release(model, unit, shard)


def test_on_evict_callback_reports_every_dropped_key():
    evicted = []
    c = WeightCache(budget_bytes=250, on_evict=evicted.append)
    _put(c, "m", "u0", 100)
    _put(c, "m", "u1", 100)
    _put(c, "m", "u2", 100)          # 300 > 250: u0 is the LRU victim
    assert evicted == [("m", "u0", 0)]
    c.clear()                        # remaining entries dropped too
    assert sorted(evicted) == [("m", "u0", 0), ("m", "u1", 0),
                               ("m", "u2", 0)]


def test_on_evict_callback_may_reenter_the_cache():
    """Callbacks run outside the cache lock: a callback that calls back
    into the cache (as the placement wiring's metrics do) must not
    deadlock."""
    seen = []

    def cb(key):
        seen.append((key, c.stats().entries))

    c = WeightCache(budget_bytes=100, on_evict=cb)
    _put(c, "m", "a", 80)
    _put(c, "m", "b", 80)            # evicts a; cb re-enters via stats()
    assert seen and seen[0][0] == ("m", "a", 0)


def test_try_get_pins_skips_loading_and_misses():
    c = WeightCache()
    assert c.try_get("m", "absent") is None
    st, _ = c.begin("m", "loading")
    assert st == LOAD
    assert c.try_get("m", "loading") is None    # in-flight: not servable
    c.complete("m", "loading", {"w": 1}, 10)
    c.release("m", "loading")
    got = c.try_get("m", "loading")
    assert got == {"w": 1}
    # the peek took a reference: the entry survives budget pressure
    # until released
    c2 = WeightCache(budget_bytes=10, on_evict=lambda k: None)
    _put(c2, "m", "u", 10)
    assert c2.try_get("m", "u") is not None     # pinned now
    _put(c2, "m", "v", 10)                      # pressure
    assert ("m", "u") in c2
    c2.release("m", "u")
    _put(c2, "m", "w", 10)                      # unpinned -> evictable
    assert ("m", "u") not in c2


# ---------------------------------------------------------------------------
# PlacementTable: cluster-wide single-flight
# ---------------------------------------------------------------------------

def test_placement_leader_election_then_peer_referrals():
    t = PlacementTable()
    mode, peer = t.begin_fetch("A", "m", "u")
    assert (mode, peer) == (ORIGIN, None)
    t.publish("A", "m", "u")
    mode, peer = t.begin_fetch("B", "m", "u")
    assert (mode, peer) == (PEER, "A")
    t.publish("B", "m", "u")
    assert sorted(t.locate("m", "u")) == ["A", "B"]
    # a holder is never referred to itself when another holder exists
    assert t.begin_fetch("A", "m", "u")[1] == "B"
    t.drop("A", "m", "u")
    t.drop("B", "m", "u")
    assert t.locate("m", "u") == []
    # last holder gone: next asker is elected leader again
    assert t.begin_fetch("C", "m", "u")[0] == ORIGIN


def test_placement_waiters_blocked_then_redirected_to_peer():
    t = PlacementTable()
    assert t.begin_fetch("A", "m", "u")[0] == ORIGIN
    results = []

    def waiter(node):
        results.append((node, t.begin_fetch(node, "m", "u")))

    threads = [threading.Thread(target=waiter, args=(n,))
               for n in ("B", "C", "D")]
    for th in threads:
        th.start()
    time.sleep(0.05)
    assert results == []                 # all blocked on the leader
    t.publish("A", "m", "u")
    for th in threads:
        th.join(timeout=5)
    assert len(results) == 3
    assert all(r == (PEER, "A") for _, r in results)
    snap = t.snapshot()
    assert snap["origin_elections"] == 1
    assert snap["peer_referrals"] == 3


def test_placement_abort_reelects_a_waiter():
    t = PlacementTable()
    assert t.begin_fetch("A", "m", "u")[0] == ORIGIN
    got = []
    th = threading.Thread(
        target=lambda: got.append(t.begin_fetch("B", "m", "u")))
    th.start()
    time.sleep(0.05)
    t.abort("A", "m", "u")               # leader's origin read failed
    th.join(timeout=5)
    assert got == [(ORIGIN, None)]       # waiter re-elected leader


# ---------------------------------------------------------------------------
# ClusterShardSource over fake peers (no jax)
# ---------------------------------------------------------------------------

class FakePeer:
    """Node.serve_shard/end_serve contract over a plain dict."""

    def __init__(self, node_id):
        self.node_id = node_id
        self.payloads = {}
        self.serving = 0

    def serve_shard(self, model, unit, skey=0):
        p = self.payloads.get((model, unit, skey))
        if p is not None:
            self.serving += 1
        return p

    def end_serve(self, model, unit, skey=0):
        self.serving -= 1


def _mk_cluster_sources(n):
    table = PlacementTable()
    peers = {f"n{i}": FakePeer(f"n{i}") for i in range(n)}
    sources = {nid: ClusterShardSource(nid, table, None, peers.get)
               for nid in peers}
    return table, peers, sources


def test_nway_burst_does_one_origin_read_per_key():
    """The acceptance invariant, isolated: N nodes fetch the same shard
    concurrently; exactly one origin read happens, everyone else is
    served by a peer."""
    n = 6
    table, peers, sources = _mk_cluster_sources(n)
    origin_reads = []
    srcs = {}
    barrier = threading.Barrier(n)

    def fetch(nid):
        def read_origin():
            origin_reads.append(nid)
            time.sleep(0.02)             # a slow origin: waiters pile up
            return {"w": nid}

        barrier.wait()
        payload, src = sources[nid].fetch("m", "u", 0, 100, read_origin)
        if src == "origin":
            peers[nid].payloads[("m", "u", 0)] = payload
        sources[nid].publish("m", "u", 0)
        srcs[nid] = src

    threads = [threading.Thread(target=fetch, args=(nid,))
               for nid in sources]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=10)
    assert len(origin_reads) == 1
    assert sorted(srcs.values()) == ["origin"] + ["peer"] * (n - 1)
    assert all(p.serving == 0 for p in peers.values())   # pins released
    assert len(table.locate("m", "u")) == n              # all published


def test_stale_referral_falls_back_to_origin():
    """The peer evicted between publish and our fetch: serve_shard
    returns None, the dead holder is dropped, and the asker degrades to
    an origin read."""
    table, peers, sources = _mk_cluster_sources(2)
    table.publish("n0", "m", "u")        # n0 claims to hold the key...
    assert ("m", "u", 0) not in peers["n0"].payloads   # ...but evicted
    payload, src = sources["n1"].fetch("m", "u", 0, 100,
                                       lambda: {"w": "origin"})
    assert src == "origin" and payload == {"w": "origin"}
    assert table.locate("m", "u") == []  # stale holder repaired away
    from repro import metrics as metrics_mod
    assert metrics_mod.resolve(None).counter(
        "cluster/stale_referrals").value >= 1


def test_unknown_peer_id_is_treated_as_stale():
    table, _, sources = _mk_cluster_sources(1)
    table.publish("ghost", "m", "u")     # a node that can't be resolved
    payload, src = sources["n0"].fetch("m", "u", 0, 100, lambda: "o")
    assert src == "origin" and payload == "o"


# ---------------------------------------------------------------------------
# storm: placement + peer tier + caches under thread pressure.  Runs in
# CI's analysis job under REPRO_ANALYZE=1 (instrumented locks) — the
# merged static+observed lock graph must stay cycle-free.
# ---------------------------------------------------------------------------

def test_cluster_storm_under_contention():
    n_nodes, n_keys, n_rounds = 3, 4, 6
    table = PlacementTable()

    class StormNode:
        def __init__(self, nid):
            self.node_id = nid
            self.cache = WeightCache(on_evict=self._on_evict)

        def _on_evict(self, key):
            table.drop(self.node_id, *key)

        def serve_shard(self, model, unit, skey=0):
            return self.cache.try_get(model, unit, skey)

        def end_serve(self, model, unit, skey=0):
            self.cache.release(model, unit, skey)

    nodes = {f"n{i}": StormNode(f"n{i}") for i in range(n_nodes)}
    sources = {nid: ClusterShardSource(nid, table, None, nodes.get)
               for nid in nodes}
    origin_reads = []
    origin_lock = threading.Lock()
    errors = []

    def worker(nid):
        node, source = nodes[nid], sources[nid]
        try:
            for r in range(n_rounds):
                for k in range(n_keys):
                    unit = f"u{k}"
                    st, leaves = node.cache.begin("m", unit)
                    if st != LOAD:
                        node.cache.release("m", unit)
                        continue

                    def read_origin(u=unit):
                        with origin_lock:
                            origin_reads.append((u, nid))
                        return {"w": u}

                    try:
                        payload, src = source.fetch("m", unit, 0, 64,
                                                    read_origin)
                        node.cache.complete("m", unit, payload, 64)
                        source.publish("m", unit, 0)
                    except BaseException:
                        node.cache.abort("m", unit)
                        source.abort("m", unit, 0)
                        raise
                    node.cache.release("m", unit)
                # eviction pressure: drop everything and re-fetch
                node.cache.clear()
        except BaseException as e:      # surface failures to the test
            errors.append((nid, e))

    threads = [threading.Thread(target=worker, args=(nid,))
               for nid in nodes]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=60)
    assert not errors, errors
    assert not any(th.is_alive() for th in threads)
    # liveness held and the table is consistent: every key's recorded
    # holders actually hold it (nothing points at evicted bytes)
    for key, holders in [((f"u{k}"), table.locate("m", f"u{k}"))
                         for k in range(n_keys)]:
        for h in holders:
            assert nodes[h].cache.try_get("m", key) is not None
            nodes[h].cache.release("m", key)


# ---------------------------------------------------------------------------
# ClusterPlatform wiring that needs no model (empty builders)
# ---------------------------------------------------------------------------

def _empty_cluster(tmp_path, n=2, **kw):
    return ClusterPlatform(WeightStore(str(tmp_path)), {}, n_nodes=n,
                           cluster_bw_mbps=0.0, **kw)


def test_router_places_by_load_when_no_locality(tmp_path):
    cp = _empty_cluster(tmp_path, n=3)
    router = cp.router()
    try:
        assert router.place("m").node_id == "node0"   # tie -> index
        cp.node("node0").metrics.gauge("router/in_flight").add(5)
        cp.node("node1").metrics.gauge("router/in_flight").add(2)
        assert router.place("m").node_id == "node2"   # least loaded
    finally:
        router.shutdown()


def test_router_prefers_cache_resident_node(tmp_path):
    cp = _empty_cluster(tmp_path, n=2)
    cp.placement.publish("node1", "m", "u0")
    cp.placement.publish("node1", "m", "u1")
    router = cp.router()
    try:
        assert router.place("m").node_id == "node1"
        # load never outranks locality in the score tuple
        cp.node("node1").metrics.gauge("router/in_flight").add(50)
        assert router.place("m").node_id == "node1"
    finally:
        router.shutdown()


def test_submit_unknown_model_raises_on_submitting_thread(tmp_path):
    cp = _empty_cluster(tmp_path)
    router = cp.router()
    try:
        with pytest.raises(UnknownModelError):
            router.submit(Request(req_id=0, model="nope", batch={}))
    finally:
        router.shutdown()


def test_node_eviction_withdraws_placement_entry(tmp_path):
    """The satellite fix, end to end at the node layer: a cache
    eviction on a node immediately drops its placement-table entry."""
    cp = _empty_cluster(tmp_path, n=2, cache_budget_bytes=150)
    node = cp.node("node0")
    st, _ = node.cache.begin("m", "u0")
    assert st == LOAD
    node.cache.complete("m", "u0", {"w": 0}, 100)
    node.source.publish("m", "u0", 0)
    node.cache.release("m", "u0")
    assert cp.placement.locate("m", "u0") == ["node0"]
    st, _ = node.cache.begin("m", "u1")   # 200 > 150: u0 evicted
    node.cache.complete("m", "u1", {"w": 1}, 100)
    node.cache.release("m", "u1")
    assert cp.placement.locate("m", "u0") == []
    # a peer fetch for u0 now elects a fresh leader instead of a
    # referral to the evicted copy
    assert cp.placement.begin_fetch("node1", "m", "u0")[0] == ORIGIN


def test_cluster_snapshot_aggregates_per_node_surfaces(tmp_path):
    cp = _empty_cluster(tmp_path, n=2)
    cp.node("node0").metrics.counter("cluster/origin_reads").inc(3)
    cp.node("node1").metrics.counter("cluster/peer_reads").inc(2)
    cp.node("node1").metrics.gauge("router/queue_depth").set(4)
    cp.placement.publish("node0", "m", "u0")
    snap = cp.cluster_snapshot()
    assert snap["n_nodes"] == 2
    assert set(snap["nodes"]) == {"node0", "node1"}
    agg = snap["cluster"]["counters"]
    assert agg["cluster/origin_reads"] == 3.0
    assert agg["cluster/peer_reads"] == 2.0
    assert snap["cluster"]["load"] == {"node0": 0.0, "node1": 4.0}
    assert snap["placement"]["models"] == {"m": {"keys": 1, "copies": 1}}
    # each node's entry is the full PR-7 surface, not a digest
    assert "counters" in snap["nodes"]["node0"]
    assert "gauges" in snap["nodes"]["node0"]


# ---------------------------------------------------------------------------
# end-to-end with a real model (slow job)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def deployed(tmp_path_factory):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import transformer
    from repro.models.api import get_config
    from repro.store.store import deploy_model

    d = tmp_path_factory.mktemp("store")
    cfg = get_config("smollm-360m", smoke=True)
    m = transformer.build(cfg)
    store = WeightStore(str(d))
    deploy_model(store, m, "smollm-360m", jax.random.key(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 8)),
        jnp.int32)}
    return store, m, cfg, batch


def _cluster(deployed, n=2, **kw):
    store, m, cfg, batch = deployed
    kw.setdefault("keep_alive_s", 1e9)
    return ClusterPlatform(store, {"smollm-360m": (lambda: (m, batch))},
                           n_nodes=n, cluster_bw_mbps=2000.0, **kw), batch


def _req(i, batch):
    return Request(req_id=i, model="smollm-360m", batch=batch)


@pytest.mark.slow
def test_second_node_cold_start_is_peer_served(deployed):
    """The headline acceptance: node1 cold-starts a model node0 already
    landed — every shard streams from node0, zero origin reads."""
    cp, batch = _cluster(deployed, n=2)
    router = cp.router(workers_per_node=2)
    try:
        r0 = router.submit_to("node0", _req(0, batch)).result(timeout=120)
        r1 = router.submit_to("node1", _req(1, batch)).result(timeout=120)
    finally:
        router.shutdown()
    assert r0.cold and r0.node == "node0"
    assert r1.cold and r1.node == "node1"
    n0, n1 = cp.node("node0"), cp.node("node1")
    assert n0.origin_reads() > 0 and n0.peer_reads() == 0
    assert n1.origin_reads() == 0 and n1.peer_reads() > 0
    # both caches now hold every unit; the table knows both copies
    pl = cp.placement.snapshot()["models"]["smollm-360m"]
    assert pl["copies"] == 2 * pl["keys"]


@pytest.mark.slow
def test_locality_routing_hits_warm_node(deployed):
    cp, batch = _cluster(deployed, n=2)
    router = cp.router(workers_per_node=2)
    try:
        r0 = router.submit_to("node1", _req(0, batch)).result(timeout=120)
        # unpinned submissions follow the warm instance
        rs = [router.submit(_req(i, batch)).result(timeout=60)
              for i in range(1, 4)]
    finally:
        router.shutdown()
    assert r0.cold
    assert all(r.node == "node1" and not r.cold for r in rs)


@pytest.mark.slow
def test_concurrent_cold_burst_one_origin_read_per_shard(deployed):
    """All nodes cold-start the same model simultaneously: placement
    consistency under concurrent fetches, and at most one origin read
    per (model, unit, shard) cluster-wide."""
    cp, batch = _cluster(deployed, n=4)
    router = cp.router(workers_per_node=2)
    try:
        futs = [router.submit_to(nd.node_id, _req(i, batch))
                for i, nd in enumerate(cp.nodes)]
        rs = [f.result(timeout=180) for f in futs]
    finally:
        router.shutdown()
    assert all(r.cold for r in rs)
    pl = cp.placement.snapshot()["models"]["smollm-360m"]
    n_keys = pl["keys"]
    assert n_keys > 0 and pl["copies"] == 4 * n_keys
    total_origin = sum(nd.origin_reads() for nd in cp.nodes)
    assert total_origin == n_keys          # exactly one per shard
    assert sum(nd.peer_reads() for nd in cp.nodes) == 3 * n_keys


@pytest.mark.slow
def test_run_trace_through_the_cluster_front_end(deployed):
    from repro.serving.trace import Invocation

    cp, batch = _cluster(deployed, n=2, keep_alive_s=120.0)
    trace = [Invocation(float(i), "smollm-360m", i) for i in range(4)]
    rs = cp.run_trace(trace, lambda name: batch)
    assert [r.req_id for r in rs] == [0, 1, 2, 3]
    assert rs[0].cold and not rs[1].cold
    assert all(r.node in ("node0", "node1") for r in rs)
    snap = cp.cluster_snapshot()
    assert snap["cluster"]["counters"]["router/completed"] == 4.0
