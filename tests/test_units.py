"""PipelineState / PipelineUnit runtime: event-driven signaling (no
fixed-interval polling), deadline wake-ups, error propagation."""
import threading
import time

import pytest

from repro.core.units import (APPLIED, CONSTRUCTED, PipelineRuntime,
                              PipelineState, PipelineUnit)


def test_publish_wakes_waiter_promptly():
    state = PipelineState()
    got = {}

    def waiter():
        got["value"] = state.wait_for(CONSTRUCTED, "u0")

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    t0 = time.monotonic()
    state.publish(CONSTRUCTED, "u0", 42)
    t.join(timeout=2.0)
    assert not t.is_alive()
    assert got["value"] == 42
    # woken by notification (scheduling slack only, no polling grid)
    assert time.monotonic() - t0 < 0.25


def test_wait_until_predicate_over_multiple_stages():
    state = PipelineState()
    out = {}

    def waiter():
        out["u"] = state.wait_until(
            lambda: "u1" if ("u1" in state._slots.get(CONSTRUCTED, {})
                             and "u1" in state._slots.get(APPLIED, {}))
            else None)

    t = threading.Thread(target=waiter)
    t.start()
    state.publish(CONSTRUCTED, "u1", object())
    time.sleep(0.02)
    assert t.is_alive()                    # only one of two conditions
    state.publish(APPLIED, "u1", object())
    t.join(timeout=2.0)
    assert out["u"] == "u1"


def test_deadline_callback_fires_once_then_sleeps():
    state = PipelineState()
    fired = []
    deadline_at = time.monotonic() + 0.03

    def deadline_fn():
        if fired:
            return None                    # after firing: no deadline
        return deadline_at - time.monotonic()

    def waiter():
        state.wait_until(
            lambda: state._slots.get(APPLIED, {}).get("u"),
            deadline_fn=deadline_fn,
            on_deadline=lambda: fired.append(time.monotonic()))

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.3)
    assert len(fired) == 1                 # exactly one deadline wake
    assert fired[0] >= deadline_at - 1e-3  # never early
    state.publish(APPLIED, "u", 1)
    t.join(timeout=2.0)
    assert not t.is_alive()


def test_error_propagates_to_waiters_and_runtime():
    state = PipelineState()

    class Boom(PipelineUnit):
        name = "boom"

        def run(self):
            raise RuntimeError("unit exploded")

    class Blocked(PipelineUnit):
        name = "blocked"

        def run(self):
            self.ctx.state.wait_for(APPLIED, "never")

    class Ctx:                             # minimal context for the test
        pass

    ctx = Ctx()
    ctx.state = state
    rt = PipelineRuntime([Boom(ctx), Blocked(ctx)], state)
    with pytest.raises(RuntimeError, match="unit exploded"):
        rt.run()                           # blocked unit must not hang


def test_shared_cv_wakes_across_components():
    """A producer signaling through the shared CV (the decoupler's I/O
    pool pattern) wakes a state waiter without any state.publish."""
    state = PipelineState()
    ready = {}

    def producer():
        time.sleep(0.03)
        with state.cv:
            ready["u"] = 7
            state.cv.notify_all()

    threading.Thread(target=producer).start()
    val = state.wait_until(lambda: ready.get("u"))
    assert val == 7
