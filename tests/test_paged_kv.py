"""Paged KV cache: KVPagePool allocator semantics (refcounts, prefix
index, cached LRU, copy-on-write, blocking backpressure), paged
DecodeScheduler token-equivalence against ``reference_generate``,
page-budget admission of mixed prompt lengths that overflow the slotted
arena, and physical page sharing across requests with a common prompt
prefix.

The pool storm test exercises the allocator from many threads with
``check_invariants`` between operations — CI runs this file under
``REPRO_ANALYZE=1`` so the lock/condition discipline is probed too.
"""
import dataclasses
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.metrics import MetricsRegistry
from repro.models import transformer
from repro.models.api import get_config
from repro.serving import (CacheOverflowError, DecodeScheduler,
                           GenerateSpec, reference_generate)
from repro.serving.decode import paged_page_count, validate_spec_paged
from repro.serving.kvpages import KVPagePool, page_hashes

CACHE_LEN = 64
PT = 16                                    # page tokens for scheduler tests

GEN_ARCHS = ["smollm-360m", "mixtral-8x7b", "recurrentgemma-2b"]


def _f32_cfg(arch, **over):
    return dataclasses.replace(get_config(arch, smoke=True),
                               compute_dtype=jnp.float32, **over)


def _prompt(cfg, seed, n=8):
    r = np.random.default_rng(seed)
    return r.integers(0, cfg.vocab_size, (n,)).astype(np.int32)


@pytest.fixture(scope="module")
def dense():
    cfg = _f32_cfg("smollm-360m")
    m = transformer.build(cfg)
    return cfg, m, m.init(jax.random.key(0))


# ---------------------------------------------------------------------------
# page_hashes
# ---------------------------------------------------------------------------

def test_page_hashes_running_and_partial():
    toks = np.arange(40, dtype=np.int32)
    hs = page_hashes("m", toks, 16)
    assert len(hs) == 2                    # trailing partial page unhashed
    # running: page 1's digest commits to page 0 too
    assert page_hashes("m", toks[:32], 16) == hs
    other = toks.copy()
    other[0] = 999
    assert page_hashes("m", other, 16)[1] != hs[1]
    # model identity prefixes the hash
    assert page_hashes("other", toks, 16) != hs


# ---------------------------------------------------------------------------
# KVPagePool unit semantics
# ---------------------------------------------------------------------------

def test_pool_alloc_release_refcount():
    pool = KVPagePool(n_pages=4, page_tokens=8)
    ids = pool.alloc(3)
    assert len(ids) == 3 and len(set(ids)) == 3
    st = pool.stats()
    assert (st.pinned, st.free) == (3, 1)
    pool.release(ids[:1])
    assert pool.stats().free == 2          # unregistered page -> free list
    pool.release(ids[1:])
    st = pool.stats()
    assert (st.pinned, st.free, st.used) == (0, 4, 0)
    pool.check_invariants()


def test_pool_never_fits_is_error_smaller_is_backpressure():
    pool = KVPagePool(n_pages=2, page_tokens=8)
    with pytest.raises(CacheOverflowError):
        pool.alloc(3)                      # can never fit: typed error
    held = pool.alloc(2)
    with pytest.raises(TimeoutError):      # fits, pool busy: backpressure
        pool.alloc(1, timeout=0.05)
    got = []
    t = threading.Thread(target=lambda: got.extend(pool.alloc(2)))
    t.start()
    time.sleep(0.05)
    assert not got                         # still blocked
    pool.release(held)
    t.join(timeout=5)
    assert len(got) == 2
    pool.check_invariants()


def test_pool_prefix_register_match_and_lru():
    pool = KVPagePool(n_pages=3, page_tokens=4)
    hs = page_hashes("m", np.arange(8, dtype=np.int32), 4)
    ids = pool.alloc(2)
    for pid, h in zip(ids, hs):
        pool.register(pid, h)
    pool.release(ids)                      # registered -> cached, not free
    st = pool.stats()
    assert (st.cached, st.free) == (2, 1)
    hit = pool.match_prefix(hs)
    assert hit == ids                      # revived in order, pinned
    assert pool.stats().prefix_hits == 2
    # a miss stops the walk and counts once
    assert pool.match_prefix(["nope"]) == []
    assert pool.stats().prefix_misses == 1
    pool.release(hit)
    # pressure evicts cached LRU pages and invalidates their hashes
    big = pool.alloc(3)
    assert pool.match_prefix(hs) == []
    pool.release(big)
    pool.check_invariants()


def test_pool_copy_on_write_fork():
    pool = KVPagePool(n_pages=2, page_tokens=4)
    (pid,) = pool.alloc(1)
    assert pool.ensure_writable(pid) == (pid, False)   # sole holder
    pool.register(pid, "h0")
    pool.release(pid_list := [pid])
    hit = pool.match_prefix(["h0"])        # now shared: us + index
    hit2 = pool.match_prefix(["h0"])       # refcount 2
    new, copied = pool.ensure_writable(pid)
    assert copied and new != pid
    assert pool.stats().cow_copies == 1
    pool.release([new] + hit2)
    pool.release(hit)
    pool.check_invariants()
    del pid_list


def test_pool_storm_invariants():
    """Many threads alloc/release/register/match concurrently; the
    page partition invariant holds throughout (run under
    REPRO_ANALYZE=1 in CI to probe the locking too)."""
    pool = KVPagePool(n_pages=16, page_tokens=4)
    toks = np.arange(64, dtype=np.int32)
    hs = page_hashes("m", toks, 4)
    stop = threading.Event()
    errors = []

    def worker(seed):
        r = np.random.default_rng(seed)
        try:
            while not stop.is_set():
                n = int(r.integers(1, 4))
                try:
                    ids = pool.alloc(n, timeout=0.2)
                except TimeoutError:
                    continue
                if r.random() < 0.5:
                    for j, pid in enumerate(ids):
                        pool.register(pid, hs[int(r.integers(len(hs)))])
                hit = pool.match_prefix(hs[:int(r.integers(1, 4))])
                pool.check_invariants()
                pool.release(hit)
                pool.release(ids)
                pool.check_invariants()
        except BaseException as e:          # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    time.sleep(1.0)
    stop.set()
    for t in threads:
        t.join(timeout=10)
    assert not errors, errors
    pool.check_invariants()


# ---------------------------------------------------------------------------
# validate_spec_paged
# ---------------------------------------------------------------------------

def test_validate_spec_paged_message_and_typing():
    spec = GenerateSpec(prompt=[1, 2, 3], n_new=100)
    with pytest.raises(CacheOverflowError) as ei:
        validate_spec_paged(spec, 3, page_tokens=8, n_pages=4)
    msg = str(ei.value)
    assert "4 pages x 8 tokens" in msg and "32 tokens" in msg
    pool = KVPagePool(n_pages=4, page_tokens=8)
    held = pool.alloc(3)
    with pytest.raises(CacheOverflowError) as ei:
        validate_spec_paged(spec, 3, page_tokens=8, n_pages=4,
                            stats=pool.stats())
    assert "live occupancy 3/4 pages" in str(ei.value)
    pool.release(held)
    # fitting requests never raise here, whatever the live occupancy
    assert validate_spec_paged(GenerateSpec(prompt=[1], n_new=8), 1,
                               page_tokens=8, n_pages=4) == 8


def test_paged_page_count_budget(dense):
    _, m, _ = dense
    per = m.kv_page_bytes(PT)
    assert per > 0
    assert paged_page_count(m, page_tokens=PT,
                            budget_bytes=10 * per + 3) == 10
    # no byte budget -> the slotted arena's worth of pages
    assert paged_page_count(m, page_tokens=PT, n_slots=4,
                            cache_len=CACHE_LEN) == 4 * (CACHE_LEN // PT)
    with pytest.raises(ValueError):
        paged_page_count(m, page_tokens=PT, budget_bytes=per - 1)


# ---------------------------------------------------------------------------
# scheduler equivalence: paged == reference, per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", GEN_ARCHS)
def test_paged_scheduler_bit_identical(arch):
    """Concurrent mixed-length paged generation matches the serial
    reference token-for-token (dense fully paged; MoE/hybrid smoke
    configs keep ring/SSM states slot-resident — the paged scheduler
    must preserve their semantics unchanged)."""
    cfg = _f32_cfg(arch)
    m = transformer.build(cfg)
    params = m.init(jax.random.key(0))
    sched = DecodeScheduler(m, params, n_slots=3, cache_len=CACHE_LEN,
                            kv_page_tokens=PT, kv_max_seq=CACHE_LEN)
    lens = (5, 8, 19)
    prompts = [_prompt(cfg, i, n=lens[i]) for i in range(3)]
    results = [None] * 3

    def run(i):
        results[i] = sched.generate(
            GenerateSpec(prompt=prompts[i], n_new=7, seed=i,
                         temperature=0.5 if i == 2 else 0.0))

    threads = [threading.Thread(target=run, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i in range(3):
        ref = reference_generate(m, params, prompts[i], n_new=7,
                                 cache_len=CACHE_LEN, seed=i,
                                 temperature=0.5 if i == 2 else 0.0)
        assert results[i].tokens == ref, (arch, i)
    st = sched.kvpool.stats()
    assert st.pinned == 0, st              # every page released on leave
    sched.kvpool.check_invariants()


def test_paged_admits_mixed_lengths_beyond_slotted_ceiling(dense):
    """N requests whose prompts overflow the slotted per-slot arena all
    admit and complete under the *same* byte budget paged."""
    cfg, m, params = dense
    n_slots, cache_len = 2, 32
    slotted = DecodeScheduler(m, params, n_slots=n_slots,
                              cache_len=cache_len)
    long_prompt = _prompt(cfg, 42, n=40)   # 40 + 8 > 32: slotted rejects
    with pytest.raises(CacheOverflowError):
        slotted.generate(GenerateSpec(prompt=long_prompt, n_new=8))
    # same budget, paged: n_slots * cache_len = 64 tokens = 8 x 8-token
    # pages shared across residents instead of 32 per slot
    paged = DecodeScheduler(m, params, n_slots=n_slots,
                            cache_len=cache_len, kv_page_tokens=8,
                            kv_max_seq=2 * cache_len,
                            kv_budget_bytes=n_slots * cache_len // 8
                            * m.kv_page_bytes(8))
    assert paged.n_pages == 8
    prompts = [long_prompt, _prompt(cfg, 43, n=9)]
    results = [None] * 2

    def run(i):
        results[i] = paged.generate(
            GenerateSpec(prompt=prompts[i], n_new=8, seed=i))

    threads = [threading.Thread(target=run, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i in range(2):
        ref = reference_generate(m, params, prompts[i], n_new=8,
                                 cache_len=64, seed=i)
        assert results[i].tokens == ref, i
    paged.kvpool.check_invariants()


def test_paged_moe_full_attention_prefix():
    """A full-attention MoE variant pages its KV and shares prefixes."""
    cfg = _f32_cfg("mixtral-8x7b", sliding_window=0)
    m = transformer.build(cfg)
    assert m.supports_prefix_cache
    params = m.init(jax.random.key(0))
    sched = DecodeScheduler(m, params, n_slots=2, cache_len=CACHE_LEN,
                            kv_page_tokens=PT, kv_max_seq=CACHE_LEN)
    shared = _prompt(cfg, 7, n=2 * PT)
    pa = np.concatenate([shared, _prompt(cfg, 8, n=5)])
    ra = sched.generate(GenerateSpec(prompt=pa, n_new=5, seed=1))
    pb = np.concatenate([shared, _prompt(cfg, 9, n=3)])
    rb = sched.generate(GenerateSpec(prompt=pb, n_new=5, seed=2))
    assert sched.kvpool.stats().prefix_hits == 2
    assert ra.tokens == reference_generate(m, params, pa, n_new=5,
                                           cache_len=CACHE_LEN, seed=1)
    assert rb.tokens == reference_generate(m, params, pb, n_new=5,
                                           cache_len=CACHE_LEN, seed=2)


# ---------------------------------------------------------------------------
# physical prefix sharing
# ---------------------------------------------------------------------------

def test_shared_prefix_pins_same_physical_pages(dense):
    """Requests with a common system prompt reuse its pages: the pool's
    used-page count grows by the unshared suffix only, and the sharing
    request's tokens are bit-identical to its serial reference."""
    cfg, m, params = dense
    sched = DecodeScheduler(m, params, n_slots=2, cache_len=CACHE_LEN,
                            kv_page_tokens=PT, kv_max_seq=CACHE_LEN)
    shared = _prompt(cfg, 100, n=2 * PT)               # 2 full pages
    pa = np.concatenate([shared, _prompt(cfg, 101, n=6)])
    ra = sched.generate(GenerateSpec(prompt=pa, n_new=6, seed=3))
    st_a = sched.kvpool.stats()
    assert st_a.prefix_hits == 0
    pb = np.concatenate([shared, _prompt(cfg, 102, n=4)])
    rb = sched.generate(GenerateSpec(prompt=pb, n_new=6, seed=4))
    st_b = sched.kvpool.stats()
    # B needed ceil((36+6)/16) = 3 pages but pinned only 1 new one: the
    # pool's live page count is below the sum of per-request needs
    assert st_b.prefix_hits == 2
    assert st_b.used - st_a.used <= 1
    assert ra.tokens == reference_generate(m, params, pa, n_new=6,
                                           cache_len=CACHE_LEN, seed=3)
    assert rb.tokens == reference_generate(m, params, pb, n_new=6,
                                           cache_len=CACHE_LEN, seed=4)
    sched.kvpool.check_invariants()


def test_identical_prompt_full_hit_still_generates(dense):
    """The hit cap always leaves a non-empty prefill suffix — an exactly
    page-aligned identical prompt must not degenerate to a zero-token
    prefill."""
    cfg, m, params = dense
    sched = DecodeScheduler(m, params, n_slots=2, cache_len=CACHE_LEN,
                            kv_page_tokens=PT, kv_max_seq=CACHE_LEN)
    p = _prompt(cfg, 55, n=2 * PT)                     # page-aligned
    ref = reference_generate(m, params, p, n_new=5, cache_len=CACHE_LEN,
                             seed=9)
    for _ in range(2):                                 # cold, then warm hit
        r = sched.generate(GenerateSpec(prompt=p, n_new=5, seed=9))
        assert r.tokens == ref
    assert sched.kvpool.stats().prefix_hits == 1       # capped at S-1 page


# ---------------------------------------------------------------------------
# metrics surface
# ---------------------------------------------------------------------------

def test_kv_metrics_wired(dense):
    cfg, m, params = dense
    reg = MetricsRegistry()
    sched = DecodeScheduler(m, params, n_slots=2, cache_len=CACHE_LEN,
                            kv_page_tokens=PT, kv_max_seq=CACHE_LEN,
                            metrics=reg)
    sched.generate(GenerateSpec(prompt=_prompt(cfg, 1, n=PT + 1), n_new=4))
    snap = reg.snapshot()
    assert snap["gauges"]["kv/pages_total"]["value"] == sched.n_pages
    assert "kv/pages_used" in snap["gauges"]
    assert "kv/pages_pinned" in snap["gauges"]
    assert snap["counters"]["kv/prefix_misses"] >= 1
    st = sched.stats()
    assert st["kv_pages_total"] == sched.n_pages
    assert st["kv_page_tokens"] == PT
