"""Property-based PipelineTrace tests (hypothesis; skipped when the
package is absent — see conftest.collect_ignore)."""
from hypothesis import given, strategies as st

from repro.core.pipeline import PipelineTrace


def _trace(events, t0=0.0, t1=None):
    tr = PipelineTrace()
    tr.t0 = t0
    for stage, layer, a, b in events:
        tr.add_event(stage, layer, a, b)
    tr.t_end = t1 if t1 is not None else max(e[3] for e in events)
    return tr


@given(st.lists(st.tuples(st.floats(0, 100), st.floats(0, 10)),
                min_size=1, max_size=30))
def test_merged_busy_never_exceeds_span(iv):
    events = [("L", f"u{i}", s, s + max(d, 1e-6))
              for i, (s, d) in enumerate(iv)]
    tr = _trace(events, t0=min(e[2] for e in events),
                t1=max(e[3] for e in events))
    assert tr.busy_time() <= tr.total_time() + 1e-9
    assert 0.0 <= tr.utilization() <= 1.0 + 1e-9


@given(st.lists(st.tuples(st.floats(0, 50), st.floats(0.01, 5)),
                min_size=1, max_size=20))
def test_merge_intervals_is_disjoint_and_covers(iv):
    ivs = [(s, s + d) for s, d in iv]
    merged = PipelineTrace.merge_intervals(ivs)
    for (a1, b1), (a2, b2) in zip(merged, merged[1:]):
        assert b1 < a2                      # disjoint, sorted
    # every original interval is inside some merged one
    for s, e in ivs:
        assert any(a <= s and e <= b for a, b in merged)
