"""Chunked prefill (§Perf iteration C1) == unchunked prefill, exactly."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import transformer
from repro.models.api import get_config


@pytest.mark.parametrize("arch", ["yi-9b", "arctic-480b", "internvl2-76b"])
def test_chunked_matches_unchunked(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              compute_dtype=jnp.float32, sliding_window=0)
    m = transformer.LM(cfg)
    params = m.init(jax.random.key(0))
    r = np.random.default_rng(1)
    B, S = 2, 32
    if cfg.family.value == "vlm":
        n_img = 8
        batch = {"tokens": jnp.asarray(
                     r.integers(0, cfg.vocab_size, (B, S - n_img)),
                     jnp.int32),
                 "img": jnp.asarray(
                     r.standard_normal((B, n_img, cfg.frontend_dim)),
                     jnp.float32)}
    else:
        batch = {"tokens": jnp.asarray(
            r.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    lg1, c1 = m.prefill(params, batch, m.init_cache(B, S))
    lg2, c2 = m.prefill_chunked(params, batch, m.init_cache(B, S), chunk=8)
    np.testing.assert_allclose(np.asarray(lg1[:, -1], np.float32),
                               np.asarray(lg2[:, -1], np.float32),
                               atol=2e-4, rtol=1e-3)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=1e-3)


def test_chunked_then_decode():
    """Cache built by chunked prefill supports exact decode."""
    cfg = dataclasses.replace(get_config("yi-9b", smoke=True),
                              compute_dtype=jnp.float32)
    m = transformer.LM(cfg)
    params = m.init(jax.random.key(0))
    r = np.random.default_rng(2)
    B, S, Sp = 2, 24, 16
    toks = jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full, _ = m.forward(params, {"tokens": toks})
    cache = m.init_cache(B, 32)
    lg, cache = m.prefill_chunked(params, {"tokens": toks[:, :Sp]}, cache,
                                  chunk=8)
    for t in range(Sp, S):
        lg, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   atol=1e-4, rtol=1e-3)


def test_chunked_rejects_unsupported():
    cfg = get_config("mamba2-780m", smoke=True)
    m = transformer.LM(cfg)
    with pytest.raises(AssertionError):
        m.prefill_chunked(m.init(jax.random.key(0)),
                          {"tokens": jnp.zeros((1, 16), jnp.int32)},
                          m.init_cache(1, 16), chunk=8)
