import os

# Tests run single-device (the dry-run subprocess sets its own 512).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

# ``hypothesis`` is optional: property-based test modules are skipped at
# collection when it is absent so the rest of the suite still runs.
try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    collect_ignore = [
        "test_distributed.py",
        "test_layers.py",
        "test_moe.py",
        "test_pipeline_props.py",
        "test_store.py",
    ]


# ---------------------------------------------------------------------------
# known-drift quarantine (PR 2): tests/known_drift.txt lists pre-existing
# failures by `<file basename>::<test name>`.  They get the `known_drift`
# marker and — unless REPRO_DRIFT_STRICT=1 (the non-blocking CI job that
# reports their true state) — a non-strict xfail, so the blocking tier-1
# run stays green without deleting the tests.
# ---------------------------------------------------------------------------

def _known_drift_entries():
    path = os.path.join(os.path.dirname(__file__), "known_drift.txt")
    try:
        with open(path) as f:
            return {ln.strip() for ln in f
                    if ln.strip() and not ln.lstrip().startswith("#")}
    except OSError:
        return set()


def pytest_collection_modifyitems(config, items):
    drift = _known_drift_entries()
    strict = os.environ.get("REPRO_DRIFT_STRICT") == "1"
    for item in items:
        base = os.path.basename(item.fspath.strpath) + "::" + \
            item.name.split("[")[0]
        if base in drift:
            item.add_marker(pytest.mark.known_drift)
            if not strict:
                item.add_marker(pytest.mark.xfail(
                    reason="known drift (tests/known_drift.txt)",
                    strict=False))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_batch(cfg, B=2, S=24, seed=1, labels=False):
    """Family-appropriate random inputs for a config."""
    import jax.numpy as jnp
    r = np.random.default_rng(seed)
    fam = cfg.family.value
    if fam == "vision":
        b = {"image": jnp.asarray(
            r.standard_normal((B, 3, cfg.img_res, cfg.img_res)),
            jnp.float32)}
    elif fam == "audio":
        b = {"frames": jnp.asarray(
            r.standard_normal((B, S, cfg.frontend_dim)), jnp.bfloat16)}
    elif fam == "vlm":
        n_img = min(8, S // 2)
        b = {"tokens": jnp.asarray(
                 r.integers(0, cfg.vocab_size, (B, S - n_img)), jnp.int32),
             "img": jnp.asarray(
                 r.standard_normal((B, n_img, cfg.frontend_dim)),
                 jnp.bfloat16)}
    else:
        b = {"tokens": jnp.asarray(
            r.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if labels and fam != "vision":
        b["labels"] = jnp.asarray(
            r.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return b
