"""Shard-granular cold starts: bit-identity vs the single-device path,
per-shard cache reuse, non-divisible-axis fallback, and the mesh=1
degenerate case.

The multi-device tests need a simulated mesh and are skipped unless the
process has >= 4 devices — CI runs them in the dedicated ``tier1-mesh``
job under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m pytest tests/test_sharded_coldstart.py
"""
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ColdStartEngine
from repro.core.shards import plan_unit
from repro.distributed.sharding import ShardingRules, leaf_specs
from repro.launch.mesh import make_serving_mesh
from repro.models import transformer
from repro.models.api import get_config
from repro.store.cache import WeightCache
from repro.store.store import (BandwidthModel, WeightStore, deploy_model,
                               slice_byte_runs)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 simulated devices (tier1-mesh CI job: XLA_FLAGS="
           "--xla_force_host_platform_device_count=4)")

# one per family with distinct sharding behaviour: dense (smollm's
# n_heads=3 exercises the non-divisible fallback), MoE (expert axis),
# hybrid (rglru + attn pattern units)
ARCHS = ["smollm-360m", "mixtral-8x7b", "recurrentgemma-2b"]


class CountingStore:
    """WeightStore wrapper counting physical unit/shard reads."""

    def __new__(cls, *a, **kw):
        class _Counting(WeightStore):
            def __init__(self, *args, **kwargs):
                super().__init__(*args, **kwargs)
                self.unit_reads = 0
                self.shard_opens = 0
                self._read_lock = threading.Lock()

            def read_unit(self, *args, **kwargs):
                with self._read_lock:
                    self.unit_reads += 1
                return super().read_unit(*args, **kwargs)

            def open_unit(self, *args, **kwargs):
                with self._read_lock:
                    self.shard_opens += 1
                return super().open_unit(*args, **kwargs)

            def reset(self):
                self.unit_reads = 0
                self.shard_opens = 0

        return _Counting(*a, **kw)


def _deploy(tmp_path, arch, name="m"):
    cfg = get_config(arch, smoke=True)
    model = transformer.build(cfg)
    store = CountingStore(str(tmp_path))
    deploy_model(store, model, name, jax.random.key(7))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 16)),
        jnp.int32)}
    return cfg, model, store, batch


def _engine(model, store, batch, *, mesh=None, rules=None, cache=None,
            name="m", strategy="cicada"):
    eng = ColdStartEngine(model, name, store, strategy=strategy,
                          mesh=mesh, rules=rules, cache=cache)
    eng.warmup(batch)
    return eng


# ---------------------------------------------------------------------------
# byte-range planning (no mesh required)
# ---------------------------------------------------------------------------

def test_slice_byte_runs_match_numpy(rng):
    for shape in [(8,), (6, 8), (4, 6, 8), (3, 5, 7, 2)]:
        arr = rng.standard_normal(shape).astype(np.float32)
        raw = arr.tobytes()
        for _ in range(8):
            index = []
            for dim in shape:
                if rng.random() < 0.4:
                    index.append(slice(None))
                else:
                    a = int(rng.integers(0, dim))
                    b = int(rng.integers(a + 1, dim + 1))
                    index.append(slice(a, b))
            index = tuple(index)
            runs = slice_byte_runs(shape, arr.itemsize, index)
            got = b"".join(raw[o:o + n] for o, n in runs)
            assert got == np.ascontiguousarray(arr[index]).tobytes()


# ---------------------------------------------------------------------------
# sharded loads on the simulated mesh
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("arch", ARCHS)
def test_bit_identity_vs_single_device(tmp_path, arch):
    """The sharded cold start answers the triggering request with logits
    BIT-identical to the single-device load (the pipeline's compute
    units never run sharded collectives), the assembled params hold the
    exact deployed bytes, and warm sharded forwards agree to fp
    tolerance (sharded matmul reduction order differs)."""
    cfg, model, store, batch = _deploy(tmp_path, arch)
    ref = _engine(model, store, batch).load(batch)

    mesh = make_serving_mesh((1, 4))
    res = _engine(model, store, batch, mesh=mesh).load(batch)

    assert np.asarray(res.logits).tobytes() == \
        np.asarray(ref.logits).tobytes()

    flat_r = jax.tree_util.tree_flatten_with_path(ref.params)[0]
    flat_s = jax.tree_util.tree_flatten_with_path(res.params)[0]
    assert len(flat_r) == len(flat_s)
    sharded_leaves = 0
    for (p1, l1), (p2, l2) in zip(flat_r, flat_s):
        assert np.array_equal(np.asarray(l1), np.asarray(l2)), p1
        if not getattr(l2.sharding, "is_fully_replicated", True):
            sharded_leaves += 1
    assert sharded_leaves > 0          # the mesh is actually used

    # every shard stream ran and was traced
    R = [e for e in res.trace.events if e.stage == "R"]
    assert {e.meta.get("shard") for e in R if e.meta} == {0, 1, 2, 3}

    warm, _ = model.forward(res.params, batch)
    ref_warm, _ = model.forward(ref.params, batch)
    a, b = np.asarray(warm, np.float32), np.asarray(ref_warm, np.float32)
    if cfg.family.value == "moe":
        # bf16 sharded matmuls perturb router logits; a flipped top-k
        # expert legitimately moves single positions — compare the
        # predicted-token agreement instead of elementwise values
        agree = (a.argmax(-1) == b.argmax(-1)).mean()
        assert agree >= 0.9, agree
    else:
        assert np.abs(a - b).max() <= 0.05 * max(np.abs(b).max(), 1.0)


@needs_mesh
def test_second_cold_start_hits_cache_per_shard(tmp_path):
    """With the shared WeightCache, every (unit, shard) stream of a
    second cold start onto the same mesh is served from the cache:
    zero additional store opens, logits identical."""
    cfg, model, store, batch = _deploy(tmp_path, "smollm-360m")
    mesh = make_serving_mesh((1, 4))
    cache = WeightCache(None)
    n_units = len(model.unit_names())

    store.reset()
    r1 = _engine(model, store, batch, mesh=mesh, cache=cache).load(batch)
    assert store.shard_opens == n_units * 4      # one open per stream
    assert store.unit_reads == 0                 # no whole-unit reads

    r2 = _engine(model, store, batch, mesh=mesh, cache=cache).load(batch)
    assert store.shard_opens == n_units * 4      # zero-read per shard
    st = cache.stats()
    assert st.misses == n_units * 4
    assert st.hits == n_units * 4
    assert np.asarray(r2.logits).tobytes() == np.asarray(r1.logits).tobytes()
    assert cache.stats().pinned == 0             # pins checked in

    R = [e for e in r2.trace.events if e.stage == "R"]
    assert all(e.meta and e.meta.get("cached") for e in R)


@needs_mesh
def test_pool_mesh_knob_and_scale_out(tmp_path):
    """InstancePool(mesh_shape=...) wires the mesh through provisioning;
    a scale-out cold start of a second instance is served per-shard
    from the shared cache without re-reading the store."""
    from repro.serving.pool import InstancePool

    cfg, model, store, batch = _deploy(tmp_path, "smollm-360m")
    cache = WeightCache(None)
    pool = InstancePool("m", lambda: (model, batch), store,
                        strategy="cicada", max_instances=2, cache=cache,
                        mesh_shape=4)
    i1 = pool.acquire()
    i2 = pool.acquire()
    store.reset()
    logits1, info1 = i1.invoke(batch)
    assert info1["cold"]
    opens = store.shard_opens
    assert opens > 0
    logits2, info2 = i2.invoke(batch)
    assert info2["cold"]
    assert store.shard_opens == opens            # all shards cache-served
    np.testing.assert_array_equal(np.asarray(logits1), np.asarray(logits2))
    pool.release(i1, logical_now=0.0, cold=True)
    pool.release(i2, logical_now=0.0, cold=True)


@needs_mesh
def test_non_divisible_axis_falls_back_to_replication(tmp_path):
    """Axes that do not divide their dimension resolve to replication
    (never a crash, never a wrong shard): smollm's n_heads=3 on a
    4-way mesh replicates the attention projections while the FFN
    (d_ff % 4 == 0) stays sharded — and under rules whose every axis
    is non-divisible, the whole unit replicates and the load still
    produces the deployed bytes."""
    cfg, model, store, batch = _deploy(tmp_path, "smollm-360m")
    mesh = make_serving_mesh((1, 4))
    specs = leaf_specs(model.abstract_unit("block_000"), mesh,
                       _serve_rules())
    assert tuple(specs["attn/wq"].spec) == ()            # 3 heads % 4
    assert any(ax is not None for ax in tuple(specs["mlp/wg"].spec))

    # a config whose every sharded dim is indivisible by 4: the whole
    # plan replicates, and the load still produces the deployed bytes
    import dataclasses
    odd_cfg = dataclasses.replace(cfg, name="odd", d_model=54, n_heads=3,
                                  n_kv_heads=1, d_ff=150, vocab_size=510)
    odd_model = transformer.build(odd_cfg)
    deploy_model(store, odd_model, "odd", jax.random.key(3))
    plan = plan_unit(store, "odd", "block_000",
                     odd_model.abstract_unit("block_000"), mesh,
                     _serve_rules())
    assert all(all(ax is None for ax in tuple(s.spec))
               for s in plan.specs.values())
    obatch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, 510, (1, 16)), jnp.int32)}
    ref = _engine(odd_model, store, obatch, name="odd").load(obatch)
    res = _engine(odd_model, store, obatch, mesh=mesh,
                  name="odd").load(obatch)
    assert np.asarray(res.logits).tobytes() == \
        np.asarray(ref.logits).tobytes()


def _serve_rules():
    from repro.distributed.sharding import serve_rules
    return serve_rules()


def test_mesh_of_one_degenerates_to_seed_path(tmp_path):
    """mesh=(1,1) is exactly the seed pipeline: unit-granular whole
    reads, no shard streams, identical logits and unsharded params."""
    cfg, model, store, batch = _deploy(tmp_path, "smollm-360m")
    ref = _engine(model, store, batch).load(batch)
    mesh = make_serving_mesh((1, 1))
    eng = _engine(model, store, batch, mesh=mesh)
    assert eng.mesh is None                      # degenerate normalization
    store.reset()
    res = eng.load(batch)
    assert store.unit_reads == len(model.unit_names())
    assert store.shard_opens == 0
    assert np.asarray(res.logits).tobytes() == \
        np.asarray(ref.logits).tobytes()
    R = [e for e in res.trace.events if e.stage == "R"]
    assert all(not (e.meta and "shard" in e.meta) for e in R)


@needs_mesh
def test_fused_strategy_places_params_on_mesh(tmp_path):
    """Non-decoupled strategies (PISeL/mini) keep unit-granular fused
    retrieval but still assemble mesh-sharded steady-state params."""
    cfg, model, store, batch = _deploy(tmp_path, "mixtral-8x7b")
    mesh = make_serving_mesh((1, 4))
    ref = _engine(model, store, batch, strategy="mini").load(batch)
    store.reset()
    res = _engine(model, store, batch, mesh=mesh,
                  strategy="mini").load(batch)
    assert store.unit_reads == len(model.unit_names())   # fused reads
    assert store.shard_opens == 0
    assert np.asarray(res.logits).tobytes() == \
        np.asarray(ref.logits).tobytes()
    anysharded = any(
        not getattr(l.sharding, "is_fully_replicated", True)
        for l in jax.tree.leaves(res.params))
    assert anysharded


# ---------------------------------------------------------------------------
# int8 shard streaming: dequant/cast leaves get shard plans too (PR 5)
# ---------------------------------------------------------------------------

def _deploy_int8(tmp_path, name="q8"):
    """An int8-quantized deployment whose sharded leaves clear the
    RUN_FLOOR at 1 byte/element: d_ff/4 = 1024-byte column runs.  The
    attention projections (n_heads=3 on a 4-way mesh) replicate — the
    *replication* fallback, which is orthogonal to quantization."""
    import dataclasses
    cfg = dataclasses.replace(get_config("smollm-360m", smoke=True),
                              name=name, d_ff=4096, vocab_size=4096)
    model = transformer.build(cfg)
    store = CountingStore(str(tmp_path))
    deploy_model(store, model, name, jax.random.key(11), quant="int8")
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 16)),
        jnp.int32)}
    return cfg, model, store, batch


@needs_mesh
def test_int8_leaves_get_ranged_shard_plans(tmp_path):
    """No whole-leaf fallback for dequant leaves: every quantized leaf
    whose resolved spec is sharded streams byte-range pieces (values +
    per-column scale slices), and its device buffers commit in the
    transformed dtype."""
    cfg, model, store, batch = _deploy_int8(tmp_path)
    mesh = make_serving_mesh((1, 4))
    plan = plan_unit(store, "q8", "block_000",
                     model.abstract_unit("block_000"), mesh, _serve_rules())
    saw_sharded_quant = 0
    for leaf, sharding in plan.specs.items():
        if not plan.quant[leaf]:
            continue
        assert plan.transformed[leaf] and plan.out_dtype[leaf] is not None
        pieces = [p for sh in plan.pieces for p in sh if p.leaf == leaf]
        if all(ax is None for ax in tuple(sharding.spec)):
            continue                     # replication fallback (n_heads=3)
        saw_sharded_quant += 1
        assert pieces and all(p.index is not None for p in pieces), leaf
        # the scale bytes ride along in the stream's cost model
        for p in pieces:
            idx = p.index
            lo = 0 if idx[-1].start is None else idx[-1].start
            hi = plan.shapes[leaf][-1] if idx[-1].stop is None \
                else idx[-1].stop
            assert p.nbytes >= (hi - lo) * 4
    assert saw_sharded_quant >= 3            # mlp wg/wu/wd at least


@needs_mesh
@pytest.mark.parametrize("width", [2, 4])
def test_int8_sharded_bit_identical_to_whole_read(tmp_path, width):
    """A quantized-leaf cold start on a mesh of {2, 4} devices streams
    shards (per-shard dequant on the placement lanes) and produces
    logits AND assembled params bit-identical to the whole-read dequant
    path; mesh=1 is covered by the degenerate-normalization test."""
    cfg, model, store, batch = _deploy_int8(tmp_path)
    ref = _engine(model, store, batch, name="q8").load(batch)

    mesh = make_serving_mesh((1, width))
    store.reset()
    res = _engine(model, store, batch, mesh=mesh, name="q8").load(batch)
    assert store.unit_reads == 0             # no whole-unit fallback reads
    assert store.shard_opens == len(model.unit_names()) * width

    assert np.asarray(res.logits).tobytes() == \
        np.asarray(ref.logits).tobytes()
    flat_r = jax.tree_util.tree_flatten_with_path(ref.params)[0]
    flat_s = jax.tree_util.tree_flatten_with_path(res.params)[0]
    assert len(flat_r) == len(flat_s)
    for (p1, l1), (p2, l2) in zip(flat_r, flat_s):
        assert np.array_equal(np.asarray(l1), np.asarray(l2)), p1
    assert any(not getattr(l.sharding, "is_fully_replicated", True)
               for _, l in flat_s)


@needs_mesh
def test_per_shard_transform_subrows_in_trace(tmp_path):
    """Fused per-shard dequants are first-class trace rows: every
    transformed shard emits a 'T' event tagged with its shard index,
    the Gantt gains a Transform lane, and the new events stay off the
    default busy-time stages so utilization is unchanged by them."""
    cfg, model, store, batch = _deploy_int8(tmp_path)
    mesh = make_serving_mesh((1, 4))
    res = _engine(model, store, batch, mesh=mesh, name="q8").load(batch)
    tr = res.trace

    T = [e for e in tr.events if e.stage == "T"]
    assert T, "per-shard transforms emitted no T sub-rows"
    assert all(e.meta and "shard" in e.meta for e in T)
    assert {e.meta["shard"] for e in T} == set(range(4))
    assert all(e.t_end >= e.t_start for e in T)
    # the transform lanes land on the units that actually dequantize
    assert {e.layer for e in T} <= set(model.unit_names())
    assert "block_000" in {e.layer for e in T}

    # visible as its own Gantt row; excluded from default busy time
    assert "Transform" in tr.render_gantt()
    assert tr.summary()["work_T"] > 0.0
    assert tr.busy_time(("T",)) > 0.0
    assert tr.busy_time() == tr.busy_time(("L", "A", "E"))


@needs_mesh
def test_int8_second_cold_start_zero_read_per_shard(tmp_path):
    """With the shared WeightCache, the second quantized cold start is
    served entirely from cached shard payloads (raw int8 values + scale
    slices): zero additional store opens, identical logits."""
    cfg, model, store, batch = _deploy_int8(tmp_path)
    mesh = make_serving_mesh((1, 4))
    cache = WeightCache(None)
    n_units = len(model.unit_names())

    store.reset()
    r1 = _engine(model, store, batch, mesh=mesh, cache=cache,
                 name="q8").load(batch)
    assert store.shard_opens == n_units * 4
    assert store.unit_reads == 0

    r2 = _engine(model, store, batch, mesh=mesh, cache=cache,
                 name="q8").load(batch)
    assert store.shard_opens == n_units * 4      # zero-read per shard
    st = cache.stats()
    assert st.misses == n_units * 4 and st.hits == n_units * 4
    assert np.asarray(r2.logits).tobytes() == \
        np.asarray(r1.logits).tobytes()
    R = [e for e in r2.trace.events if e.stage == "R"]
    assert R and all(e.meta and e.meta.get("cached") for e in R)
    assert cache.stats().pinned == 0
