"""ColdStartEngine end-to-end: all five strategies produce exactly the
deployed model's logits, pipeline event-ordering invariants hold, and
the paper's qualitative claims reproduce under a throttled store."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ColdStartEngine, get_strategy
from repro.models import transformer
from repro.models.api import get_config
from repro.store.store import (BandwidthModel, WeightStore, deploy_model,
                               unflatten_unit)

STRATS = ["traditional", "pisel", "mini", "preload", "cicada"]


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    d = tmp_path_factory.mktemp("store")
    cfg = get_config("smollm-360m", smoke=True)
    m = transformer.build(cfg)
    store = WeightStore(str(d), BandwidthModel(bandwidth_mbps=120,
                                               latency_ms=0.3))
    deploy_model(store, m, "m", jax.random.key(7))
    # reference logits from the deployed weights
    units = {}
    for u in m.unit_names():
        leaves = store.read_and_deserialize("m", u)
        units[u] = unflatten_unit(m.abstract_unit(u),
                                  {k: v for k, (v, _) in leaves.items()})
    params = m.assemble(units)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 16)),
        jnp.int32)}
    ref_logits, _ = m.forward(params, batch)
    return store, m, cfg, batch, np.asarray(ref_logits, np.float32)


@pytest.mark.parametrize("strategy", STRATS)
def test_strategy_correctness(setup, strategy):
    store, m, cfg, batch, ref = setup
    eng = ColdStartEngine(m, "m", store, strategy=strategy,
                          chunk_bytes=1 << 15)
    eng.warmup(batch)
    res = eng.load(batch)
    got = np.asarray(res.logits, np.float32)
    np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)
    # assembled params serve warm requests identically
    warm, _ = m.forward(res.params, batch)
    np.testing.assert_allclose(np.asarray(warm, np.float32), ref,
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("strategy", STRATS)
def test_event_ordering_invariants(setup, strategy):
    store, m, cfg, batch, ref = setup
    eng = ColdStartEngine(m, "m", store, strategy=strategy,
                          chunk_bytes=1 << 15)
    eng.warmup(batch)
    tr = eng.load(batch).trace
    L = tr.events_for("L")
    A = tr.events_for("A")
    E = tr.events_for("E")
    R = tr.events_for("R")
    units = m.unit_names()
    assert set(L) == set(A) == set(E) == set(units)
    strat = get_strategy(strategy)
    for u in units:
        # A_i cannot finish before its structure exists
        assert A[u].t_end >= L[u].t_end - 1e-6
        # E_i strictly after its weights are applied
        assert E[u].t_start >= A[u].t_end - 1e-6
    # E is sequential in layer order
    ee = [E[u] for u in units]
    for a, b in zip(ee, ee[1:]):
        assert b.t_start >= a.t_end - 1e-6
    assert set(R) == set(units)
    for u in units:
        # retrieval always completes before its application completes
        assert R[u].t_end <= A[u].t_end + 1e-6
    if strat.decouple:
        # async retrieval was issued at request arrival: the earliest
        # stream starts before the last construction finishes
        assert min(r.t_start for r in R.values()) < \
            max(l.t_end for l in L.values()) + 1e-6
    else:
        # fused: retrieval cannot begin until the layer is constructed
        for u in units:
            assert R[u].t_start >= L[u].t_end - 1e-6
    if not strat.pipelined:
        # traditional: phases do not interleave
        assert max(e.t_end for e in L.values()) <= \
            min(a.t_start for a in A.values()) + 1e-6
        assert max(a.t_end for a in A.values()) <= \
            min(e.t_start for e in E.values()) + 1e-6


def test_paper_qualitative_claims(setup):
    store, m, cfg, batch, ref = setup
    res = {}
    for s in STRATS:
        eng = ColdStartEngine(m, "m", store, strategy=s,
                              chunk_bytes=1 << 15)
        eng.warmup(batch)
        res[s] = eng.load(batch).trace.summary()
    # MiniLoader cuts construction work (paper: >50% on real models)
    assert res["mini"]["work_L"] < res["pisel"]["work_L"]
    assert res["cicada"]["work_L"] < res["preload"]["work_L"]
    # placeholder memory: 1-bit vs fp32 is exactly 1/32 per layer (paper
    # Fig. 10); compare totals (peak depends on pipeline dynamics — mini
    # constructs faster so more placeholders coexist)
    tr_mini = ColdStartEngine(m, "m", store, strategy="mini",
                              chunk_bytes=1 << 15)
    tr_mini.warmup(batch)
    t_mini = tr_mini.load(batch).trace
    tr_pisel = ColdStartEngine(m, "m", store, strategy="pisel",
                               chunk_bytes=1 << 15)
    tr_pisel.warmup(batch)
    t_pisel = tr_pisel.load(batch).trace
    ratio = t_pisel.memory_total_bytes() / t_mini.memory_total_bytes()
    assert 24 < ratio <= 32.5, ratio
    # Cicada beats PISeL end-to-end
    assert res["cicada"]["total_s"] < res["pisel"]["total_s"]
    # the decoupler's utilization mechanism (paper Fig. 12) shows in
    # Preload, where construction still covers the I/O window; under
    # Mini/Cicada our JAX MiniLoader removes construction entirely, so
    # the pipeline is I/O-bound and CPU-busy utilization legitimately
    # drops while E2E improves (EXPERIMENTS.md §Reproduction note A)
    assert res["preload"]["utilization"] > res["pisel"]["utilization"]


def test_int8_deployment_pipeline(tmp_path):
    """Cold start from an int8 store: dequant happens at application."""
    cfg = get_config("smollm-360m", smoke=True)
    m = transformer.build(cfg)
    store = WeightStore(str(tmp_path))
    deploy_model(store, m, "q", jax.random.key(9), quant="int8")
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 8)),
        jnp.int32)}
    eng = ColdStartEngine(m, "q", store, strategy="cicada")
    eng.warmup(batch)
    res = eng.load(batch)
    # logits close to the f32 deployment (quantization-level tolerance)
    store2 = WeightStore(str(tmp_path))
    deploy_model(store2, m, "f", jax.random.key(9))
    eng2 = ColdStartEngine(m, "f", store2, strategy="cicada")
    eng2.warmup(batch)
    ref = eng2.load(batch)
    a = np.asarray(res.logits, np.float32)
    b = np.asarray(ref.logits, np.float32)
    assert np.abs(a - b).max() < 0.15 * max(np.abs(b).max(), 1.0)
