"""Dry-run machinery self-test (subprocess: it forces 512 host devices).

Covers one cell per step kind (train / prefill / decode / long-decode)
at reduced config on both production mesh shapes, plus the skip logic.
Full-size cells are exercised by ``python -m repro.launch.dryrun --all``
(see EXPERIMENTS.md §Dry-run); they are too slow for unit CI.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_dryrun(*args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("JAX_PLATFORMS", None)
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)


@pytest.mark.slow
def test_smoke_train_both_meshes(tmp_path):
    out = tmp_path / "r.json"
    r = run_dryrun("--arch", "yi-9b", "--shape", "train_4k", "--smoke",
                   "--both-meshes", "--out", str(out))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    recs = json.loads(out.read_text())
    assert [x["mesh"] for x in recs] == ["16x16", "2x16x16"]
    assert all(x["status"] == "ok" for x in recs)
    assert recs[0]["devices"] == 256 and recs[1]["devices"] == 512
    # single-pod record carries roofline costs
    assert recs[0]["cost_per_device"]["flops"] > 0
    assert recs[0]["cost_per_device"]["collectives"]["total"] > 0
    assert "micro_batches" in recs[0]


@pytest.mark.slow
def test_smoke_decode_and_skip(tmp_path):
    out = tmp_path / "r.json"
    r = run_dryrun("--arch", "mamba2-780m", "--shape", "long_500k",
                   "--smoke", "--out", str(out))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text())[0]
    assert rec["status"] == "ok"            # SSM runs long-context decode

    r2 = run_dryrun("--arch", "yi-9b", "--shape", "long_500k",
                    "--smoke", "--out", str(out))
    rec2 = json.loads(out.read_text())[0]
    assert rec2["status"] == "skip"
    assert "full attention" in rec2["skip_reason"]

    r3 = run_dryrun("--arch", "hubert-xlarge", "--shape", "decode_32k",
                    "--smoke", "--out", str(out))
    rec3 = json.loads(out.read_text())[0]
    assert rec3["status"] == "skip"
    assert "encoder" in rec3["skip_reason"]


@pytest.mark.slow
def test_smoke_moe_prefill(tmp_path):
    out = tmp_path / "r.json"
    r = run_dryrun("--arch", "mixtral-8x7b", "--shape", "prefill_32k",
                   "--smoke", "--out", str(out))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text())[0]
    assert rec["status"] == "ok"
    assert rec["memory"]["live_bytes_per_device"] > 0
