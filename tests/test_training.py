"""Training substrate: convergence, checkpoint/restart determinism,
preemption safety, data-pipeline purity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.distributed.checkpoint import Checkpointer
from repro.models import transformer
from repro.models.api import get_config
from repro.training.data import MarkovLM, host_batches
from repro.training.optim import AdamW, global_norm, warmup_cosine
from repro.training.train import TrainLoop, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("smollm-360m", smoke=True)
    model = transformer.build(cfg)
    return cfg, model


def test_loss_decreases(tiny):
    cfg, model = tiny
    opt = AdamW(lr=1e-2)
    step = jax.jit(make_train_step(model, opt))
    params = model.init(jax.random.key(0))
    opt_state = opt.init(params)
    data = MarkovLM(cfg.vocab_size, seed=0)
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in
                 data.batch(8, 32, i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.85, losses[::10]
    assert all(np.isfinite(l) for l in losses)


def test_grad_clipping_bounds_norm(tiny):
    cfg, model = tiny
    opt = AdamW(lr=1e-3, clip_norm=1.0)
    params = model.init(jax.random.key(0))
    data = MarkovLM(cfg.vocab_size, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch(4, 16, 0).items()}
    (_, _), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True)(params)
    _, _, metrics = opt.update(grads, opt.init(params), params)
    assert float(metrics["grad_norm"]) > 0


def test_warmup_cosine_schedule():
    sched = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(sched(jnp.asarray(100))) == pytest.approx(1e-4, rel=0.01)
    # monotone decreasing after warmup
    vals = [float(sched(jnp.asarray(s))) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_data_pipeline_pure_and_sharded():
    gen = MarkovLM(256, seed=3)
    b1 = gen.batch(8, 16, step=5)
    b2 = gen.batch(8, 16, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host shards partition the global batch
    h0 = next(host_batches(gen, global_batch=8, seq=16, host_id=0,
                           n_hosts=2, start_step=5))
    h1 = next(host_batches(gen, global_batch=8, seq=16, host_id=1,
                           n_hosts=2, start_step=5))
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(gen.sample(2, 8, 0)[:, 1:-0 or None][:, :-1],
                                  gen.batch(2, 8, 0)["labels"][:, :-1])


def test_markov_floor_below_uniform():
    gen = MarkovLM(128, seed=0)
    assert gen.bigram_ce_floor() < np.log(128) * 0.6


def test_checkpoint_resume_exact_trajectory(tiny, tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    cfg, model = tiny
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, opt))
    data = MarkovLM(cfg.vocab_size, seed=1)

    def run(params, opt_state, lo, hi):
        for i in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in
                     data.batch(4, 16, i).items()}
            params, opt_state, _ = step(params, opt_state, batch)
        return params, opt_state

    p0 = model.init(jax.random.key(0))
    s0 = opt.init(p0)
    p_straight, _ = run(p0, s0, 0, 6)

    p3, s3 = run(p0, s0, 0, 3)
    ck = Checkpointer(str(tmp_path))
    ck.save(3, p3, s3)
    step_r, p_r, s_r = ck.restore(model.abstract(),
                                  jax.eval_shape(opt.init, model.abstract()))
    assert step_r == 3
    p_resumed, _ = run(p_r, s_r, 3, 6)
    for a, b in zip(jax.tree.leaves(p_straight), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-6, rtol=1e-6)


def test_preemption_saves_and_stops(tiny, tmp_path):
    cfg, model = tiny
    opt = AdamW(lr=1e-3)
    ck = Checkpointer(str(tmp_path))
    loop = TrainLoop(model, opt, checkpointer=ck, ckpt_every=1000,
                     log_every=1000, log_fn=lambda s: None)
    params = model.init(jax.random.key(0))
    opt_state = opt.init(params)
    data = MarkovLM(cfg.vocab_size, seed=0)
    batches = host_batches(data, global_batch=4, seq=16)

    calls = {"n": 0}
    orig = loop.step_fn

    def step_and_preempt(*a):
        calls["n"] += 1
        if calls["n"] == 2:
            loop._preempted = True          # simulated SIGTERM
        return orig(*a)

    loop.step_fn = step_and_preempt
    loop.run(params, opt_state, batches, n_steps=50)
    assert calls["n"] == 2                  # stopped early
    assert ck.latest_step() == 2            # checkpoint written on preempt
