"""Serverless platform: cold/warm lifecycle, keep-alive eviction,
trace generation, batched decode server."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import transformer
from repro.models.api import get_config
from repro.serving.engine import (BatchedLMServer, FunctionInstance,
                                  ServerlessPlatform)
from repro.serving.trace import Invocation, azure_like_trace, summarize
from repro.store.store import WeightStore, deploy_model


@pytest.fixture(scope="module")
def deployed(tmp_path_factory):
    d = tmp_path_factory.mktemp("store")
    cfg = get_config("smollm-360m", smoke=True)
    m = transformer.build(cfg)
    store = WeightStore(str(d))
    deploy_model(store, m, "smollm-360m", jax.random.key(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 8)),
        jnp.int32)}
    return store, m, cfg, batch


@pytest.mark.slow
def test_cold_then_warm(deployed):
    store, m, cfg, batch = deployed
    inst = FunctionInstance(m, "smollm-360m", store, strategy="cicada",
                            example_batch=batch)
    logits1, info1 = inst.invoke(batch)
    assert info1["cold"] and info1["load_s"] > 0
    logits2, info2 = inst.invoke(batch)
    assert not info2["cold"] and info2["infer_s"] > 0
    np.testing.assert_allclose(np.asarray(logits1, np.float32),
                               np.asarray(logits2, np.float32),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.slow
def test_eviction_forces_cold_start(deployed):
    store, m, cfg, batch = deployed
    inst = FunctionInstance(m, "smollm-360m", store, example_batch=batch)
    inst.invoke(batch)
    assert inst.live
    inst.evict()
    assert not inst.live
    _, info = inst.invoke(batch)
    assert info["cold"]


@pytest.mark.slow
def test_platform_trace_replay(deployed):
    store, m, cfg, batch = deployed
    builders = {"smollm-360m": lambda: (m, batch)}
    platform = ServerlessPlatform(store, builders, strategy="cicada",
                                  keep_alive_s=120.0)
    trace = [Invocation(0.0, "smollm-360m", 0),
             Invocation(1.0, "smollm-360m", 1),
             Invocation(300.0, "smollm-360m", 2)]   # past keep-alive
    out = platform.run_trace(trace, lambda name: batch)
    assert [r.cold for r in out] == [True, False, True]
    assert all(r.latency_s > 0 for r in out)


@pytest.mark.slow
def test_platform_concurrent_replay(deployed):
    """run_trace(concurrency=4): concurrent cold starts scale the pool
    out, responses keep trace order and gain queueing delay."""
    store, m, cfg, batch = deployed
    builders = {"smollm-360m": lambda: (m, batch)}
    platform = ServerlessPlatform(store, builders, strategy="cicada",
                                  keep_alive_s=1000.0, max_instances=2)
    trace = [Invocation(0.0, "smollm-360m", i) for i in range(4)]
    out = platform.run_trace(trace, lambda name: batch, concurrency=4)
    assert [r.req_id for r in out] == [0, 1, 2, 3]
    assert sum(r.cold for r in out) == 2          # one per instance
    assert all(r.queue_s >= 0 for r in out)
    assert all(r.latency_s > 0 for r in out)
    ps = platform.pool_stats()["smollm-360m"]
    assert ps.size == 2
    assert ps.cold_starts == 2 and ps.warm_hits == 2
    assert platform.last_router_stats.submitted == 4
    assert platform.last_router_stats.completed == 4


def test_trace_generator_statistics():
    tr = azure_like_trace(duration_s=3600.0, n_invocations=2426,
                          models=["a", "b", "c"], seed=0)
    s = summarize(tr)
    assert s["n"] == 2426                      # exact count (paper Sec IV-B)
    assert s["burst_ratio"] > 2.0              # bursty like Fig. 8
    ts = [i.t for i in tr]
    assert ts == sorted(ts)
    assert 0 <= min(ts) and max(ts) <= 3600.0
    assert {i.model for i in tr} == {"a", "b", "c"}
    # deterministic
    tr2 = azure_like_trace(duration_s=3600.0, n_invocations=2426,
                           models=["a", "b", "c"], seed=0)
    assert [(i.t, i.model) for i in tr] == [(i.t, i.model) for i in tr2]


@pytest.mark.slow
def test_batched_decode_matches_stepwise_forward():
    """Greedy generation through the server == argmax over full forwards."""
    import dataclasses
    cfg = dataclasses.replace(get_config("smollm-360m", smoke=True),
                              compute_dtype=jnp.float32)
    m = transformer.build(cfg)
    params = m.init(jax.random.key(1))
    srv = BatchedLMServer(m, params, cache_len=64)
    prompt = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab_size, (2, 8)), jnp.int32)
    gen = srv.generate(prompt, n_new=5)
    assert gen.shape == (2, 5)
    # oracle: greedy over repeated full forwards
    toks = prompt
    expect = []
    for _ in range(5):
        lg, _ = m.forward(params, {"tokens": toks})
        nxt = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        expect.append(nxt)
        toks = jnp.concatenate([toks, nxt], axis=1)
    np.testing.assert_array_equal(np.asarray(gen),
                                  np.asarray(jnp.concatenate(expect, 1)))
