"""Distributed substrate: sharding-rule resolution, checkpoint
atomicity/retention/elasticity, gradient compression, straggler
detection, and a multi-device shard_map collective (subprocess)."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.compression import (dequantize_int8,
                                           init_error_feedback,
                                           make_error_feedback_transform,
                                           quantize_int8)
from repro.distributed.resilience import HeartbeatMonitor


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_guarded_spec_drops_nondivisible():
    mesh = _mesh11()
    rules = shd.ShardingRules({"heads": "model", "batch": "data"})
    # size-1 mesh axes resolve to replication (never crash)
    spec = shd._guarded_spec(mesh, rules, (4, 6), ("batch", "heads"))
    assert spec == P()


@given(dim=st.integers(1, 64))
def test_guarded_spec_divisibility(dim):
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rules = shd.ShardingRules({"x": "model"})
    spec = shd._guarded_spec(mesh, rules, (dim,), ("x",))
    # with mesh size 1 everything must be replicated
    assert spec == P()


def test_param_specs_cover_tree():
    from repro.models import transformer
    from repro.models.api import get_config
    cfg = get_config("mixtral-8x7b", smoke=True)
    m = transformer.build(cfg)
    ab = m.abstract()
    mesh = _mesh11()
    rules = shd.train_rules()
    specs = shd.param_specs(ab, mesh, rules)
    assert jax.tree_util.tree_structure(specs) == \
        jax.tree_util.tree_structure(ab)


def test_constrain_noop_outside_context():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2 ** 16), scale=st.floats(1e-3, 1e3))
def test_int8_quant_bound(seed, scale):
    x = jnp.asarray(np.random.default_rng(seed).standard_normal(64) * scale,
                    jnp.float32)
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(deq - x))) <= float(s) / 2 + 1e-6


def test_error_feedback_unbiased_over_time():
    """Error feedback: the *sum* of compressed grads tracks the sum of
    true grads (residual stays bounded)."""
    r = np.random.default_rng(0)
    f = make_error_feedback_transform()
    g_true = {"w": jnp.asarray(r.standard_normal((32, 32)), jnp.float32)}
    ef = init_error_feedback(g_true)
    acc = jnp.zeros((32, 32))
    K = 50
    for _ in range(K):
        comp, ef = f(g_true, ef)
        acc = acc + comp["w"]
    err = np.abs(np.asarray(acc / K - g_true["w"])).max()
    # residual carry-over keeps the time-average within one quantum of truth
    q_step = float(jnp.max(jnp.abs(g_true["w"]))) / 127.0
    assert err < q_step * 2 / K * 50       # bounded by quantum
    assert float(jnp.max(jnp.abs(ef["w"]))) <= q_step  # residual bounded


def test_compressed_psum_multidevice_subprocess():
    """shard_map int8 all-gather reduce on 4 fake devices."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed.compression import compressed_psum
mesh = jax.make_mesh((4,), ("dp",), axis_types=(jax.sharding.AxisType.Auto,))
x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 64)), jnp.float32)
f = shard_map(lambda xs: compressed_psum(xs[0], "dp")[None],
              mesh=mesh, in_specs=P("dp"), out_specs=P("dp"))
got = np.asarray(f(x))
want = np.asarray(jnp.mean(x, axis=0))
for row in got:
    np.testing.assert_allclose(row, want, atol=np.abs(x).max()/127.0 + 1e-6)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# checkpoint details
# ---------------------------------------------------------------------------

def test_checkpoint_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": np.arange(8, dtype=np.float32)}
    for s in (1, 2, 3, 4):
        ck.save(s, {"w": tree["w"] * s})
    assert ck.all_steps() == [3, 4]        # retention
    assert ck.latest_step() == 4
    ab = {"w": jax.ShapeDtypeStruct((8,), jnp.float32)}
    step, params, _ = ck.restore(ab)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(params["w"]), tree["w"] * 4)


def test_checkpoint_no_partial_state_on_interrupt(tmp_path):
    """A .tmp directory never shadows a completed checkpoint."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": np.ones(4, np.float32)})
    # simulate a crashed save: leftover tmp dir
    os.makedirs(str(tmp_path / "step_00000002.tmp"))
    assert ck.latest_step() == 1
    assert ck.all_steps() == [1]


def test_checkpoint_restore_with_shardings(tmp_path):
    """Elastic path: restore with explicit (single-device) shardings."""
    ck = Checkpointer(str(tmp_path))
    w = np.random.default_rng(0).standard_normal((8, 4)).astype(np.float32)
    ck.save(7, {"w": w})
    mesh = _mesh11()
    sh = {"w": jax.sharding.NamedSharding(mesh, P())}
    _, params, _ = ck.restore({"w": jax.ShapeDtypeStruct((8, 4),
                                                         jnp.float32)},
                              shardings=sh)
    np.testing.assert_array_equal(np.asarray(params["w"]), w)


# ---------------------------------------------------------------------------
# resilience
# ---------------------------------------------------------------------------

def test_straggler_detection():
    mon = HeartbeatMonitor(threshold=1.5, timeout_s=100.0)
    for step in range(5):
        for h in range(4):
            mon.report(f"host{h}", 1.0 if h != 2 else 3.0, now=float(step))
    assert mon.stragglers(now=5.0) == ["host2"]


def test_dead_host_detection():
    mon = HeartbeatMonitor(timeout_s=10.0)
    mon.report("a", 1.0, now=0.0)
    mon.report("b", 1.0, now=0.0)
    mon.report("a", 1.0, now=50.0)
    assert "b" in mon.stragglers(now=50.0)
    assert "a" not in mon.stragglers(now=50.0)
